#!/usr/bin/env python3
"""Validate a chipsim flight-recorder trace against the Chrome trace-event format.

Usage: trace_check.py <trace.json> [<more.json> ...]

Structural checks (stdlib only, no Perfetto dependency):

  - the document is a JSON object with a non-empty `traceEvents` array;
  - every event has a known phase (`X i C b n e M`), integer pid/tid,
    a string name, and (except metadata) a non-negative numeric `ts`;
  - complete spans (`X`) carry a non-negative `dur`;
  - spans on the same (pid, tid) track strictly nest: a span either
    contains the next one or ends before it starts — partial overlap
    would render as garbage in Perfetto and indicates a recorder bug;
  - async events (`b`/`n`/`e`) balance per (pid, cat, id): begins and
    ends pair up, nothing fires before the first begin or after the
    last end;
  - every request-lifecycle track (async events named `request`)
    reaches a terminal state: its final `e` event carries a non-empty
    `args.state` (finished / dropped / truncated);
  - counter events (`C`) carry only numeric series values.

CI generates a trace with `chipsim trace --scenario <fleet preset>` and
runs this checker over it, so the exported document stays loadable in
Perfetto / chrome://tracing as the recorder evolves.
"""

import json
import sys

PHASES = {"X", "i", "C", "b", "n", "e", "M"}
# Span-nesting tolerance in trace-event time units (µs): ts/dur are
# nanoseconds divided by 1e3, so 1e-6 µs = 1/1000 of the ns grid.
EPS = 1e-6


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_events(events, errors):
    """Per-event field checks; returns events grouped for the structural passes."""
    spans = {}  # (pid, tid) -> [(ts, dur, name)]
    asyncs = {}  # (pid, cat, id) -> [(ts, ph, name, args)]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing or empty 'name'")
        for key in ("pid", "tid"):
            if not (isinstance(ev.get(key), int) and not isinstance(ev.get(key), bool)):
                errors.append(f"{where}: '{key}' must be an integer")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not is_num(ts) or ts < 0:
            errors.append(f"{where} ({ev.get('name')}): bad 'ts' {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not is_num(dur) or dur < 0:
                errors.append(f"{where} ({ev.get('name')}): negative or missing 'dur' {dur!r}")
            else:
                spans.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                    (ts, dur, ev["name"])
                )
        elif ph in ("b", "n", "e"):
            if not isinstance(ev.get("id"), str) or not ev["id"]:
                errors.append(f"{where} ({ev.get('name')}): async event without 'id'")
                continue
            key = (ev.get("pid"), ev.get("cat"), ev["id"])
            asyncs.setdefault(key, []).append((ts, ph, ev["name"], ev.get("args") or {}))
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where} ({ev.get('name')}): counter without series")
            else:
                for k, v in args.items():
                    if not is_num(v):
                        errors.append(
                            f"{where} ({ev.get('name')}): counter series '{k}' not numeric"
                        )
    return spans, asyncs


def check_nesting(spans, errors):
    """Spans on one track must nest or be disjoint — no partial overlap."""
    for (pid, tid), track in sorted(spans.items()):
        # Sort by start, longest first on ties, so a parent precedes the
        # children it contains.
        track.sort(key=lambda s: (s[0], -s[1]))
        stack = []  # end times of open ancestor spans
        for ts, dur, name in track:
            end = ts + dur
            while stack and stack[-1] <= ts + EPS:
                stack.pop()
            if stack and end > stack[-1] + EPS:
                errors.append(
                    f"track pid={pid} tid={tid}: span '{name}' [{ts}, {end}] "
                    f"partially overlaps an earlier span ending at {stack[-1]}"
                )
                continue
            stack.append(end)


def check_async(asyncs, errors):
    """Begin/end balance per async track, plus request terminal states."""
    requests = terminal = 0
    for (pid, cat, aid), evs in sorted(asyncs.items()):
        evs.sort(key=lambda e: e[0])
        label = f"async pid={pid} cat={cat} id={aid}"
        begins = [e for e in evs if e[1] == "b"]
        ends = [e for e in evs if e[1] == "e"]
        if not begins:
            errors.append(f"{label}: events without a 'b' begin")
            continue
        if len(begins) != len(ends):
            errors.append(f"{label}: {len(begins)} begin(s) vs {len(ends)} end(s)")
            continue
        first_b = min(e[0] for e in begins)
        last_e = max(e[0] for e in ends)
        if any(e[0] < first_b for e in evs):
            errors.append(f"{label}: event fires before the first begin")
        if any(e[0] > last_e for e in evs):
            errors.append(f"{label}: event fires after the last end")
        if any(e[2] == "request" for e in evs):
            requests += 1
            final = max(ends, key=lambda e: e[0])
            state = final[3].get("state")
            if isinstance(state, str) and state:
                terminal += 1
            else:
                errors.append(f"{label}: request never reaches a terminal state")
    return requests, terminal


def check_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: FAILED\n  - unreadable: {e}", file=sys.stderr)
        return 1
    errors = []
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        errors.append("document has no 'traceEvents' array")
        events = []
    elif not events:
        errors.append("'traceEvents' is empty — the recorder traced nothing")
    spans, asyncs = check_events(events, errors)
    check_nesting(spans, errors)
    requests, terminal = check_async(asyncs, errors)
    if errors:
        print(f"{path}: FAILED", file=sys.stderr)
        shown = errors[:20]
        for e in shown:
            print(f"  - {e}", file=sys.stderr)
        if len(errors) > len(shown):
            print(f"  - ... and {len(errors) - len(shown)} more", file=sys.stderr)
        return 1
    nspans = sum(len(t) for t in spans.values())
    print(
        f"{path}: OK ({len(events)} events, {nspans} spans on {len(spans)} tracks, "
        f"{len(asyncs)} async tracks, {terminal}/{requests} requests terminal)"
    )
    return 0


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    return max(check_file(p) for p in argv[1:])


if __name__ == "__main__":
    sys.exit(main(sys.argv))
