"""Kernel-vs-reference correctness: the CORE numeric signal for L1.

Every Pallas kernel must match the pure-jnp oracle in kernels/ref.py to
float32 tolerance, across shapes (hypothesis-driven), block sizes, and
padding conventions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import imc as imc_kernels
from compile.kernels import ref
from compile.kernels import thermal_step as tk

jax.config.update("jax_platform_name", "cpu")

SIZES = [8, 16, 64, 128, 256]


def rng(seed):
    return np.random.default_rng(seed)


def rand_system(n, seed=0):
    """A diagonally-dominant SPD-ish system like a real RC network."""
    r = rng(seed)
    g = r.uniform(0.0, 1.0, size=(n, n)).astype(np.float32)
    g = (g + g.T) / 2
    np.fill_diagonal(g, g.sum(axis=1) + 1.0)  # strictly diagonally dominant
    return jnp.asarray(g)


# ---------------------------------------------------------------------------
# matvec kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
def test_matvec_bias_matches_ref(n):
    r = rng(n)
    a = jnp.asarray(r.standard_normal((n, n), dtype=np.float32))
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    got = tk.matvec_bias(a, x, b)
    want = ref.matvec_bias_ref(a, x, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", SIZES)
def test_matvec_matches_ref(n):
    r = rng(n + 1)
    g = jnp.asarray(r.standard_normal((n, n), dtype=np.float32))
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    np.testing.assert_allclose(tk.matvec(g, x), g @ x, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", SIZES)
def test_dual_matvec_matches_ref(n):
    r = rng(n + 2)
    a = jnp.asarray(r.standard_normal((n, n), dtype=np.float32))
    bm = jnp.asarray(r.standard_normal((n, n), dtype=np.float32))
    t = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    p = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    got = tk.dual_matvec(a, bm, t, p)
    want = ref.thermal_step_ref(a, bm, t, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,br", [(64, 8), (64, 16), (64, 32), (64, 64), (128, 128)])
def test_matvec_block_size_invariance(n, br):
    """Result must not depend on the row-block tiling."""
    r = rng(7)
    a = jnp.asarray(r.standard_normal((n, n), dtype=np.float32))
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    got = tk.matvec_bias(a, x, b, block_rows=br)
    want = ref.matvec_bias_ref(a, x, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_matvec_bias_hypothesis(n, seed, scale):
    """Hypothesis sweep: random shapes/seeds/scales against the oracle."""
    r = rng(seed)
    a = jnp.asarray((r.standard_normal((n, n)) * scale).astype(np.float32))
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    got = tk.matvec_bias(a, x, b)
    want = ref.matvec_bias_ref(a, x, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale * n)


# ---------------------------------------------------------------------------
# IMC estimator kernel
# ---------------------------------------------------------------------------

IMC_PARAMS = jnp.asarray([65.0, 0.4, 2.0, 0.05, 200.0, 30.0], dtype=jnp.float32)


def rand_features(b, seed=0):
    r = rng(seed)
    f = np.zeros((b, 6), dtype=np.float32)
    f[:, 0] = r.uniform(1e3, 1e8, b)  # macs
    f[:, 1] = r.uniform(1e3, 2e6, b)  # weight bytes
    f[:, 2] = r.uniform(1e2, 1e6, b)  # in act bytes
    f[:, 3] = r.uniform(1e2, 1e6, b)  # out elems
    f[:, 4] = r.uniform(1, 512, b)
    f[:, 5] = r.uniform(1, 512, b)
    return jnp.asarray(f)


@pytest.mark.parametrize("b", [8, 64, 128])
def test_imc_estimate_matches_ref(b):
    f = rand_features(b, seed=b)
    got = imc_kernels.imc_estimate(f, IMC_PARAMS)
    want = ref.imc_estimate_ref(f, IMC_PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([4, 16, 32, 128]), seed=st.integers(0, 2**31 - 1))
def test_imc_estimate_hypothesis(b, seed):
    f = rand_features(b, seed=seed)
    got = imc_kernels.imc_estimate(f, IMC_PARAMS)
    want = ref.imc_estimate_ref(f, IMC_PARAMS)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_imc_outputs_positive_and_consistent():
    """latency/energy/power positive; power == energy/latency (unit check)."""
    f = rand_features(64, seed=42)
    out = np.asarray(imc_kernels.imc_estimate(f, IMC_PARAMS))
    lat, en, pw = out[:, 0], out[:, 1], out[:, 2]
    assert (lat > 0).all() and (en > 0).all() and (pw > 0).all()
    np.testing.assert_allclose(pw, en / lat * 1e3, rtol=1e-4)
