"""L2 graph correctness: transient scan, steady CG, imc batch, AOT lowering."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rc_system(n, seed=0, dt_us=1.0):
    """Build a physically-plausible RC system and its implicit-Euler matrices.

    Returns (G, C, A, Bm): G conductance [n,n] SPD, C capacitance diag [n],
    A = (I + dt C^-1 G)^-1, Bm = A dt C^-1. dt in seconds = dt_us * 1e-6.
    """
    r = np.random.default_rng(seed)
    # 1-D chain of thermal nodes with ambient tie at both ends.
    g_link = r.uniform(1e-3, 1e-2, n + 1)  # W/K
    g = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        if i > 0:
            g[i, i - 1] -= g_link[i]
            g[i, i] += g_link[i]
        if i < n - 1:
            g[i, i + 1] -= g_link[i + 1]
            g[i, i] += g_link[i + 1]
    g[0, 0] += g_link[0]  # ambient ties
    g[n - 1, n - 1] += g_link[n]
    c = r.uniform(1e-6, 1e-5, n)  # J/K
    dt = dt_us * 1e-6
    m = np.eye(n) + dt * (g / c[:, None])
    a = np.linalg.inv(m)
    bm = a @ np.diag(dt / c)
    return (
        jnp.asarray(g, jnp.float32),
        jnp.asarray(c, jnp.float32),
        jnp.asarray(a, jnp.float32),
        jnp.asarray(bm, jnp.float32),
    )


@pytest.mark.parametrize("n", [8, 64])
def test_transient_matches_ref(n):
    _, _, a, bm = rc_system(n)
    r = np.random.default_rng(1)
    t0 = jnp.zeros(n, jnp.float32)
    p = jnp.asarray(r.uniform(0, 2.0, (16, n)).astype(np.float32))
    traj, t_final = model.thermal_transient(a, bm, t0, p)
    want = ref.thermal_transient_ref(a, bm, t0, p)
    np.testing.assert_allclose(traj, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(t_final, want[-1], rtol=1e-4, atol=1e-4)


def test_transient_padding_convention():
    """Padded rows (A=I, Bm=0, P=0) must stay exactly at 0 delta-T."""
    n, npad = 8, 16
    _, _, a, bm = rc_system(n)
    a_p = np.eye(npad, dtype=np.float32)
    bm_p = np.zeros((npad, npad), dtype=np.float32)
    a_p[:n, :n] = np.asarray(a)
    bm_p[:n, :n] = np.asarray(bm)
    p = np.zeros((8, npad), dtype=np.float32)
    p[:, :n] = 1.0
    traj, _ = model.thermal_transient(
        jnp.asarray(a_p), jnp.asarray(bm_p), jnp.zeros(npad, jnp.float32), jnp.asarray(p)
    )
    traj = np.asarray(traj)
    assert np.all(traj[:, n:] == 0.0)
    assert np.all(traj[-1, :n] > 0.0)  # real nodes heated up


@pytest.mark.parametrize("n", [8, 64])
def test_steady_cg_converges_to_direct_solve(n):
    g, _, _, _ = rc_system(n, seed=3)
    r = np.random.default_rng(4)
    p = jnp.asarray(r.uniform(0, 1.0, n).astype(np.float32))
    t = jnp.zeros(n, jnp.float32)
    for _ in range(8):  # up to 8 dispatches x CG_ITERS
        t, rs = model.thermal_steady(g, p, t)
        if float(rs) < 1e-10:
            break
    want = np.linalg.solve(np.asarray(g, np.float64), np.asarray(p, np.float64))
    np.testing.assert_allclose(np.asarray(t), want, rtol=1e-3, atol=1e-3)


def test_steady_matches_cg_ref():
    n = 32
    g, _, _, _ = rc_system(n, seed=5)
    p = jnp.asarray(np.random.default_rng(6).uniform(0, 1, n).astype(np.float32))
    t, _ = model.thermal_steady(g, p, jnp.zeros(n, jnp.float32))
    want = ref.cg_solve_ref(g, p, model.CG_ITERS)
    np.testing.assert_allclose(t, want, rtol=1e-3, atol=1e-4)


def test_imc_batch_wrapper():
    from .test_kernel import IMC_PARAMS, rand_features

    f = rand_features(model.IMC_BATCH, seed=9)
    (out,) = model.imc_batch(f, IMC_PARAMS)
    want = ref.imc_estimate_ref(f, IMC_PARAMS)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# AOT lowering sanity (text parses as HLO; entries complete)
# ---------------------------------------------------------------------------


def test_aot_entries_cover_all_sizes():
    names = [name for name, _, _ in model.aot_entries()]
    for n in model.THERMAL_SIZES:
        assert f"thermal_transient_n{n}" in names
        assert f"thermal_steady_n{n}" in names
    assert any(n.startswith("imc_batch") for n in names)


def test_aot_lowering_smallest_variant_produces_hlo_text():
    name, fn, args = model.aot_entries()[0]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: root must be a tuple
    assert "tuple(" in text or "ROOT" in text
