# Makes tests/ a package so pytest imports modules as tests.<name> and
# the relative import in test_model.py (`from .test_kernel import ...`)
# resolves.  Run from python/: `python -m pytest tests -q`.
