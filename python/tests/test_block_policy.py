"""Block-policy and performance-structure tests for the Pallas kernels.

The §Perf pass fixed the row-block policy to full-row blocks for the AOT
sizes (EXPERIMENTS.md §Perf); these tests pin that policy and its
correctness so a refactor cannot silently reintroduce the 27x interpret
overhead or break divisibility assumptions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import imc as imc_kernels
from compile.kernels import ref
from compile.kernels import thermal_step as tk

jax.config.update("jax_platform_name", "cpu")


def test_full_row_block_for_aot_sizes():
    for n in model.THERMAL_SIZES:
        assert tk._pick_block(n) == n, f"AOT size {n} must use a full-row block"


def test_large_sizes_fall_back_to_stripes():
    assert tk._pick_block(2048) == 128
    assert tk._pick_block(1920) == 128
    # Odd sizes degrade gracefully.
    assert tk._pick_block(3 * 1024) == 128


def test_imc_full_batch_block():
    assert imc_kernels._pick_block(model.IMC_BATCH) == model.IMC_BATCH


@pytest.mark.parametrize("n", [640])
def test_full_block_matches_striped_block(n):
    """The §Perf block change must be bit-compatible in float tolerance."""
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((n, n), dtype=np.float32) * 0.01)
    bm = jnp.asarray(r.standard_normal((n, n), dtype=np.float32) * 0.01)
    t = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    p = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    full = tk.dual_matvec(a, bm, t, p, block_rows=n)
    striped = tk.dual_matvec(a, bm, t, p, block_rows=128)
    want = ref.thermal_step_ref(a, bm, t, p)
    np.testing.assert_allclose(full, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(striped, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([64, 256]), seed=st.integers(0, 2**31 - 1))
def test_transient_scan_full_block_hypothesis(n, seed):
    """The composed scan (as AOT-lowered) stays equal to the python ref."""
    r = np.random.default_rng(seed)
    a = jnp.asarray((np.eye(n) * 0.9 + r.standard_normal((n, n)) * 1e-3).astype(np.float32))
    bm = jnp.asarray((r.standard_normal((n, n)) * 1e-3).astype(np.float32))
    t0 = jnp.zeros(n, jnp.float32)
    p = jnp.asarray(r.uniform(0, 1, (8, n)).astype(np.float32))
    traj, t_final = model.thermal_transient(a, bm, t0, p)
    want = ref.thermal_transient_ref(a, bm, t0, p)
    np.testing.assert_allclose(traj, want, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(t_final, want[-1], rtol=3e-4, atol=3e-4)


def test_non_divisible_block_asserts():
    r = np.random.default_rng(1)
    a = jnp.asarray(r.standard_normal((6, 6), dtype=np.float32))
    x = jnp.asarray(r.standard_normal(6, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(6, dtype=np.float32))
    with pytest.raises(AssertionError):
        tk.matvec_bias(a, x, b, block_rows=4)
