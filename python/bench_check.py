#!/usr/bin/env python3
"""Guard hot-path throughput metrics against perf regressions.

Usage: bench_check.py <fresh_dir> <baseline_dir> [--factor 1.5] [--enforce-measured]
       bench_check.py <fresh_dir> <baseline_dir> --ratchet [--dry-run]

Before gating, every run prints the full baseline-vs-fresh delta table:
one row per (artifact, metric) across the union of both sides — timing
quantiles, throughput metrics, and the per-subsystem wall-clock shares
(`share_*`) the self-profiler attaches when armed.  A regression is
thereby attributable at a glance: `fleet_requests_per_s` down 30% with
`share_flit_engine` up 25% points at the flit engine, not the
dispatcher.

Each entry in CHECKS pairs a glob of `BENCH_*.json` artifacts produced by
`cargo bench --bench perf_hotpaths` (written into <fresh_dir> via
CHIPSIM_BENCH_JSON) with the throughput metric it enforces:

  - `BENCH_noc_flit*.json`  -> `flit_hops_per_s`   (flit-level NoI engine)
  - `BENCH_fleet*.json`     -> `fleet_requests_per_s` (fleet serving loop)

ADVISORY pairs work the same way but never fail the gate — today that is
`speedup` on `BENCH_noc_flit_parallel*.json` (parallel-vs-sequential
wall-clock ratio, which depends on the runner's core count).  Advisory
floors still ratchet with --ratchet, so the committed number tracks
reality.

Every fresh artifact is compared against the committed baseline of the
same name in <baseline_dir> (the repo root).  Fails when a fresh result
drops more than `factor` times below its baseline.

The committed baselines double as the perf trajectory: rerunning the
bench without CHIPSIM_BENCH_JSON overwrites them in place, so each commit
records the numbers of its era.

With --enforce-measured the gate refuses to run against baselines still
stamped `"estimated": true` — an estimated baseline silently downgrades
the check to advisory, which is exactly the regression this flag exists
to prevent.  CI passes it, so the perf trajectory is actually enforced.
(A conservative committed floor without the stamp IS enforced: it only
carries a "note" explaining its provenance until the first ratchet.)

With --ratchet, instead of checking, the committed floors are rewritten
from the fresh artifact: download CI's `bench-json` artifact of a green
run, then `python3 python/bench_check.py <artifact_dir> . --ratchet` and
commit the result.  Every `BENCH_*.json` in the artifact (not just the
enforced cases) is copied over its committed twin, any `"estimated"`
stamp and provenance `"note"` are dropped, and `"measured": true` is set
— so the gate runs against real numbers from then on.  Add --dry-run to
preview exactly what would be rewritten (per-metric deltas against the
committed twins) without touching any file.
"""

import argparse
import glob
import json
import os
import sys

# (artifact glob, enforced metric) — one row per guarded hot path.
CHECKS = [
    ("BENCH_noc_flit*.json", "flit_hops_per_s"),
    ("BENCH_fleet*.json", "fleet_requests_per_s"),
]

# (artifact glob, advisory metric) — reported with the same floor math
# but never failing.  `speedup` depends on the runner's core count, so
# its floor stays advisory; --ratchet still rewrites it alongside the
# enforced metrics, so the committed trajectory is real.
ADVISORY = [
    ("BENCH_noc_flit_parallel*.json", "speedup"),
]


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def metric_of(doc, metric):
    return (doc.get("metrics") or {}).get(metric)


def fmt_val(v):
    return "-" if v is None else f"{v:.4g}"


def fmt_delta(base, fresh):
    if base is None or fresh is None:
        return "-"
    if base == 0:
        return "new" if fresh else "+0.0%"
    return f"{(fresh - base) / base * 100.0:+.1f}%"


def print_deltas(fresh_dir, baseline_dir):
    """Always-printed forensics: every metric of every artifact on either
    side, baseline vs fresh with % change — `share_*` subsystem shares
    included, so gate failures below are attributable."""
    names = sorted(
        {os.path.basename(p) for p in glob.glob(os.path.join(baseline_dir, "BENCH_*.json"))}
        | {os.path.basename(p) for p in glob.glob(os.path.join(fresh_dir, "BENCH_*.json"))}
    )
    rows = []
    for name in names:
        base_path = os.path.join(baseline_dir, name)
        fresh_path = os.path.join(fresh_dir, name)
        base = (load_doc(base_path).get("metrics") or {}) if os.path.exists(base_path) else {}
        fresh = (load_doc(fresh_path).get("metrics") or {}) if os.path.exists(fresh_path) else {}
        for key in sorted(set(base) | set(fresh)):
            b, f = base.get(key), fresh.get(key)
            rows.append((name, key, fmt_val(b), fmt_val(f), fmt_delta(b, f)))
    if not rows:
        print("delta table: no BENCH_*.json artifacts on either side")
        return
    headers = ("artifact", "metric", "baseline", "fresh", "delta")
    widths = [max(len(r[i]) for r in rows + [headers]) for i in range(len(headers))]
    print("baseline vs fresh (every metric, incl. subsystem wall-clock shares):")
    print("  " + "  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        print("  " + "  ".join(c.ljust(w) for c, w in zip(r, widths)))
    print()


def ratchet(fresh_dir, baseline_dir, dry_run=False):
    fresh = sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json")))
    if not fresh:
        print(f"ratchet: no BENCH_*.json in {fresh_dir} — nothing to adopt", file=sys.stderr)
        return 1
    for path in fresh:
        name = os.path.basename(path)
        doc = load_doc(path)
        doc.pop("estimated", None)
        doc.pop("note", None)
        doc["measured"] = True
        dest = os.path.join(baseline_dir, name)
        existed = os.path.exists(dest)
        metrics = doc.get("metrics") or {}
        old = (load_doc(dest).get("metrics") or {}) if existed else {}
        detail = "".join(
            f" {k}={fmt_val(v)} ({fmt_delta(old.get(k), v)})" if existed else f" {k}={v:.3g}"
            for k, v in sorted(metrics.items())
        )
        if dry_run:
            verb = "would ratchet" if existed else "would adopt (new baseline)"
            print(f"{name}: {verb}{detail}")
            continue
        with open(dest, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        verb = "ratcheted" if existed else "adopted (new baseline)"
        print(f"{name}: {verb}{detail}")
    if dry_run:
        print(
            f"ratchet dry-run OK ({len(fresh)} baseline(s) would be rewritten — "
            "rerun without --dry-run to apply)"
        )
    else:
        print(f"ratchet OK ({len(fresh)} baseline(s) rewritten — review and commit the diff)")
    return 0


def check_glob(pattern, metric, args, failures, advisory=False):
    """Compare every baseline matching `pattern`; returns cases checked.

    With advisory=True every problem is printed instead of failing —
    used for floors (like `speedup`) that depend on the runner."""
    problems = [] if advisory else failures
    baselines = sorted(glob.glob(os.path.join(args.baseline_dir, pattern)))
    if not baselines:
        problems.append(
            f"no {pattern} baselines found in {args.baseline_dir} — "
            f"the '{metric}' perf guard checked nothing"
        )
        checked = 0
    else:
        checked = 0
        for base_path in baselines:
            name = os.path.basename(base_path)
            base_doc = load_doc(base_path)
            base = metric_of(base_doc, metric)
            # A baseline stamped "estimated": true was never measured (the
            # bootstrap committed before a toolchain existed): report but do
            # not fail on it.  The first real `cargo bench` run rewrites the
            # file without the stamp, arming the gate.
            estimated = bool(base_doc.get("estimated"))
            if estimated and args.enforce_measured and not advisory:
                problems.append(
                    f"{name}: baseline is stamped 'estimated' — the gate would be advisory; "
                    "refresh it from a measured CI bench-json artifact"
                )
                continue
            if base is None:
                problems.append(f"{name}: baseline has no '{metric}' metric")
                continue
            fresh_path = os.path.join(args.fresh_dir, name)
            if not os.path.exists(fresh_path):
                problems.append(f"{name}: fresh result missing from {args.fresh_dir}")
                continue
            fresh = metric_of(load_doc(fresh_path), metric)
            if fresh is None:
                problems.append(f"{name}: fresh result has no '{metric}' metric")
                continue
            checked += 1
            ratio = fresh / base if base > 0 else float("inf")
            tag = ""
            if advisory:
                tag = " [advisory metric]"
            elif estimated:
                tag = " [estimated baseline, advisory]"
            print(f"{name}: baseline {base:.3g} fresh {fresh:.3g} {metric} ({ratio:.2f}x){tag}")
            if fresh < base / args.factor:
                msg = (
                    f"{name}: {metric} regressed more than {args.factor}x below baseline "
                    f"({fresh:.3g} < {base:.3g} / {args.factor})"
                )
                if advisory or estimated:
                    why = "metric is advisory" if advisory else "baseline is estimated"
                    print(f"ADVISORY (not failing, {why}): {msg}")
                else:
                    problems.append(msg)
    if advisory:
        for msg in problems:
            print(f"ADVISORY (not failing): {msg}")
    return checked


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh_dir", help="directory with freshly generated BENCH_*.json")
    ap.add_argument("baseline_dir", help="directory with committed baseline BENCH_*.json")
    ap.add_argument(
        "--factor",
        type=float,
        default=1.5,
        help="fail when fresh throughput < baseline / factor (default 1.5)",
    )
    ap.add_argument(
        "--enforce-measured",
        action="store_true",
        help="fail on baselines stamped 'estimated' instead of downgrading to advisory",
    )
    ap.add_argument(
        "--ratchet",
        action="store_true",
        help="rewrite the committed baselines in <baseline_dir> from the fresh "
        "artifact in <fresh_dir>, stamping them measured (then commit the diff)",
    )
    ap.add_argument(
        "--dry-run",
        action="store_true",
        help="with --ratchet: print what would be rewritten (per-metric deltas "
        "against the committed baselines) without writing anything",
    )
    args = ap.parse_args()

    if args.dry_run and not args.ratchet:
        ap.error("--dry-run only applies to --ratchet")
    if args.ratchet:
        return ratchet(args.fresh_dir, args.baseline_dir, dry_run=args.dry_run)

    print_deltas(args.fresh_dir, args.baseline_dir)
    failures = []
    checked = 0
    for pattern, metric in CHECKS:
        checked += check_glob(pattern, metric, args, failures)
    for pattern, metric in ADVISORY:
        check_glob(pattern, metric, args, failures, advisory=True)

    if failures:
        print("\nbench_check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"bench_check OK ({checked} case(s) within {args.factor}x of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
