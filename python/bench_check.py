#!/usr/bin/env python3
"""Guard the NoC flit-engine throughput against perf regressions.

Usage: bench_check.py <fresh_dir> <baseline_dir> [--factor 1.5] [--enforce-measured]
       bench_check.py <fresh_dir> <baseline_dir> --ratchet

Compares the `flit_hops_per_s` metric of every `BENCH_noc_flit*.json`
artifact produced by `cargo bench --bench perf_hotpaths` (written into
<fresh_dir> via CHIPSIM_BENCH_JSON) against the committed baseline of the
same name in <baseline_dir> (the repo root).  Fails when a fresh result
drops more than `factor` times below its baseline.

The committed baselines double as the perf trajectory: rerunning the
bench without CHIPSIM_BENCH_JSON overwrites them in place, so each commit
records the numbers of its era.

With --enforce-measured the gate refuses to run against baselines still
stamped `"estimated": true` — an estimated baseline silently downgrades
the check to advisory, which is exactly the regression this flag exists
to prevent.  CI passes it, so the perf trajectory is actually enforced.

With --ratchet, instead of checking, the committed floors are rewritten
from the fresh artifact: download CI's `bench-json` artifact of a green
run, then `python3 python/bench_check.py <artifact_dir> . --ratchet` and
commit the result.  Every `BENCH_*.json` in the artifact (not just the
flit cases) is copied over its committal twin, any `"estimated"` stamp is
dropped, and `"measured": true` is set — which arms the gate for metrics
the glob enforces and records a real baseline for the ones it does not
(e.g. the fleet-serving case) so a later glob widening starts from
measured numbers.
"""

import argparse
import glob
import json
import os
import sys

METRIC = "flit_hops_per_s"


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def metric_of(doc):
    return (doc.get("metrics") or {}).get(METRIC)


def ratchet(fresh_dir, baseline_dir):
    fresh = sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json")))
    if not fresh:
        print(f"ratchet: no BENCH_*.json in {fresh_dir} — nothing to adopt", file=sys.stderr)
        return 1
    for path in fresh:
        name = os.path.basename(path)
        doc = load_doc(path)
        doc.pop("estimated", None)
        doc.pop("note", None)
        doc["measured"] = True
        dest = os.path.join(baseline_dir, name)
        existed = os.path.exists(dest)
        with open(dest, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        verb = "ratcheted" if existed else "adopted (new baseline)"
        m = metric_of(doc)
        detail = f" {METRIC}={m:.3g}" if m is not None else ""
        print(f"{name}: {verb}{detail}")
    print(f"ratchet OK ({len(fresh)} baseline(s) rewritten — review and commit the diff)")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh_dir", help="directory with freshly generated BENCH_*.json")
    ap.add_argument("baseline_dir", help="directory with committed baseline BENCH_*.json")
    ap.add_argument(
        "--factor",
        type=float,
        default=1.5,
        help="fail when fresh throughput < baseline / factor (default 1.5)",
    )
    ap.add_argument(
        "--enforce-measured",
        action="store_true",
        help="fail on baselines stamped 'estimated' instead of downgrading to advisory",
    )
    ap.add_argument(
        "--ratchet",
        action="store_true",
        help="rewrite the committed baselines in <baseline_dir> from the fresh "
        "artifact in <fresh_dir>, stamping them measured (then commit the diff)",
    )
    args = ap.parse_args()

    if args.ratchet:
        return ratchet(args.fresh_dir, args.baseline_dir)

    baselines = sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_noc_flit*.json")))
    failures = []
    checked = 0
    for base_path in baselines:
        name = os.path.basename(base_path)
        base_doc = load_doc(base_path)
        base = metric_of(base_doc)
        # A baseline stamped "estimated": true was never measured (the
        # bootstrap committed before a toolchain existed): report but do
        # not fail on it.  The first real `cargo bench` run rewrites the
        # file without the stamp, arming the gate.
        estimated = bool(base_doc.get("estimated"))
        if estimated and args.enforce_measured:
            failures.append(
                f"{name}: baseline is stamped 'estimated' — the gate would be advisory; "
                "refresh it from a measured CI bench-json artifact"
            )
            continue
        if base is None:
            failures.append(f"{name}: baseline has no '{METRIC}' metric")
            continue
        fresh_path = os.path.join(args.fresh_dir, name)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh result missing from {args.fresh_dir}")
            continue
        fresh = metric_of(load_doc(fresh_path))
        if fresh is None:
            failures.append(f"{name}: fresh result has no '{METRIC}' metric")
            continue
        checked += 1
        ratio = fresh / base if base > 0 else float("inf")
        tag = " [estimated baseline, advisory]" if estimated else ""
        print(f"{name}: baseline {base:.3g} fresh {fresh:.3g} flit-hops/s ({ratio:.2f}x){tag}")
        if fresh < base / args.factor:
            msg = (
                f"{name}: {METRIC} regressed more than {args.factor}x below baseline "
                f"({fresh:.3g} < {base:.3g} / {args.factor})"
            )
            if estimated:
                print(f"ADVISORY (not failing, baseline is estimated): {msg}")
            else:
                failures.append(msg)

    if not baselines:
        failures.append(
            f"no BENCH_noc_flit*.json baselines found in {args.baseline_dir} — "
            "the flit perf guard checked nothing"
        )
    if failures:
        print("\nbench_check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"bench_check OK ({checked} flit case(s) within {args.factor}x of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
