#!/usr/bin/env python3
"""Guard the NoC flit-engine throughput against perf regressions.

Usage: bench_check.py <fresh_dir> <baseline_dir> [--factor 1.5] [--enforce-measured]

Compares the `flit_hops_per_s` metric of every `BENCH_noc_flit*.json`
artifact produced by `cargo bench --bench perf_hotpaths` (written into
<fresh_dir> via CHIPSIM_BENCH_JSON) against the committed baseline of the
same name in <baseline_dir> (the repo root).  Fails when a fresh result
drops more than `factor` times below its baseline.

The committed baselines double as the perf trajectory: rerunning the
bench without CHIPSIM_BENCH_JSON overwrites them in place, so each commit
records the numbers of its era.

With --enforce-measured the gate refuses to run against baselines still
stamped `"estimated": true` — an estimated baseline silently downgrades
the check to advisory, which is exactly the regression this flag exists
to prevent.  CI passes it, so the perf trajectory is actually enforced.
"""

import argparse
import glob
import json
import os
import sys

METRIC = "flit_hops_per_s"


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def metric_of(doc):
    return (doc.get("metrics") or {}).get(METRIC)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh_dir", help="directory with freshly generated BENCH_*.json")
    ap.add_argument("baseline_dir", help="directory with committed baseline BENCH_*.json")
    ap.add_argument(
        "--factor",
        type=float,
        default=1.5,
        help="fail when fresh throughput < baseline / factor (default 1.5)",
    )
    ap.add_argument(
        "--enforce-measured",
        action="store_true",
        help="fail on baselines stamped 'estimated' instead of downgrading to advisory",
    )
    args = ap.parse_args()

    baselines = sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_noc_flit*.json")))
    failures = []
    checked = 0
    for base_path in baselines:
        name = os.path.basename(base_path)
        base_doc = load_doc(base_path)
        base = metric_of(base_doc)
        # A baseline stamped "estimated": true was never measured (the
        # bootstrap committed before a toolchain existed): report but do
        # not fail on it.  The first real `cargo bench` run rewrites the
        # file without the stamp, arming the gate.
        estimated = bool(base_doc.get("estimated"))
        if estimated and args.enforce_measured:
            failures.append(
                f"{name}: baseline is stamped 'estimated' — the gate would be advisory; "
                "refresh it from a measured CI bench-json artifact"
            )
            continue
        if base is None:
            failures.append(f"{name}: baseline has no '{METRIC}' metric")
            continue
        fresh_path = os.path.join(args.fresh_dir, name)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh result missing from {args.fresh_dir}")
            continue
        fresh = metric_of(load_doc(fresh_path))
        if fresh is None:
            failures.append(f"{name}: fresh result has no '{METRIC}' metric")
            continue
        checked += 1
        ratio = fresh / base if base > 0 else float("inf")
        tag = " [estimated baseline, advisory]" if estimated else ""
        print(f"{name}: baseline {base:.3g} fresh {fresh:.3g} flit-hops/s ({ratio:.2f}x){tag}")
        if fresh < base / args.factor:
            msg = (
                f"{name}: {METRIC} regressed more than {args.factor}x below baseline "
                f"({fresh:.3g} < {base:.3g} / {args.factor})"
            )
            if estimated:
                print(f"ADVISORY (not failing, baseline is estimated): {msg}")
            else:
                failures.append(msg)

    if not baselines:
        failures.append(
            f"no BENCH_noc_flit*.json baselines found in {args.baseline_dir} — "
            "the flit perf guard checked nothing"
        )
    if failures:
        print("\nbench_check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"bench_check OK ({checked} flit case(s) within {args.factor}x of baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
