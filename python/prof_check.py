#!/usr/bin/env python3
"""Validate a chipsim self-profile (`chipsim-profile-v1`) document.

Usage: prof_check.py <profile.json> [<more.json> ...]

Structural checks (stdlib only):

  - the document is a JSON object with `schema == "chipsim-profile-v1"`
    and a positive integer `wall_ns` (`cpu_ns` non-negative);
  - every subsystem row has a non-empty name, `self_ns <= total_ns`,
    positive `calls`, and a `share` in [0, 1]; self-time shares sum
    to at most 1 (they are fractions of the scoped cpu time);
  - counters carry non-negative integer values and non-negative rates;
  - worker rows have a utilization in [0, 1];
  - paths nest consistently: `self_ns <= total_ns` per row, and the
    direct children of any stack sum to at most the parent's total —
    a child exceeding its parent means broken scope accounting;
  - collapsed lines are inferno-shaped (`frame;frame value`), rooted
    at `chipsim`, with frames drawn from the subsystem table.

CI generates profiles with `chipsim profile --scenario <preset>` and
runs this checker over them, so the exported document stays consumable
by flamegraph tooling and dashboards as the profiler evolves.
"""

import json
import sys

SCHEMA = "chipsim-profile-v1"
# Shares are computed from integer nanosecond sums; allow float slack.
EPS = 1e-9


def is_count(v):
    return isinstance(v, int) and not isinstance(v, bool) and v >= 0


def is_frac(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and -EPS <= v <= 1 + EPS


def check_subsystems(subs, errors):
    """Per-row sanity plus the global share budget; returns known frame names."""
    names = set()
    share_sum = 0.0
    for i, s in enumerate(subs):
        where = f"subsystems[{i}]"
        if not isinstance(s, dict):
            errors.append(f"{where}: not an object")
            continue
        name = s.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing or empty 'name'")
            continue
        names.add(name)
        if not (is_count(s.get("total_ns")) and is_count(s.get("self_ns"))):
            errors.append(f"{where} ({name}): total_ns/self_ns must be non-negative integers")
            continue
        if s["self_ns"] > s["total_ns"]:
            errors.append(f"{where} ({name}): self_ns {s['self_ns']} > total_ns {s['total_ns']}")
        if not (is_count(s.get("calls")) and s["calls"] > 0):
            errors.append(f"{where} ({name}): listed but 'calls' is not positive")
        if not is_frac(s.get("share")):
            errors.append(f"{where} ({name}): share {s.get('share')!r} outside [0, 1]")
        else:
            share_sum += s["share"]
    if share_sum > 1 + 1e-6:
        errors.append(f"subsystem self-time shares sum to {share_sum:.6f} > 1")
    return names


def check_paths(paths, errors):
    """Self <= total per stack, direct-children totals bounded by the parent."""
    totals = {}
    for i, p in enumerate(paths):
        where = f"paths[{i}]"
        if not isinstance(p, dict) or not isinstance(p.get("stack"), str) or not p["stack"]:
            errors.append(f"{where}: missing 'stack'")
            continue
        if not (is_count(p.get("total_ns")) and is_count(p.get("self_ns"))):
            errors.append(f"{where} ({p['stack']}): bad total_ns/self_ns")
            continue
        if p["self_ns"] > p["total_ns"]:
            errors.append(f"{where} ({p['stack']}): self_ns exceeds total_ns")
        totals[p["stack"]] = p["total_ns"]
    children = {}
    for stack, total in totals.items():
        if ";" in stack:
            parent = stack.rsplit(";", 1)[0]
            children[parent] = children.get(parent, 0) + total
    for parent, child_sum in sorted(children.items()):
        if parent in totals and child_sum > totals[parent]:
            errors.append(
                f"children of '{parent}' sum to {child_sum} > parent total {totals[parent]}"
            )


def check_collapsed(lines, frames, errors):
    for i, line in enumerate(lines):
        where = f"collapsed[{i}]"
        if not isinstance(line, str) or " " not in line:
            errors.append(f"{where}: not a 'stack value' line: {line!r}")
            continue
        stack, value = line.rsplit(" ", 1)
        if not value.isdigit():
            errors.append(f"{where}: value '{value}' is not an integer")
        parts = stack.split(";")
        if parts[0] != "chipsim":
            errors.append(f"{where}: stack not rooted at 'chipsim': {stack}")
        for frame in parts[1:]:
            if frame not in frames:
                errors.append(f"{where}: unknown frame '{frame}'")


def check_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: FAILED\n  - unreadable: {e}", file=sys.stderr)
        return 1
    errors = []
    if not isinstance(doc, dict):
        errors.append("document is not a JSON object")
        doc = {}
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    if not (is_count(doc.get("wall_ns")) and doc.get("wall_ns", 0) > 0):
        errors.append(f"wall_ns {doc.get('wall_ns')!r} must be a positive integer")
    if not is_count(doc.get("cpu_ns")):
        errors.append(f"cpu_ns {doc.get('cpu_ns')!r} must be a non-negative integer")
    subs = doc.get("subsystems")
    if not isinstance(subs, list) or not subs:
        errors.append("'subsystems' must be a non-empty array — the profiler scoped nothing")
        subs = []
    frames = check_subsystems(subs, errors)
    counters = doc.get("counters")
    if not isinstance(counters, list):
        errors.append("'counters' must be an array")
        counters = []
    for i, c in enumerate(counters):
        if not isinstance(c, dict) or not isinstance(c.get("name"), str):
            errors.append(f"counters[{i}]: missing 'name'")
        elif not is_count(c.get("value")):
            errors.append(f"counters[{i}] ({c['name']}): bad 'value'")
        elif not (isinstance(c.get("per_s"), (int, float)) and c["per_s"] >= 0):
            errors.append(f"counters[{i}] ({c['name']}): bad 'per_s'")
    workers = doc.get("workers")
    if not isinstance(workers, list):
        errors.append("'workers' must be an array")
        workers = []
    for i, w in enumerate(workers):
        if not isinstance(w, dict) or not isinstance(w.get("name"), str):
            errors.append(f"workers[{i}]: missing 'name'")
        elif not is_count(w.get("busy_ns")) or not is_frac(w.get("util")):
            errors.append(f"workers[{i}] ({w['name']}): bad busy_ns/util")
    paths = doc.get("paths")
    if not isinstance(paths, list):
        errors.append("'paths' must be an array")
        paths = []
    check_paths(paths, errors)
    collapsed = doc.get("collapsed")
    if not isinstance(collapsed, list):
        errors.append("'collapsed' must be an array")
        collapsed = []
    check_collapsed(collapsed, frames, errors)
    if errors:
        print(f"{path}: FAILED", file=sys.stderr)
        shown = errors[:20]
        for e in shown:
            print(f"  - {e}", file=sys.stderr)
        if len(errors) > len(shown):
            print(f"  - ... and {len(errors) - len(shown)} more", file=sys.stderr)
        return 1
    print(
        f"{path}: OK ({len(subs)} subsystems, {len(counters)} counters, "
        f"{len(workers)} workers, {len(paths)} paths, {len(collapsed)} collapsed lines)"
    )
    return 0


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    return max(check_file(p) for p in argv[1:])


if __name__ == "__main__":
    sys.exit(main(sys.argv))
