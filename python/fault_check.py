#!/usr/bin/env python3
"""Validate a chipsim fault report (`chipsim-fault-v1`) document.

Usage: fault_check.py <fault.json> [<more.json> ...]

Structural checks (stdlib only):

  - the document is a JSON object with `schema == "chipsim-fault-v1"`
    and non-negative integer counters;
  - `availability` is a float in [0, 1];
  - the executed timeline is monotone in `at_ns`, every entry names a
    known fault kind, and a repair (`up == true`) is only legal after a
    failure of the same (kind, target) — a dangling repair means the
    toggle bookkeeping lost a failure;
  - `injected` equals the number of failure entries and `repairs` the
    number of repair entries (the counters and the timeline are two
    views of the same executed schedule);
  - `recovered <= retries`: a request cannot complete via retry without
    a retry dispatch, and `recovered <= aborts` — only aborted work can
    recover.

CI runs a fault preset with `--faults`/`--faults-out` and gates the
emitted JSON with this checker, so the report stays consumable by
dashboards as the fault subsystem evolves.
"""

import json
import sys

SCHEMA = "chipsim-fault-v1"
KINDS = {"link", "router", "chiplet", "sensor", "board"}
COUNTERS = [
    "injected",
    "repairs",
    "reroutes",
    "flow_fails",
    "aborts",
    "retries",
    "recovered",
    "fault_dropped",
    "sensor_faults",
    "goodput_under_fault",
]


def is_count(v):
    return isinstance(v, int) and not isinstance(v, bool) and v >= 0


def check_timeline(timeline, errors):
    """Monotonicity, known kinds, and fail-before-repair pairing."""
    downs = set()
    prev = -1
    fails = repairs = 0
    for i, e in enumerate(timeline):
        where = f"timeline[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        at, kind, target, up = e.get("at_ns"), e.get("kind"), e.get("target"), e.get("up")
        if not is_count(at):
            errors.append(f"{where}: 'at_ns' must be a non-negative integer")
            continue
        if at < prev:
            errors.append(f"{where}: at_ns {at} < previous {prev} (timeline not monotone)")
        prev = at
        if kind not in KINDS:
            errors.append(f"{where}: unknown kind {kind!r}")
            continue
        if not is_count(target):
            errors.append(f"{where}: 'target' must be a non-negative integer")
            continue
        if not isinstance(up, bool):
            errors.append(f"{where}: 'up' must be a boolean")
            continue
        if up:
            repairs += 1
            if (kind, target) not in downs:
                errors.append(f"{where}: repair of {kind} {target} with no prior failure")
            else:
                downs.discard((kind, target))
        else:
            fails += 1
            downs.add((kind, target))
    return fails, repairs


def check(path):
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    for k in COUNTERS:
        if not is_count(doc.get(k)):
            errors.append(f"'{k}' must be a non-negative integer, got {doc.get(k)!r}")
    avail = doc.get("availability")
    if not isinstance(avail, (int, float)) or isinstance(avail, bool):
        errors.append(f"'availability' must be a number, got {avail!r}")
    elif not 0.0 <= avail <= 1.0:
        errors.append(f"availability {avail} outside [0, 1]")
    timeline = doc.get("timeline")
    if not isinstance(timeline, list):
        errors.append("'timeline' must be an array")
        return errors
    fails, repairs = check_timeline(timeline, errors)
    if is_count(doc.get("injected")) and doc["injected"] != fails:
        errors.append(f"injected {doc['injected']} != {fails} timeline failure entries")
    if is_count(doc.get("repairs")) and doc["repairs"] != repairs:
        errors.append(f"repairs {doc['repairs']} != {repairs} timeline repair entries")
    if is_count(doc.get("recovered")) and is_count(doc.get("retries")):
        if doc["recovered"] > doc["retries"]:
            errors.append(f"recovered {doc['recovered']} > retries {doc['retries']}")
    if is_count(doc.get("recovered")) and is_count(doc.get("aborts")):
        if doc["recovered"] > doc["aborts"]:
            errors.append(f"recovered {doc['recovered']} > aborts {doc['aborts']}")
    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    failed = False
    for path in argv[1:]:
        errors = check(path)
        if errors:
            failed = True
            print(f"FAIL {path}")
            for e in errors:
                print(f"  - {e}")
        else:
            print(f"ok   {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
