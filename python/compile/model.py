"""L2 JAX compute graphs for CHIPSIM's analysis pipeline.

Three graphs are AOT-lowered to HLO text (see aot.py) and executed from the
Rust coordinator via PJRT:

  thermal_transient : scan of fused implicit-Euler steps over a chunk of
                      power bins.  Rust precomputes A = (I + dt C^-1 G)^-1
                      and Bm = A dt C^-1 once per physical configuration
                      (dense LU inverse in rust/src/util/linalg.rs), then
                      streams [S, N] power chunks, carrying T between
                      dispatches.  Implicit Euler is unconditionally stable,
                      so one step per 1 us power bin regardless of the RC
                      time constants.
  thermal_steady    : fixed-iteration conjugate gradient solve of G T = P
                      (G is SPD: conductance matrix with ambient ties).
  imc_batch         : batched IMC latency/energy/power estimator (the
                      CiMLoop-analog backend as an artifact).

Shapes are static per artifact variant; Rust zero-pads to the next variant.
Padding convention for thermal: padded rows of A are identity, of Bm zero,
padded P entries zero -> padded temperatures stay exactly 0 (ambient delta).
For steady: padded G rows/cols are identity diag, padded P zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import imc as imc_kernels
from .kernels import thermal_step as tk

# Timesteps per transient dispatch (power bins per chunk).
TRANSIENT_CHUNK = 256
# CG iterations per steady-state dispatch (caller re-dispatches if the
# returned residual is above tolerance, warm-starting from t).
CG_ITERS = 64
# Batch size per IMC estimator dispatch.
IMC_BATCH = 128

# Node-count variants for which thermal artifacts are emitted.  640 covers
# the paper's 10x10-chiplet system (400 active + 100 interposer + 100
# spreader + 40 boundary slack); 64/256 cover the small configs used by
# tests and examples; 1024 is headroom for larger DSE grids.
THERMAL_SIZES = (64, 256, 640, 1024)


def thermal_transient(
    a: jnp.ndarray, bm: jnp.ndarray, t0: jnp.ndarray, p_seq: jnp.ndarray
):
    """Scan the fused thermal step over a [S, N] power chunk.

    Returns (traj [S, N], t_final [N]).  traj[k] is the temperature at the
    *end* of power bin k.
    """

    def step(t, p):
        t_next = tk.dual_matvec(a, bm, t, p)
        return t_next, t_next

    t_final, traj = jax.lax.scan(step, t0, p_seq)
    return traj, t_final


def thermal_steady(g: jnp.ndarray, p: jnp.ndarray, t0: jnp.ndarray):
    """CG_ITERS conjugate-gradient iterations on G t = p from warm start t0.

    Returns (t [N], rs [scalar residual norm^2]).  The Rust caller loops
    dispatches until rs < tol, feeding t back in as t0.
    """
    eps = jnp.asarray(1e-30, dtype=p.dtype)
    r0 = p - tk.matvec(g, t0)
    rs0 = r0 @ r0

    def iter_fn(carry, _):
        t, r, d, rs = carry
        gd = tk.matvec(g, d)
        alpha = rs / jnp.maximum(d @ gd, eps)
        t = t + alpha * d
        r = r - alpha * gd
        rs_new = r @ r
        beta = rs_new / jnp.maximum(rs, eps)
        d = r + beta * d
        return (t, r, d, rs_new), None

    (t, _r, _d, rs), _ = jax.lax.scan(
        iter_fn, (t0, r0, r0, rs0), None, length=CG_ITERS
    )
    return t, rs


def imc_batch(features: jnp.ndarray, params: jnp.ndarray):
    """Batched IMC estimate: features [B,6], params [6] -> [B,3]."""
    return (imc_kernels.imc_estimate(features, params),)


# ---------------------------------------------------------------------------
# AOT entry points: (name, fn, example_args) triples consumed by aot.py.
# Every fn must return a tuple (return_tuple=True lowering).
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def aot_entries():
    entries = []
    for n in THERMAL_SIZES:
        entries.append(
            (
                f"thermal_transient_n{n}",
                lambda a, bm, t0, p: thermal_transient(a, bm, t0, p),
                (_f32(n, n), _f32(n, n), _f32(n), _f32(TRANSIENT_CHUNK, n)),
            )
        )
        entries.append(
            (
                f"thermal_steady_n{n}",
                lambda g, p, t0: thermal_steady(g, p, t0),
                (_f32(n, n), _f32(n), _f32(n)),
            )
        )
    entries.append(
        (
            f"imc_batch_b{IMC_BATCH}",
            lambda f, q: imc_batch(f, q),
            (_f32(IMC_BATCH, 6), _f32(6)),
        )
    )
    return entries
