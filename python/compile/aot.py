"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, under --out-dir (default ../artifacts relative to this file):

  <name>.hlo.txt    one per aot_entries() variant
  manifest.json     name -> {file, inputs: [{shape, dtype}], num_outputs,
                             constants of interest (chunk sizes, cg iters)}

The Rust runtime (rust/src/runtime/) consumes manifest.json; keep the
schema in sync with runtime::manifest.

Python runs ONCE at build time (`make artifacts`); nothing here is on the
request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text/return-tuple",
        "constants": {
            "transient_chunk": model.TRANSIENT_CHUNK,
            "cg_iters": model.CG_ITERS,
            "imc_batch": model.IMC_BATCH,
            "thermal_sizes": list(model.THERMAL_SIZES),
        },
        "entries": {},
    }
    for name, fn, example_args in model.aot_entries():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        num_outputs = len(jax.tree_util.tree_leaves(out_avals))
        manifest["entries"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example_args
            ],
            "num_outputs": num_outputs,
        }
        print(f"  {name}: {len(text)} chars, {num_outputs} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    default_out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    ap.add_argument("--out-dir", default=os.path.normpath(default_out))
    ap.add_argument(
        "--out",
        default=None,
        help="legacy single-file alias; its directory is used as --out-dir",
    )
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    print(f"AOT-lowering to {out_dir}")
    build_all(out_dir)
    # Legacy Makefile stamp target.
    if args.out:
        with open(args.out, "w") as f:
            f.write("# see manifest.json; artifacts are per-entry .hlo.txt files\n")
    print("done")


if __name__ == "__main__":
    main()
