"""L1 Pallas kernel for the batched IMC analytical estimator.

This is the CiMLoop-analog compute backend served as an AOT artifact: the
Rust coordinator batches layer-segment feature rows and gets back
(latency_ns, energy_pj, avg_power_mw) per row, computed exactly like
``ref.imc_estimate_ref``.

The kernel is purely element-wise over the batch dimension, so the grid
tiles rows; each grid step processes a (BB, 6) feature tile entirely in
VMEM.  Feature/parameter/output layouts are documented in ref.py and
mirrored by rust/src/compute/pjrt.rs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(b: int) -> int:
    """Full-batch block for the AOT size (see thermal_step._pick_block —
    same §Perf rationale; the whole (128, 6) feature tile is tiny)."""
    if b <= 1024:
        return b
    for bb in (128, 64, 32, 16, 8, 4, 2, 1):
        if b % bb == 0:
            return bb
    return 1


def _imc_kernel(f_ref, q_ref, o_ref):
    f = f_ref[...]  # (BB, 6)
    q = q_ref[...]  # (6,)
    macs = f[:, 0]
    out_elems = f[:, 3]
    t_mac = macs / jnp.maximum(q[0], 1e-9)
    t_adc = out_elems * q[3]
    latency = q[4] + jnp.maximum(t_mac, t_adc)
    e_dyn = macs * q[1] + out_elems * q[2]
    e_leak = q[5] * latency * 1e-3
    energy = e_dyn + e_leak
    power = energy / jnp.maximum(latency, 1e-9) * 1e3
    o_ref[...] = jnp.stack([latency, energy, power], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def imc_estimate(
    features: jnp.ndarray, params: jnp.ndarray, block_rows: int | None = None
) -> jnp.ndarray:
    """Batched IMC estimate. features: [B,6] f32, params: [6] f32 -> [B,3]."""
    b, nf = features.shape
    assert nf == ref.IMC_NUM_FEATURES
    bb = block_rows or _pick_block(b)
    assert b % bb == 0, f"B={b} not divisible by block_rows={bb}"
    return pl.pallas_call(
        _imc_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, nf), lambda i: (i, 0)),
            pl.BlockSpec((ref.IMC_NUM_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, ref.IMC_NUM_OUTPUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ref.IMC_NUM_OUTPUTS), features.dtype),
        interpret=True,
    )(features, params)
