"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions are the *correctness contract*: pytest (and hypothesis)
assert that each Pallas kernel matches its reference to tight tolerances
across shapes and dtypes. They are also used by `model.py` docs/tests to
sanity-check the composed L2 graphs.

Nothing in this file is ever lowered into the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec_bias_ref(a: jnp.ndarray, x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x + b  (the thermal step primitive)."""
    return a @ x + b


def thermal_step_ref(
    a: jnp.ndarray, bm: jnp.ndarray, t: jnp.ndarray, p: jnp.ndarray
) -> jnp.ndarray:
    """One implicit-Euler thermal step: T' = A @ T + Bm @ P.

    A  = (I + dt C^-1 G)^-1           (precomputed by the Rust caller)
    Bm = (I + dt C^-1 G)^-1 dt C^-1   (ditto)
    """
    return a @ t + bm @ p


def thermal_transient_ref(
    a: jnp.ndarray, bm: jnp.ndarray, t0: jnp.ndarray, p_seq: jnp.ndarray
) -> jnp.ndarray:
    """Reference transient solve: scan thermal_step_ref over p_seq rows.

    Returns the [S, N] trajectory (temperature *after* each power bin).
    """
    traj = []
    t = t0
    for k in range(p_seq.shape[0]):
        t = thermal_step_ref(a, bm, t, p_seq[k])
        traj.append(t)
    return jnp.stack(traj)


def cg_solve_ref(g: jnp.ndarray, p: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Fixed-iteration conjugate gradient for SPD G: solve G t = p.

    Matches the L2 `thermal_steady` graph step-for-step (same update
    order, same epsilon guard) so numerics agree to float tolerance.
    """
    n = p.shape[0]
    t = jnp.zeros((n,), dtype=p.dtype)
    r = p - g @ t
    d = r
    rs = r @ r
    eps = jnp.asarray(1e-30, dtype=p.dtype)
    for _ in range(iters):
        gd = g @ d
        alpha = rs / jnp.maximum(d @ gd, eps)
        t = t + alpha * d
        r = r - alpha * gd
        rs_new = r @ r
        beta = rs_new / jnp.maximum(rs, eps)
        d = r + beta * d
        rs = rs_new
    return t


# ---------------------------------------------------------------------------
# IMC analytical estimator (the CiMLoop-analog compute backend, batched).
#
# Feature layout per layer-segment row (see rust/src/compute/pjrt.rs, which
# must stay in sync):
#   f[0] = macs                (multiply-accumulates in the segment)
#   f[1] = weight_bytes        (stationary weights mapped to the crossbars)
#   f[2] = in_act_bytes        (input activations streamed in)
#   f[3] = out_act_elems       (output activations -> ADC conversions)
#   f[4] = rows_used           (crossbar rows activated)
#   f[5] = cols_used           (crossbar cols activated)
#
# Parameter layout (one row per chiplet type):
#   q[0] = mac_rate_gops       (sustained GOPS for MAC array == ops/ns)
#   q[1] = e_mac_pj            (energy per MAC, pJ)
#   q[2] = e_adc_pj            (energy per output-element ADC conversion, pJ)
#   q[3] = t_adc_ns_per_elem   (ADC serialization, ns per output element)
#   q[4] = base_latency_ns     (fixed per-segment issue overhead)
#   q[5] = leak_mw             (static power while active, mW)
# Outputs per row: [latency_ns, energy_pj, avg_power_mw]
# ---------------------------------------------------------------------------

IMC_NUM_FEATURES = 6
IMC_NUM_PARAMS = 6
IMC_NUM_OUTPUTS = 3


def imc_estimate_ref(features: jnp.ndarray, params: jnp.ndarray) -> jnp.ndarray:
    """Batched IMC latency/energy/power estimate. features: [B,6] params: [6]."""
    macs = features[:, 0]
    out_elems = features[:, 3]
    mac_rate = params[0]  # GOPS == ops/ns
    t_mac = macs / jnp.maximum(mac_rate, 1e-9)
    t_adc = out_elems * params[3]
    latency = params[4] + jnp.maximum(t_mac, t_adc)
    e_dyn = macs * params[1] + out_elems * params[2]
    e_leak = params[5] * latency * 1e-3  # mW * ns -> pJ
    energy = e_dyn + e_leak
    power = energy / jnp.maximum(latency, 1e-9) * 1e3  # pJ/ns == W -> mW
    return jnp.stack([latency, energy, power], axis=1)
