"""L1 Pallas kernels for the MFIT-analog thermal solver.

The thermal hot path is dominated by dense matvecs over the (padded) RC
system matrices:

  transient:  T' = A @ T + Bm @ P       (implicit-Euler step, 2 matvecs)
  steady CG:  g  = G @ d                (one matvec per iteration)

`matvec_bias` implements ``y = A @ x + b`` tiled over row blocks so each
grid step holds one (BR, N) tile of A in VMEM alongside the full x/b
vectors.  `dual_matvec` fuses the transient step's two matvecs into one
kernel so A and Bm row tiles stream through VMEM together and T'/P never
round-trip to HBM between them.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the BlockSpec over rows
is the HBM->VMEM schedule; with BR=128 a (128, 1024) f32 tile is 512 KiB,
well under VMEM, and the matvec feeds the MXU one 128-row stripe at a
time.  On this image the kernels run with ``interpret=True`` (CPU PJRT
cannot execute Mosaic custom-calls) so they lower to plain HLO ops; the
block structure is still what a real TPU build would use.

All kernels require N to be a multiple of the row block (the AOT variants
use N in {64, 256, 640, 1024}); the Rust caller zero-pads to the next
variant size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int) -> int:
    """Row-block policy (§Perf-tuned, see EXPERIMENTS.md).

    For the AOT sizes (N <= 1024) a FULL-row block is chosen: one grid
    step, one (N, N) A-tile resident at a time.  VMEM check: (1024, 1024)
    f32 = 4 MiB < 16 MiB, so the schedule is valid on a real TPU too.  On
    this CPU image (interpret=True) the full block lowers to a single dot
    and matches the pure-jnp roofline, where the previous 128-row tiling
    paid a 27x penalty in per-block dynamic-slice overhead inside the
    scan.  Larger systems fall back to 128-row stripes (the classic
    MXU-friendly tiling).
    """
    if n <= 1024:
        return n
    for br in (128, 64, 32, 16, 8, 4, 2, 1):
        if n % br == 0:
            return br
    return 1


# ---------------------------------------------------------------------------
# y = A @ x + b
# ---------------------------------------------------------------------------


def _matvec_bias_kernel(a_ref, x_ref, b_ref, o_ref):
    # a_ref: (BR, N) row tile; x_ref: (N,); b_ref/o_ref: (BR,)
    a = a_ref[...]
    x = x_ref[...]
    o_ref[...] = a @ x + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec_bias(
    a: jnp.ndarray, x: jnp.ndarray, b: jnp.ndarray, block_rows: int | None = None
) -> jnp.ndarray:
    """y = A @ x + b with A tiled over row blocks. A: [N,N], x/b: [N]."""
    n = a.shape[0]
    br = block_rows or _pick_block(n)
    assert n % br == 0, f"N={n} not divisible by block_rows={br}"
    return pl.pallas_call(
        _matvec_bias_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, x, b)


# ---------------------------------------------------------------------------
# y = A @ t + Bm @ p   (fused transient step)
# ---------------------------------------------------------------------------


def _dual_matvec_kernel(a_ref, bm_ref, t_ref, p_ref, o_ref):
    o_ref[...] = a_ref[...] @ t_ref[...] + bm_ref[...] @ p_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dual_matvec(
    a: jnp.ndarray,
    bm: jnp.ndarray,
    t: jnp.ndarray,
    p: jnp.ndarray,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """One implicit-Euler thermal step T' = A @ T + Bm @ P as a fused kernel."""
    n = a.shape[0]
    br = block_rows or _pick_block(n)
    assert n % br == 0, f"N={n} not divisible by block_rows={br}"
    return pl.pallas_call(
        _dual_matvec_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, bm, t, p)


# ---------------------------------------------------------------------------
# y = G @ x   (CG matvec, no bias)
# ---------------------------------------------------------------------------


def _matvec_kernel(g_ref, x_ref, o_ref):
    o_ref[...] = g_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec(g: jnp.ndarray, x: jnp.ndarray, block_rows: int | None = None) -> jnp.ndarray:
    """y = G @ x with G tiled over row blocks."""
    n = g.shape[0]
    br = block_rows or _pick_block(n)
    assert n % br == 0, f"N={n} not divisible by block_rows={br}"
    return pl.pallas_call(
        _matvec_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), g.dtype),
        interpret=True,
    )(g, x)
