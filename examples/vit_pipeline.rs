//! ViT-B/16 weight-stationary pipelined inference (paper §V-E).
//!
//!     cargo run --release --example vit_pipeline
//!
//! The 10×10 mesh dedicates its four corner chiplets as I/O dies hosting
//! the 86 MB of ViT weights; mapping streams each layer's weights from
//! the nearest corner (weight-stationary start-up), then pipelined input
//! batches flow through the 25 transformer sub-layers.  The system comes
//! from the `vit-pipeline` registry scenario; only the inference count is
//! varied per design point.  Reports the weight-load vs inference-time
//! split and the throughput scaling with input pipelining that Fig. 10
//! builds on.

use chipsim::prelude::*;
use chipsim::util::benchkit::{fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let registry = Registry::builtin();
    let scenario = registry.get("vit-pipeline").expect("builtin scenario");
    let model = NeuralModel::build(ModelKind::VitB16);
    println!(
        "ViT-B/16: {} layers, {:.1} MB weights, {:.1} GMACs/inference",
        model.layers.len(),
        model.total_weight_bytes() as f64 / 1e6,
        model.total_macs() as f64 / 1e9
    );

    let mut t = Table::new(
        "ViT-B/16 single model, input pipelining (4 corner I/O chiplets)",
        &["Inferences", "Total time", "Amortized / inf", "Throughput (inf/s)"],
    );
    let mut first_total = 0.0f64;
    for inf in [1u32, 2, 5, 10, 20] {
        let mut params = scenario.params();
        params.inferences_per_model = inf;
        let report = Simulation::builder()
            .hardware(scenario.hardware())
            .params(params)
            .build()?
            .run(scenario.workload(0))?;
        let o = &report.outcomes[0];
        let total = (o.finished_ns - o.mapped_ns) as f64;
        if inf == 1 {
            first_total = total;
        }
        t.row(vec![
            inf.to_string(),
            fmt_ns(total),
            fmt_ns(total / inf as f64),
            format!("{:.1}", inf as f64 / (total * 1e-9)),
        ]);
    }
    t.print();
    println!(
        "\nweight-load amortization: 1-inference run costs {} total;\n\
         the paper notes loading takes ~3x the single-inference execution,\n\
         so throughput rises steeply until pipelining saturates the NoI.",
        fmt_ns(first_total)
    );
    Ok(())
}
