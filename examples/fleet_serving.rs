//! Fleet-scale serving driver: four 6x6-mesh replica boards behind one
//! dispatcher, racing round-robin against least-outstanding routing on
//! the same bursty arrival stream, then an autoscaling run where a
//! queue-depth policy chases a diurnal rate curve with 5 ms cold starts.
//!
//!     cargo run --release --example fleet_serving [-- --quick]
//!
//! Every replica is a full co-simulation (own NoI, compute backend, and
//! power state); the dispatcher advances them in lock-step epochs on a
//! worker pool, so the whole fleet is deterministic in the seed no
//! matter how many threads execute it (see `chipsim::fleet`).

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::fleet::{parse_autoscaler, parse_routing, Fleet, FleetSpec};
use chipsim::serving::{ArrivalSpec, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::util::benchkit::Table;

fn board() -> anyhow::Result<Simulation> {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(SimParams {
            pipelined: true,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        })
        .build()
}

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let horizon_ms = if quick { 10.0 } else { 20.0 };
    let seed = 0xF1EE7;

    // One 6x6 board saturates around 3 krps, so four boards see a mean
    // offered load near 70% of fleet capacity — but it arrives in 16 krps
    // bursts, which is where the routing policy starts to matter.
    let bursty = TrafficSpec::new(ArrivalSpec::on_off(16_000.0, 1_000.0, 3e6, 3e6))
        .horizon_ms(horizon_ms)
        .warmup_ms(3.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None);
    let mut table = Table::new(
        "4x 6x6-mesh fleet: routing policies on one bursty stream",
        &["routing", "completed", "p99_us", "viol_pct", "goodput_rps"],
    );
    for routing in ["round-robin", "least-outstanding"] {
        let t0 = std::time::Instant::now();
        let report =
            Fleet::new(FleetSpec::new(bursty.clone(), 4), board, parse_routing(routing)?)
                .run(seed)?;
        println!(
            "{routing}: {} epochs across {} boards in {:?} wall",
            report.epochs,
            report.replicas.len(),
            t0.elapsed()
        );
        table.row(vec![
            routing.to_string(),
            report.global.completed().to_string(),
            format!("{:.1}", report.global.overall.hist.quantile(0.99) as f64 / 1e3),
            format!("{:.2}", report.global.violation_frac() * 100.0),
            format!("{:.0}", report.goodput_rps()),
        ]);
    }
    table.print();

    // Autoscaling: start at 2 boards and let the queue-depth policy
    // chase a day/night curve; each scale-up pays a 5 ms cold start
    // before the new board accepts work.
    let diurnal = TrafficSpec::new(ArrivalSpec::diurnal(8_000.0, 0.7, 8_000_000))
        .horizon_ms(horizon_ms)
        .warmup_ms(3.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None);
    let report = Fleet::new(
        FleetSpec::new(diurnal, 2).max_replicas(5),
        board,
        parse_routing("least-outstanding")?,
    )
    .autoscaler(parse_autoscaler("queue:24")?)
    .run(seed)?;
    print!("{}", report.summary());
    println!(
        "autoscale: peaked at {} boards over {} scale events",
        report.peak_replicas(),
        report.scale_events.len()
    );
    Ok(())
}
