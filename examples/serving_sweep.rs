//! Serving load sweep: where is this chiplet system's saturation knee?
//!
//! Sweeps a Poisson CNN stream across arrival rates on a 6x6 mesh,
//! printing p50/p99, goodput, and SLO violations per rate, then bisects
//! for the highest rate still meeting the SLO — the number a capacity
//! planner actually wants from a simulator.
//!
//! Run: `cargo run --release --example serving_sweep`

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::serving::{ArrivalSpec, LoadSweep, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let params = SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let make_sim = || {
        Simulation::builder().hardware(hw.clone()).params(params.clone()).build()
    };
    let spec = TrafficSpec::new(
        ArrivalSpec::poisson(1_000.0).kinds(&[ModelKind::ResNet18, ModelKind::ResNet34]),
    )
    .horizon_ms(15.0)
    .warmup_ms(2.0)
    .window_ms(2.0)
    .slo_ms(1.0)
    .steady(None);

    println!("== serving sweep: 6x6 mesh, ResNet18/34 Poisson mix, SLO 1 ms ==");
    for rate in [500.0, 1_000.0, 2_000.0, 4_000.0] {
        let probe = TrafficSpec { arrivals: spec.arrivals.with_rate(rate)?, ..spec.clone() };
        let report = make_sim()?.run_traffic_with(&probe, 0xC0FFEE)?;
        let st = &report.stats;
        println!(
            "  {:>6.0} req/s: p50 {:>8.1} µs  p99 {:>8.1} µs  goodput {:>6.0} req/s  \
             viol {:>5.2} %  ({} done, {} dropped)",
            rate,
            st.overall.hist.quantile(0.5) as f64 / 1e3,
            st.overall.hist.quantile(0.99) as f64 / 1e3,
            st.goodput_rps(),
            st.violation_frac() * 100.0,
            st.completed(),
            st.dropped,
        );
    }

    let sweep = LoadSweep::new(spec, 500.0, 8_000.0).iters(4);
    let result = sweep.run(make_sim, 0xC0FFEE)?;
    println!("\nbisection ({} probes):", result.probes.len());
    for p in &result.probes {
        println!(
            "  {:>7.0} req/s  p99 {:>9.1} µs  viol {:>5.2} %  {}",
            p.rate_rps,
            p.p99_ns as f64 / 1e3,
            p.violation_frac * 100.0,
            if p.meets_slo { "PASS" } else { "fail" },
        );
    }
    println!("saturation knee: ~{:.0} req/s under the 1 ms SLO", result.knee_rps);
    Ok(())
}
