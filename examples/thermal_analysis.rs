//! Power → thermal pipeline: transient + steady-state analysis of a run.
//!
//!     cargo run --release --example thermal_analysis
//!
//! Reproduces the paper's §V-D flow end to end: a pipelined co-simulation
//! built with `.thermal(ThermalSpec::Auto { .. })` generates 1 µs
//! per-chiplet power profiles and attaches a thermal summary to the
//! report (AOT JAX/Pallas artifact via PJRT, native-oracle fallback).
//! The full trajectory, heatmap, and steady-state solve below use the
//! low-level solver API directly.

use chipsim::config::{HardwareConfig, SimParams, WorkloadConfig};
use chipsim::metrics;
use chipsim::sim::{Simulation, ThermalSpec};
use chipsim::thermal::{native::NativeSolver, pjrt::PjrtThermalSolver, ThermalModel};

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 10,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    println!("co-simulating 20-model stream for the power profile...");
    let report = Simulation::builder()
        .hardware(hw.clone())
        .params(params)
        .thermal(ThermalSpec::Auto { stride_bins: 10 })
        .build()?
        .run(WorkloadConfig::cnn_stream(20, 10, 0x7E47))?;
    println!(
        "  span {} ms, {} power bins",
        report.span_ns / 1_000_000,
        report.power.num_bins()
    );
    if let Some(th) = &report.thermal {
        println!(
            "  builder thermal summary ({}, {} steps): hottest {:.2} °C, spread {:.2} K",
            th.solver, th.steps, th.hottest_c, th.spread_k
        );
    }

    let tm = ThermalModel::build(&hw);
    let stride = 10; // 1 µs bins -> 10 µs thermal steps
    let dt_s = stride as f64 * report.power.bin_ns as f64 * 1e-9;
    let power_rows = report.power.matrix_w(stride);
    let node_steps: Vec<Vec<f64>> = power_rows.iter().map(|r| tm.node_power(r)).collect();

    // Transient: PJRT AOT artifact preferred.
    let (traj, solver) = match PjrtThermalSolver::open_default(&tm, dt_s) {
        Ok(mut s) => {
            let traj = s.transient(&vec![0.0; tm.n], &node_steps)?;
            println!("  transient: {} steps in {} PJRT dispatches", traj.len(), s.dispatches());
            (traj, "pjrt")
        }
        Err(e) => {
            println!("  ({e}; using native solver)");
            let s = NativeSolver::new(&tm, dt_s)?;
            (s.transient(&vec![0.0; tm.n], &node_steps), "native")
        }
    };
    let last = traj.last().expect("non-empty run");
    println!("{}", tm.heatmap(last, 10, 10));

    // Transient peak per chiplet over the whole run.
    let mut peak = vec![f64::NEG_INFINITY; hw.num_chiplets()];
    for row in &traj {
        for (ch, pk) in peak.iter_mut().enumerate() {
            *pk = pk.max(tm.chiplet_temp(row, ch));
        }
    }
    let hottest = peak
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "transient peak: chiplet {} at {:.2} °C ({} solver)",
        hottest.0,
        hottest.1 + tm.ambient_c,
        solver
    );

    // Steady state under the run's average power.
    let nbins = report.power.num_bins().max(1);
    let avg_w: Vec<f64> = (0..hw.num_chiplets())
        .map(|c| report.power.avg_power_mw(c) * 1e-3)
        .collect();
    let p_nodes = tm.node_power(&avg_w);
    let steady = NativeSolver::steady(&tm, &p_nodes)?;
    let steady_max = (0..hw.num_chiplets())
        .map(|c| tm.chiplet_temp(&steady, c))
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "steady state at average power ({} bins): hottest {:.2} °C",
        nbins,
        steady_max + tm.ambient_c
    );

    let p1 = metrics::write_result("thermal_analysis_heatmap.txt", &tm.heatmap(last, 10, 10))?;
    let p2 = metrics::write_result(
        "thermal_analysis_temps.csv",
        &tm.temps_csv(last, hw.num_chiplets()),
    )?;
    println!("written: {} and {}", p1.display(), p2.display());
    Ok(())
}
