//! Closed-loop DTM: the throttle-vs-SLO tradeoff in one screen.
//!
//!     cargo run --release --example dtm_closed_loop
//!
//! Runs the same saturating traffic three times — uncontrolled (NoOp),
//! threshold-throttled, and PID-governed — with the thermal RC network
//! stepped *inside* the simulation loop and the governor's per-chiplet
//! f/V choices feeding back into compute latency and dynamic power.
//! Prints peak temperature, ceiling violations, throttle residency, and
//! the serving-side price (p99, goodput), then writes the threshold
//! run's per-window temperature/frequency trace into the results dir.

use chipsim::dtm::GovernorSpec;
use chipsim::metrics;
use chipsim::prelude::*;
use chipsim::serving::ArrivalSpec;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let hw = || HardwareConfig::homogeneous_mesh(4, 4);
    let params = SimParams { pipelined: true, warmup_ns: 0, cooldown_ns: 0, ..SimParams::default() };
    let spec = TrafficSpec::new(
        ArrivalSpec::poisson(5_000.0).kinds(&[ModelKind::ResNet18]).inferences(2),
    )
    .horizon_ms(20.0)
    .warmup_ms(2.0)
    .window_ms(2.0)
    .slo_ms(2.0)
    .steady(None);

    // Setpoints sit a couple of kelvin over the 45 °C ambient: that is
    // where a millisecond-scale horizon lands (the package heats on a
    // seconds-scale RC constant; see README "Thermal & DTM").
    let ceiling = 47.0;
    let governors = [
        GovernorSpec::noop(ceiling),
        GovernorSpec::threshold(ceiling),
        GovernorSpec::pid(ceiling - 1.0),
    ];

    println!(
        "{:<20} {:>8} {:>6} {:>10} {:>10} {:>9}",
        "governor", "peak_c", "viol", "resid_pct", "p99_us", "goodput"
    );
    let mut threshold_csv = None;
    for governor in governors {
        let report = Simulation::builder()
            .hardware(hw())
            .params(params.clone())
            .thermal(ThermalSpec::InLoop { window_ns: 100_000, governor })
            .build()?
            .run_traffic_with(&spec, 0xD7A)?;
        let d = report.dtm().expect("in-loop run attaches a DtmReport");
        println!(
            "{:<20} {:>8.2} {:>6} {:>10.1} {:>10.1} {:>9.0}",
            d.governor,
            d.peak_c,
            d.ceiling_violations,
            d.throttle_residency * 100.0,
            report.stats.overall.hist.quantile(0.99) as f64 / 1e3,
            report.stats.goodput_rps(),
        );
        if d.governor == "threshold-throttle" {
            threshold_csv = Some(d.timeline_csv());
        }
    }
    if let Some(csv) = threshold_csv {
        let path = metrics::write_result("dtm_threshold_timeline.csv", &csv)?;
        println!("threshold window trace written to {}", path.display());
    }
    Ok(())
}
