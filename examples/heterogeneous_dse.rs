//! Design-space exploration: chiplet composition × NoI topology.
//!
//!     cargo run --release --example heterogeneous_dse
//!
//! Exercises CHIPSIM's modularity (paper §V-C) through the scenario
//! registry: each design point is registered as a named scenario, then
//! the whole batch runs concurrently under `SweepRunner` with
//! deterministic per-scenario seeds — the loop an architect would run
//! during early exploration, at thread-pool speed.

use chipsim::prelude::*;
use chipsim::util::benchkit::{fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 5,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let mut registry = Registry::new();
    let designs: Vec<(&str, fn() -> HardwareConfig)> = vec![
        ("mesh/homog-A", || HardwareConfig::homogeneous_mesh(8, 8)),
        ("mesh/hetero-AB", || HardwareConfig::heterogeneous_mesh(8, 8)),
        ("floret8/homog-A", || HardwareConfig::floret(8, 8, 8)),
        ("floret4/homog-A", || HardwareConfig::floret(8, 8, 4)),
    ];
    for (name, hw) in designs {
        registry.register(Scenario::new(
            name,
            "DSE design point",
            hw,
            params.clone(),
            |_seed| WorkloadConfig::cnn_stream(16, 5, 0xD5E),
        ));
    }

    let t0 = std::time::Instant::now();
    let outcomes = SweepRunner::new().run_all(&registry)?;
    println!(
        "{} design points co-simulated in {:.2} s wall (threaded)",
        outcomes.len(),
        t0.elapsed().as_secs_f64()
    );

    let mut t = Table::new(
        "DSE: 16-model CNN stream, pipelined, 5 inf/model",
        &["Design", "ResNet18 lat", "ResNet50 lat", "Makespan", "Energy (mJ)", "Util"],
    );
    for o in &outcomes {
        let report = o.result.as_ref().expect("design point simulates");
        let lat = |k: ModelKind| {
            report.mean_latency_of(k).map(fmt_ns).unwrap_or_else(|| "-".into())
        };
        t.row(vec![
            o.scenario.clone(),
            lat(ModelKind::ResNet18),
            lat(ModelKind::ResNet50),
            fmt_ns(report.span_ns as f64),
            format!("{:.2}", (report.compute_energy_pj + report.comm_energy_pj) / 1e9),
            format!("{:.1}%", report.mean_utilization() * 100.0),
        ]);
    }
    t.print();
    println!("\n(the Floret design should cut ResNet communication latency vs mesh\n while the heterogeneous mix trades latency for energy)");
    Ok(())
}
