//! Design-space exploration: chiplet composition × NoI topology.
//!
//!     cargo run --release --example heterogeneous_dse
//!
//! Exercises CHIPSIM's modularity (paper §V-C): the same workload is
//! co-simulated across homogeneous/heterogeneous chiplet mixes and
//! mesh/Floret interconnects, reporting latency, energy, and utilization
//! per design point — the loop an architect would run during early
//! exploration.

use chipsim::config::{HardwareConfig, SimParams, WorkloadConfig};
use chipsim::sim::GlobalManager;
use chipsim::util::benchkit::{fmt_ns, Table};
use chipsim::workload::ModelKind;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let designs: Vec<(&str, HardwareConfig)> = vec![
        ("mesh/homog-A", HardwareConfig::homogeneous_mesh(8, 8)),
        ("mesh/hetero-AB", HardwareConfig::heterogeneous_mesh(8, 8)),
        ("floret8/homog-A", HardwareConfig::floret(8, 8, 8)),
        ("floret4/homog-A", HardwareConfig::floret(8, 8, 4)),
    ];
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 5,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let mut t = Table::new(
        "DSE: 16-model CNN stream, pipelined, 5 inf/model",
        &["Design", "ResNet18 lat", "ResNet50 lat", "Makespan", "Energy (mJ)", "Util"],
    );
    for (name, hw) in designs {
        let report = GlobalManager::new(hw, params.clone())
            .run(WorkloadConfig::cnn_stream(16, 5, 0xD5E))?;
        let lat = |k: ModelKind| {
            report.mean_latency_of(k).map(|x| fmt_ns(x)).unwrap_or_else(|| "-".into())
        };
        t.row(vec![
            name.into(),
            lat(ModelKind::ResNet18),
            lat(ModelKind::ResNet50),
            fmt_ns(report.span_ns as f64),
            format!("{:.2}", (report.compute_energy_pj + report.comm_energy_pj) / 1e9),
            format!("{:.1}%", report.mean_utilization() * 100.0),
        ]);
    }
    t.print();
    println!("\n(the Floret design should cut ResNet communication latency vs mesh\n while the heterogeneous mix trades latency for energy)");
    Ok(())
}
