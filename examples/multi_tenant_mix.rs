//! Multi-tenant co-execution: what does sharing the fabric cost each
//! tenant?
//!
//! Runs two CNN serving tenants on one 6x6 mesh under all three
//! placement policies, with the interference sweep enabled: every tenant
//! is also run solo on its same placement, so the printed matrix shows
//! exactly how much co-location inflates its tail latency.
//!
//! Run: `cargo run --release --example multi_tenant_mix`

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::mapping::PlacementPolicy;
use chipsim::serving::mix::{run_mix, TenantSpec, WorkloadMix};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    // Narrow links make the shared NoI scarce: interference becomes
    // visible instead of hiding under bandwidth headroom.
    let mut hw = HardwareConfig::homogeneous_mesh(6, 6);
    hw.link.width_bytes = 8;
    let params = SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let tenants = || {
        vec![
            TenantSpec::poisson("latency", ModelKind::ResNet18, 1_500.0).slo_ms(2.0),
            TenantSpec::poisson("batch", ModelKind::ResNet34, 700.0).slo_ms(8.0),
        ]
    };

    for policy in [
        PlacementPolicy::DisjointPartition,
        PlacementPolicy::GreedyBestFit,
        PlacementPolicy::Interleaved,
    ] {
        let mix = WorkloadMix::new(tenants())
            .placement(policy)
            .horizon_ms(30.0)
            .warmup_ms(2.0)
            .window_ms(5.0)
            .interference(true);
        let report = run_mix(
            || {
                Simulation::builder()
                    .hardware(hw.clone())
                    .params(params.clone())
                    .build()
            },
            &mix,
            0xC0FFEE,
        )?;
        println!("== placement: {} ==", policy.name());
        print!("{}", report.summary());
        if let Some(matrix) = &report.interference {
            println!(
                "worst co-location p99 slowdown: {:.2}x\n",
                matrix.max_p99_slowdown()
            );
        }
    }
    println!(
        "Disjoint partitions isolate tenants at the cost of capacity; interleaving \
         shares everything and pays for it in the tail."
    );
    Ok(())
}
