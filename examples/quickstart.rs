//! Quickstart: co-simulate a small CNN stream on a 6×6 chiplet mesh.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the minimal public-API path: hardware preset → sim params
//! → workload → GlobalManager → report.

use chipsim::prelude::*;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();

    // 6×6 homogeneous IMC mesh (NeuRRAM-like chiplets, X-Y routed NoI).
    let hw = HardwareConfig::homogeneous_mesh(6, 6);

    // Pipelined execution, 5 back-to-back inferences per model.
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 5,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };

    // Stream of 8 CNNs sampled uniformly from the paper's four types.
    let workload = WorkloadConfig::cnn_stream(8, 5, 0xBEEF);

    let mut manager = GlobalManager::new(hw, params);
    let report = manager.run(workload)?;

    print!("{}", report.summary());
    println!("NoI bytes·hops moved: {}", report.noc_work);
    println!(
        "peak system power: {:.2} W over {} 1 µs bins",
        report.power.total_series_w().iter().cloned().fold(0.0, f64::max),
        report.power.num_bins()
    );
    Ok(())
}
