//! Quickstart: co-simulate a small CNN stream on a 6×6 chiplet mesh.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the two minimal public-API paths: a one-liner through the
//! scenario registry, and the explicit `Simulation` builder chain
//! (hardware → params → build → run → report).

use chipsim::prelude::*;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();

    // Path 1 — the registry one-liner: every preset has a name.
    let registry = Registry::builtin();
    let scenario = registry.get("mesh-6x6-quickstart").expect("builtin scenario");
    let report = scenario.run(0xBEEF)?;
    println!("[registry] {}", report.summary());

    // Path 2 — the builder: compose the same run explicitly.  Each part
    // (mapper, network fidelity, compute backend, thermal, observers)
    // defaults sensibly and can be swapped independently.
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 5,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let workload = WorkloadConfig::cnn_stream(8, 5, 0xBEEF);

    let mut sim = Simulation::builder().hardware(hw).params(params).build()?;
    let report = sim.run(workload)?;

    print!("{}", report.summary());
    println!("NoI bytes·hops moved: {}", report.noc_work);
    println!(
        "peak system power: {:.2} W over {} 1 µs bins",
        report.power.total_series_w().iter().cloned().fold(0.0, f64::max),
        report.power.num_bins()
    );
    Ok(())
}
