//! End-to-end driver (the repository's validation workload): the paper's
//! full §V-B experiment on a real (small-but-complete) configuration.
//!
//!     cargo run --release --example full_cosim [-- --quick]
//!
//! Runs the 50-model CNN stream on the 10×10 homogeneous mesh in both
//! non-pipelined and pipelined modes, co-simulating compute + NoI +
//! power, then reproduces the paper's headline result: the decoupled
//! baselines underestimate end-to-end inference latency by a factor that
//! grows with utilization — exceeding 100–340 % when pipelined.  All
//! layers of the stack compose here through the builder API: workload →
//! mapper → co-sim loop → packet NoI → analytical IMC backend → power
//! bins (with a live `SimObserver` progress probe), and the resulting
//! power profile is pushed through the AOT thermal artifact when
//! available.  Results are recorded in EXPERIMENTS.md.

use std::sync::{Arc, Mutex};

use chipsim::baselines::BaselineEstimator;
use chipsim::config::{HardwareConfig, SimParams, WorkloadConfig};
use chipsim::metrics::inaccuracy_pct;
use chipsim::sim::{EventCounter, Simulation};
use chipsim::thermal::ThermalModel;
use chipsim::util::benchkit::{fmt_ns, Table};
use chipsim::workload::ALL_CNNS;

fn main() -> anyhow::Result<()> {
    chipsim::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let n_models = if quick { 10 } else { 50 };
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let mut base = BaselineEstimator::new(hw.clone());

    let mut headline: f64 = 0.0;
    for pipelined in [false, true] {
        let params = SimParams {
            pipelined,
            inferences_per_model: 10,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        let counter = Arc::new(Mutex::new(EventCounter::default()));
        let t0 = std::time::Instant::now();
        let report = Simulation::builder()
            .hardware(hw.clone())
            .params(params)
            .observer(counter.clone())
            .build()?
            .run(WorkloadConfig::cnn_stream(n_models, 10, 0xC0FFEE))?;
        let mode = if pipelined { "pipelined" } else { "non-pipelined" };
        println!(
            "== {mode}: {} models in {} simulated ({:?} wall; observer saw {} mapped / {} compute events) ==",
            report.outcomes.len(),
            fmt_ns(report.span_ns as f64),
            t0.elapsed(),
            counter.lock().unwrap().mapped,
            counter.lock().unwrap().compute_events,
        );
        let mut t = Table::new(
            &format!("baseline inaccuracy ({mode}, 10 inf/model)"),
            &["Model", "CHIPSIM", "Comm.Only err", "Comm.+Comp err"],
        );
        for kind in ALL_CNNS {
            let Some(cs) = report.mean_latency_of(kind) else { continue };
            let co = base.comm_only(kind).unwrap().inference_latency_ns;
            let cc = base.comm_compute(kind).unwrap().inference_latency_ns;
            if pipelined {
                headline = headline.max(inaccuracy_pct(cs, co));
            }
            t.row(vec![
                kind.name().into(),
                fmt_ns(cs),
                format!("{:.0}%", inaccuracy_pct(cs, co)),
                format!("{:.0}%", inaccuracy_pct(cs, cc)),
            ]);
        }
        t.print();

        if pipelined {
            // Close the loop: power profile -> thermal analysis.
            let tm = ThermalModel::build(&hw);
            let stride = 10;
            let dt_s = stride as f64 * report.power.bin_ns as f64 * 1e-9;
            let rows = report.power.matrix_w(stride);
            let steps: Vec<Vec<f64>> = rows.iter().map(|r| tm.node_power(r)).collect();
            match chipsim::thermal::pjrt::PjrtThermalSolver::open_default(&tm, dt_s) {
                Ok(mut solver) => {
                    let traj = solver.transient(&vec![0.0; tm.n], &steps)?;
                    let last = traj.last().unwrap();
                    println!(
                        "thermal (PJRT AOT, {} dispatches): hottest chiplet {:.2} °C",
                        solver.dispatches(),
                        (0..hw.num_chiplets())
                            .map(|c| tm.chiplet_temp(last, c) + tm.ambient_c)
                            .fold(f64::NEG_INFINITY, f64::max)
                    );
                }
                Err(e) => println!("thermal artifact unavailable ({e}); run `make artifacts`"),
            }
        }
    }
    println!(
        "\nheadline: max pipelined Comm.Only inaccuracy = {headline:.0}% \
         (paper reports >340% at 20 inf/model)"
    );
    Ok(())
}
