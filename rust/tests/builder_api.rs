//! Public-API tests for the `Simulation` builder, the scenario registry,
//! and the parallel `SweepRunner` (default-fill, invalid-combination
//! errors, and the parallel == sequential determinism guarantee).

use std::sync::{Arc, Mutex};

use chipsim::prelude::*;
use chipsim::sim::EventCounter;

// ------------------------------------------------------------- defaults

#[test]
fn builder_default_fills_every_part() {
    // No hardware, params, mapper, network, or compute supplied: the
    // builder must produce the documented defaults (10x10 type-A mesh,
    // nearest-neighbour mapper, analytical backend).
    let sim = Simulation::builder().build().expect("defaults are valid");
    assert_eq!(sim.hardware().rows, 10);
    assert_eq!(sim.hardware().cols, 10);
    assert_eq!(sim.mapper_name(), "nearest-neighbor");
    assert_eq!(sim.backend_name(), "analytical");
    assert!(!sim.params().pipelined);
}

#[test]
fn builder_runs_a_minimal_workload_with_defaults() {
    let report = Simulation::builder()
        .params(SimParams {
            inferences_per_model: 1,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        })
        .build()
        .unwrap()
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    assert!(report.thermal.is_none(), "thermal defaults to off");
}

// ------------------------------------------------- invalid combinations

#[test]
fn zero_chiplet_mesh_is_a_build_error() {
    for (rows, cols) in [(0, 4), (4, 0), (0, 0)] {
        let hw = HardwareConfig::homogeneous_mesh(rows, cols);
        let err = Simulation::builder().hardware(hw).build().err();
        assert!(err.is_some(), "{rows}x{cols} must fail");
        assert!(err.unwrap().to_string().contains("zero chiplets"));
    }
}

#[test]
fn io_only_hardware_is_a_build_error() {
    let mut hw = HardwareConfig::homogeneous_mesh(3, 3);
    hw.chiplet_types = vec![chipsim::config::ChipletTypeParams::io_die()];
    hw.type_of = vec![0; 9];
    let err = Simulation::builder().hardware(hw).build().err().expect("must fail");
    assert!(err.to_string().contains("no compute chiplets"), "{err}");
}

#[test]
fn out_of_range_type_index_is_a_build_error() {
    let mut hw = HardwareConfig::homogeneous_mesh(2, 2);
    hw.type_of[3] = 7; // only one chiplet type defined
    let err = Simulation::builder().hardware(hw).build().err().expect("must fail");
    assert!(err.to_string().contains("type index"), "{err}");
}

#[test]
fn zero_inferences_is_a_build_error() {
    let err = Simulation::builder()
        .params(SimParams { inferences_per_model: 0, ..SimParams::default() })
        .build()
        .err()
        .expect("must fail");
    assert!(err.to_string().contains("inferences_per_model"), "{err}");
}

// ------------------------------------------------------------ observers

#[test]
fn observers_from_prelude_compose() {
    let counter = Arc::new(Mutex::new(EventCounter::default()));
    let report = Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(4, 4))
        .params(SimParams {
            inferences_per_model: 1,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        })
        .observer(counter.clone())
        .build()
        .unwrap()
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    assert_eq!(counter.lock().unwrap().finished, report.outcomes.len());
}

// ----------------------------------------------------- scenario registry

#[test]
fn registry_scenarios_build_valid_simulations() {
    let reg = Registry::builtin();
    assert!(reg.len() >= 4, "registry too small: {:?}", reg.names());
    for sc in reg.iter() {
        let sim = sc.build().unwrap_or_else(|e| panic!("scenario '{}': {e}", sc.name));
        assert!(sim.hardware().num_chiplets() > 0);
    }
}

#[test]
fn scenario_run_is_seed_deterministic() {
    let reg = Registry::builtin();
    let sc = reg.get("mesh-6x6-quickstart").expect("builtin");
    let a = sc.run(7).unwrap();
    let b = sc.run(7).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    // A seed that samples a different CNN stream gives a different run.
    let base_kinds = sc.workload(7).kinds;
    let mut alt = 8u64;
    while sc.workload(alt).kinds == base_kinds {
        alt += 1;
    }
    let c = sc.run(alt).unwrap();
    assert_ne!(a.fingerprint(), c.fingerprint());
}

// ----------------------------------------------------------- sweep runner

#[test]
fn sweep_parallel_matches_sequential_byte_for_byte() {
    // The acceptance bar: >= 4 registry scenarios, run concurrently,
    // byte-identical to the sequential reference.
    let reg = Registry::builtin();
    let names = [
        "mesh-6x6-quickstart",
        "flit-validation",
        "ccd-star",
        "thermal-hotspot",
        "floret",
    ];
    let runner = SweepRunner::new().threads(4).base_seed(0xDEC0DE);
    let par = runner.run(&reg, &names).unwrap();
    let seq = runner.run_sequential(&reg, &names).unwrap();
    assert_eq!(par.len(), names.len());
    for (p, s) in par.iter().zip(&seq) {
        assert_eq!(p.scenario, s.scenario, "outcome order must match input order");
        assert_eq!(p.seed, s.seed);
        let (pr, sr) = (p.result.as_ref().unwrap(), s.result.as_ref().unwrap());
        assert_eq!(
            pr.fingerprint(),
            sr.fingerprint(),
            "parallel run of '{}' diverged from sequential",
            p.scenario
        );
    }
}

#[test]
fn sweep_single_thread_equals_many_threads() {
    let reg = Registry::builtin();
    let names = ["mesh-6x6-quickstart", "flit-validation"];
    let one = SweepRunner::new().threads(1).run(&reg, &names).unwrap();
    let many = SweepRunner::new().threads(8).run(&reg, &names).unwrap();
    for (a, b) in one.iter().zip(&many) {
        assert_eq!(
            a.result.as_ref().unwrap().fingerprint(),
            b.result.as_ref().unwrap().fingerprint()
        );
    }
}
