//! Integration tests for multi-tenant co-execution: end-to-end mix runs
//! in both NoC fidelities, the solo-vs-co-located interference matrix,
//! per-tenant accounting, and determinism.

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::mapping::PlacementPolicy;
use chipsim::scenario::Registry;
use chipsim::serving::mix::{run_mix, MixReport, TenantSpec, WorkloadMix};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;

fn serving_params() -> SimParams {
    SimParams { pipelined: true, warmup_ns: 0, cooldown_ns: 0, ..SimParams::default() }
}

fn run_on(hw: &HardwareConfig, mix: &WorkloadMix, seed: u64) -> MixReport {
    let hw = hw.clone();
    run_mix(
        move || {
            Simulation::builder()
                .hardware(hw.clone())
                .params(serving_params())
                .build()
        },
        mix,
        seed,
    )
    .expect("mix run")
}

/// Every offered request must be accounted for once the horizon drains:
/// counted, truncated by warm-up, or dropped.
fn assert_accounted(report: &MixReport) {
    for t in &report.tenants {
        assert_eq!(
            t.offered,
            t.stats.completed() + t.stats.warmup_skipped + t.stats.dropped,
            "tenant '{}' loses requests: {} offered vs {} done + {} warmup + {} dropped",
            t.name,
            t.offered,
            t.stats.completed(),
            t.stats.warmup_skipped,
            t.stats.dropped,
        );
    }
}

#[test]
fn contended_mix_reports_interference_with_co_p99_at_least_solo() {
    // The constrained-bandwidth preset: narrow links, fully interleaved
    // placement — co-location must not look free.
    let reg = Registry::builtin();
    let sc = reg.get("mix-contended-interleaved").expect("builtin mix preset");
    let report = sc.run_mix(0xC0FFEE).expect("mix preset runs end-to-end");
    assert_eq!(report.tenants.len(), 2);
    assert_accounted(&report);
    for t in &report.tenants {
        assert!(t.offered > 0, "tenant '{}' offered nothing", t.name);
        assert!(t.stats.completed() > 0, "tenant '{}' completed nothing", t.name);
        assert!(t.chiplets > 0);
        assert!(t.comm.flows > 0 && t.comm.byte_hops > 0, "no NoI attribution for '{}'", t.name);
    }
    let matrix = report.interference.as_ref().expect("preset enables the interference sweep");
    assert_eq!(matrix.entries.len(), 2);
    for e in &matrix.entries {
        assert!(e.solo_p99_ns > 0, "solo baseline of '{}' is empty", e.tenant);
        assert!(e.co_p99_ns > 0);
    }
    // The acceptance property: sharing a constrained fabric makes the
    // co-located tail at least as bad as the solo tail for someone.
    assert!(
        matrix.max_p99_slowdown() >= 1.0,
        "co-location cannot beat every tenant's solo p99: {:?}",
        matrix
            .entries
            .iter()
            .map(|e| (e.tenant.clone(), e.solo_p99_ns, e.co_p99_ns))
            .collect::<Vec<_>>()
    );
    // The summary renders the matrix.
    let s = report.summary();
    assert!(s.contains("interference matrix"), "{s}");
}

#[test]
fn flit_fidelity_mix_runs_end_to_end() {
    let reg = Registry::builtin();
    let sc = reg.get("mix-duo-partitioned-flit").expect("builtin flit mix preset");
    assert_eq!(sc.params().noc_fidelity, chipsim::config::NocFidelity::Flit);
    let report = sc.run_mix(0xBEEF).expect("flit mix runs end-to-end");
    assert_eq!(report.tenants.len(), 2);
    assert_accounted(&report);
    for t in &report.tenants {
        assert!(t.stats.completed() > 0, "tenant '{}' completed nothing", t.name);
        assert!(t.comm.byte_hops > 0);
    }
    assert_eq!(report.placement, PlacementPolicy::DisjointPartition);
    // Disjoint partitions: no chiplet serves two tenants.
    let a = &report.tenants[0];
    let b = &report.tenants[1];
    assert!(a.chiplets + b.chiplets <= 36);
}

#[test]
fn disjoint_partitions_reproduce_solo_latency_when_bandwidth_is_unconstrained() {
    // Two equal tenants split a 6x6 mesh into complete row bands (equal
    // demands -> 18 + 18 chiplets), and 256 B links make communication
    // negligible.  With nothing shared, the co-located run must
    // reproduce each tenant's solo behaviour.
    let mut hw = HardwareConfig::homogeneous_mesh(6, 6);
    hw.link.width_bytes = 256;
    let mix = WorkloadMix::new(vec![
        TenantSpec::poisson("north", ModelKind::ResNet18, 800.0).slo_ms(2.0),
        TenantSpec::poisson("south", ModelKind::ResNet18, 800.0).slo_ms(2.0),
    ])
    .placement(PlacementPolicy::DisjointPartition)
    .horizon_ms(20.0)
    .warmup_ms(2.0)
    .window_ms(5.0)
    .interference(true);
    let report = run_on(&hw, &mix, 0x5EED);
    assert_accounted(&report);
    assert_eq!(report.tenants[0].chiplets, 18);
    assert_eq!(report.tenants[1].chiplets, 18);
    let matrix = report.interference.as_ref().expect("interference enabled");
    for (t, e) in report.tenants.iter().zip(&matrix.entries) {
        assert!(t.stats.completed() > 20, "tenant '{}' too sparse to compare", t.name);
        // Identical arrival stream, disjoint chiplets, idle links: solo
        // and co-located completions must match one for one.
        assert_eq!(
            e.co_completed, e.solo_completed,
            "tenant '{}': co-located run must complete the same requests solo did",
            e.tenant
        );
        let rel = |a: u64, b: u64| {
            (a as f64 - b as f64).abs() / (b as f64).max(1.0)
        };
        assert!(
            rel(e.co_p99_ns, e.solo_p99_ns) < 0.01,
            "tenant '{}': co p99 {} vs solo p99 {} differ with nothing shared",
            e.tenant,
            e.co_p99_ns,
            e.solo_p99_ns
        );
        assert!(
            rel(e.co_p50_ns, e.solo_p50_ns) < 0.01,
            "tenant '{}': co p50 {} vs solo p50 {} differ with nothing shared",
            e.tenant,
            e.co_p50_ns,
            e.solo_p50_ns
        );
    }
}

#[test]
fn mix_is_deterministic_per_seed() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let mix = WorkloadMix::new(vec![
        TenantSpec::poisson("a", ModelKind::ResNet18, 600.0).slo_ms(2.0),
        TenantSpec::poisson("b", ModelKind::AlexNet, 300.0).slo_ms(4.0),
    ])
    .placement(PlacementPolicy::GreedyBestFit)
    .horizon_ms(10.0)
    .warmup_ms(1.0)
    .window_ms(2.0);
    let x = run_on(&hw, &mix, 42);
    let y = run_on(&hw, &mix, 42);
    assert_eq!(x.fingerprint(), y.fingerprint());
    let z = run_on(&hw, &mix, 43);
    assert_ne!(x.fingerprint(), z.fingerprint(), "seed must matter");
}

#[test]
fn infeasible_mix_is_rejected_up_front() {
    // AlexNet (~61 MB) cannot fit any partition of a 4x4 system (32 MiB
    // total): placement must fail fast with the journal rolled back, not
    // let the run limp along dropping everything.
    let hw = HardwareConfig::homogeneous_mesh(4, 4);
    let mix = WorkloadMix::new(vec![
        TenantSpec::poisson("fits", ModelKind::ResNet18, 400.0).slo_ms(2.0),
        TenantSpec::poisson("huge", ModelKind::AlexNet, 200.0).slo_ms(4.0),
    ])
    .placement(PlacementPolicy::DisjointPartition)
    .horizon_ms(5.0)
    .warmup_ms(0.5)
    .window_ms(1.0);
    let err = run_mix(
        {
            let hw = hw.clone();
            move || Simulation::builder().hardware(hw.clone()).params(serving_params()).build()
        },
        &mix,
        7,
    )
    .err()
    .expect("placement must reject the infeasible mix");
    assert!(err.to_string().contains("infeasible"), "{err}");
}

#[test]
fn oversized_request_drops_within_its_partition_while_other_tenant_serves() {
    use chipsim::sim::{BatchSource, NullSink};
    use chipsim::workload::ModelRequest;
    // Hand-built masks: tenant 0 owns rows 0..3, tenant 1 rows 3..6
    // (18 chiplets = 36 MiB — AlexNet's ~61 MB can never map there, and
    // its fc6 layer alone outgrows the partition).  The request must be
    // dropped promptly, attributed to tenant 1, while tenant 0 serves.
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let n = hw.num_chiplets();
    let mut sim = Simulation::builder()
        .hardware(hw.clone())
        .params(serving_params())
        .build()
        .unwrap();
    sim.set_tenant_masks(vec![
        (0..n).map(|c| c < 18).collect(),
        (0..n).map(|c| c >= 18).collect(),
    ]);
    let req = |id: usize, kind, arrival_ns, tenant| ModelRequest {
        id,
        kind,
        arrival_ns,
        inferences: 1,
        tenant,
    };
    let reqs = vec![
        req(0, ModelKind::ResNet18, 0, 0),
        req(1, ModelKind::AlexNet, 10, 1),
        req(2, ModelKind::ResNet18, 20, 0),
    ];
    let report = sim.run_with(&mut BatchSource::new(reqs), &mut NullSink).unwrap();
    assert_eq!(report.dropped, vec![(1, ModelKind::AlexNet)]);
    assert_eq!(report.outcomes.len(), 2);
    assert!(report.outcomes.iter().all(|o| o.tenant == 0));
    // NoI flow attribution: the serving tenant moved activations, the
    // dropped one never injected a flow.
    assert!(report.tenant_comm[0].byte_hops > 0);
    assert_eq!(report.tenant_comm.get(1).map(|c| c.flows).unwrap_or(0), 0);
}
