//! Tests for the extension features: link-utilization statistics and
//! THERMOS-style thermal-aware mapping.

use chipsim::config::{HardwareConfig, SimParams, WorkloadConfig};
use chipsim::mapping::{MemoryLedger, NearestNeighborMapper};
use chipsim::noc::topology::Topology;
use chipsim::noc::LinkUtilization;
use chipsim::sim::Simulation;
use chipsim::workload::{ModelKind, NeuralModel};

fn params(pipelined: bool, inf: u32) -> SimParams {
    SimParams {
        pipelined,
        inferences_per_model: inf,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    }
}

/// Shared builder-API assembly for this target.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid test configuration")
}

// ------------------------------------------------------ link utilization

#[test]
fn link_utilization_reported_and_bounded() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let report = sim(hw, params(true, 3))
        .run(WorkloadConfig::cnn_stream(6, 3, 0xC0FFEE))
        .unwrap();
    let u = &report.link_util;
    assert!(!u.per_link.is_empty());
    assert!(u.per_link.iter().all(|&x| (0.0..=1.0).contains(&x)));
    assert!(u.peak > 0.0, "some link must have carried traffic");
    assert!(u.peak >= u.mean);
    assert!(u.hottest < u.per_link.len());
}

#[test]
fn utilization_grows_with_load() {
    let hw = HardwareConfig::homogeneous_mesh(8, 8);
    let light = sim(hw.clone(), params(true, 1))
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    let heavy = sim(hw, params(true, 10))
        .run(WorkloadConfig::cnn_stream(10, 10, 0xC0FFEE))
        .unwrap();
    assert!(
        heavy.link_util.mean > light.link_util.mean,
        "heavy {} !> light {}",
        heavy.link_util.mean,
        light.link_util.mean
    );
}

#[test]
fn link_utilization_from_busy_math() {
    let u = LinkUtilization::from_busy(&[50, 100, 0, 25], 100);
    assert_eq!(u.per_link, vec![0.5, 1.0, 0.0, 0.25]);
    assert!((u.mean - 0.4375).abs() < 1e-12);
    assert_eq!(u.hottest, 1);
    assert_eq!(u.peak, 1.0);
}

// --------------------------------------------------- thermal-aware mapping

#[test]
fn heat_penalty_steers_mapping_away_from_hotspots() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let topo = Topology::build(&hw);
    let model = NeuralModel::build(ModelKind::ResNet18);
    // Cold baseline: which chiplets does the plain mapper pick?
    let mut ledger = MemoryLedger::new(&hw);
    let plain = NearestNeighborMapper::new(&hw, &topo)
        .try_map(&model, &mut ledger)
        .unwrap();
    let plain_set: std::collections::HashSet<usize> =
        plain.layers.iter().flatten().map(|s| s.chiplet).collect();
    // Mark exactly those chiplets as scorching; remap with a strong
    // penalty — the mapper must move the bulk of the model elsewhere.
    let mut heat = vec![0.0; hw.num_chiplets()];
    for &c in &plain_set {
        heat[c] = 1_000.0;
    }
    let mut ledger2 = MemoryLedger::new(&hw);
    let cooled = NearestNeighborMapper::new(&hw, &topo)
        .with_heat(&heat, 50.0)
        .try_map(&model, &mut ledger2)
        .unwrap();
    let cooled_set: std::collections::HashSet<usize> =
        cooled.layers.iter().flatten().map(|s| s.chiplet).collect();
    let overlap = plain_set.intersection(&cooled_set).count();
    assert!(
        overlap * 2 < plain_set.len(),
        "thermal-aware mapping should avoid hot chiplets: {overlap}/{} reused",
        plain_set.len()
    );
}

#[test]
fn zero_weight_heat_is_identical_to_plain() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let topo = Topology::build(&hw);
    let model = NeuralModel::build(ModelKind::ResNet34);
    let mut l1 = MemoryLedger::new(&hw);
    let mut l2 = MemoryLedger::new(&hw);
    let heat = vec![5.0; hw.num_chiplets()];
    let a = NearestNeighborMapper::new(&hw, &topo).try_map(&model, &mut l1).unwrap();
    // Uniform heat => identical ranking even with a non-zero weight.
    let b = NearestNeighborMapper::new(&hw, &topo)
        .with_heat(&heat, 10.0)
        .try_map(&model, &mut l2)
        .unwrap();
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        let ca: Vec<usize> = la.iter().map(|s| s.chiplet).collect();
        let cb: Vec<usize> = lb.iter().map(|s| s.chiplet).collect();
        assert_eq!(ca, cb);
    }
}

#[test]
fn thermal_aware_cosim_spreads_energy() {
    // With the flag on, a stream of identical models should spread heat
    // over more chiplets (lower max per-chiplet energy share).
    let hw = HardwareConfig::homogeneous_mesh(8, 8);
    let run = |aware: f64| {
        let mut p = params(false, 3);
        p.thermal_aware_hops = aware;
        let report = sim(hw.clone(), p)
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 5]))
            .unwrap();
        let per: Vec<f64> =
            (0..64).map(|c| report.power.dynamic_energy_pj(c)).collect();
        let total: f64 = per.iter().sum();
        let max = per.iter().cloned().fold(0.0, f64::max);
        (max / total, report.outcomes.len())
    };
    let (plain_share, n1) = run(0.0);
    let (aware_share, n2) = run(8.0);
    assert_eq!(n1, n2, "same number of models must complete");
    assert!(
        aware_share <= plain_share * 1.05,
        "thermal-aware should not concentrate more: {aware_share} vs {plain_share}"
    );
}

#[test]
fn thermal_aware_keeps_correctness_invariants() {
    let hw = HardwareConfig::heterogeneous_mesh(8, 8);
    let mut p = params(true, 2);
    p.thermal_aware_hops = 4.0;
    let report = sim(hw, p)
        .run(WorkloadConfig::cnn_stream(8, 2, 0xC0FFEE))
        .unwrap();
    assert_eq!(report.outcomes.len() + report.dropped.len(), 8);
    for o in &report.outcomes {
        assert_eq!(o.inference_latency_ns.len(), 2);
    }
}
