//! Integration tests for the sustained-traffic serving subsystem:
//! end-to-end SLO metrics, per-seed determinism, steady-state early
//! stop, and the constant-memory guarantee (a 100x longer horizon must
//! not grow the PowerTracker's live bin count).

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::scenario::Registry;
use chipsim::serving::{ArrivalSpec, SteadyState, StopReason, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;

fn serving_params() -> SimParams {
    SimParams { pipelined: true, warmup_ns: 0, cooldown_ns: 0, ..SimParams::default() }
}

fn sim(rows: usize, cols: usize) -> Simulation {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(rows, cols))
        .params(serving_params())
        .build()
        .expect("valid configuration")
}

/// Light but realistic load: single-kind requests well under saturation,
/// so runs stay fast in debug builds.
fn light_spec(horizon_ms: f64) -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(1_000.0).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(horizon_ms)
        .warmup_ms(0.0)
        .window_ms(1.0)
        .slo_ms(2.0)
        .steady(None)
}

#[test]
fn traffic_run_reports_slo_metrics() {
    let report = sim(6, 6).run_traffic_with(&light_spec(20.0), 0xFEED).unwrap();
    assert!(report.offered > 0, "no requests offered");
    let st = &report.stats;
    assert!(st.completed() > 0, "nothing completed");
    assert_eq!(
        report.offered,
        st.completed() + st.warmup_skipped + st.dropped,
        "every offered request must be accounted for after drain"
    );
    let h = &st.overall.hist;
    let (p50, p99, p999) = (h.quantile(0.5), h.quantile(0.99), h.quantile(0.999));
    assert!(p50 > 0 && p50 <= p99 && p99 <= p999 && p999 <= h.max());
    assert!(st.goodput_rps() > 0.0);
    assert_eq!(report.stop, StopReason::Drained);
    // Streaming mode retains no per-model outcomes.
    assert!(report.sim.outcomes.is_empty());
    // The summary renders and mentions the headline numbers.
    let s = report.summary();
    assert!(s.contains("p99"), "{s}");
    assert!(s.contains("goodput"), "{s}");
}

#[test]
fn impossible_slo_counts_every_completion_as_violation() {
    let spec = light_spec(10.0).slo_us(1.0); // 1 µs end-to-end: unmeetable
    let report = sim(6, 6).run_traffic_with(&spec, 0xFEED).unwrap();
    let st = &report.stats;
    assert!(st.completed() > 0);
    assert_eq!(st.violations(), st.completed());
    assert!((st.violation_frac() - 1.0).abs() < 1e-12);
    assert_eq!(st.goodput_rps(), 0.0);
}

#[test]
fn traffic_is_byte_identical_per_seed() {
    let spec = light_spec(15.0);
    let a = sim(6, 6).run_traffic_with(&spec, 42).unwrap();
    let b = sim(6, 6).run_traffic_with(&spec, 42).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.span_ns(), b.span_ns());
    let c = sim(6, 6).run_traffic_with(&spec, 43).unwrap();
    assert_ne!(a.fingerprint(), c.fingerprint(), "seed must matter");
}

#[test]
fn constant_memory_with_respect_to_horizon() {
    // The acceptance bar: a 100x longer simulated horizon must not grow
    // the PowerTracker's live bin count — windows drain as time advances.
    let short = sim(6, 6).run_traffic_with(&light_spec(2.0), 7).unwrap();
    let long = sim(6, 6).run_traffic_with(&light_spec(200.0), 7).unwrap();
    assert!(long.span_ns() > 50 * short.span_ns(), "long run must actually be long");
    let window_bins = 1_000; // 1 ms window / 1 µs bins
    let live_short = short.sim.power.live_bins();
    let live_long = long.sim.power.live_bins();
    assert!(
        live_long <= 4 * window_bins,
        "live bins must stay within a few windows, got {live_long}"
    );
    assert!(
        live_long <= live_short.max(2 * window_bins) * 2,
        "live bins grew with horizon: {live_short} -> {live_long}"
    );
    // The long run really did profile (and drain) two orders of magnitude
    // more bins, and energy accounting survived the draining.
    assert!(long.sim.power.drained_bins() > 20 * short.sim.power.num_bins().max(1));
    let total_dynamic: f64 =
        (0..long.sim.power.num_chiplets()).map(|c| long.sim.power.dynamic_energy_pj(c)).sum();
    assert!(
        (total_dynamic - long.sim.compute_energy_pj - long.sim.comm_energy_pj).abs()
            <= 1e-6 * total_dynamic.max(1.0),
        "drained power lost energy: bins {total_dynamic} vs booked {}",
        long.sim.compute_energy_pj + long.sim.comm_energy_pj
    );
    // Bounded window ring: the report keeps a tail, not the whole trace.
    assert!(long.windows.len() <= 32);
}

#[test]
fn steady_state_detection_stops_early() {
    // With a generous tolerance any two consecutive populated windows
    // agree, so the run must stop long before the horizon.
    let spec = light_spec(200.0)
        .steady(Some(SteadyState { windows: 2, rel_tol: 10.0, min_per_window: 1 }));
    let report = sim(6, 6).run_traffic_with(&spec, 11).unwrap();
    assert_eq!(report.stop, StopReason::SteadyState);
    assert!(
        report.span_ns() < 50_000_000,
        "expected early stop, ran to {} ns",
        report.span_ns()
    );
}

#[test]
fn builder_attached_traffic_spec_round_trips() {
    let report = Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(serving_params())
        .traffic(light_spec(5.0))
        .build()
        .unwrap()
        .run_traffic(0xBEEF)
        .unwrap();
    assert!(report.stats.completed() > 0);
    // Without an attached spec, run_traffic is an actionable error.
    let err = sim(4, 4).run_traffic(0xBEEF).err().expect("must fail");
    assert!(err.to_string().contains("traffic"), "{err}");
}

#[test]
fn trace_replay_scenario_is_deterministic_and_drains() {
    let reg = Registry::builtin();
    let sc = reg.get("traffic-trace-replay").expect("registered");
    let a = sc.run_traffic(5).unwrap();
    let b = sc.run_traffic(5).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.offered, 120, "3 bursts x 40 requests");
    assert_eq!(
        a.offered,
        a.stats.completed() + a.stats.warmup_skipped + a.stats.dropped
    );
}

#[test]
fn bursty_traffic_inflates_tail_over_poisson() {
    // Same mean offered rate, very different arrival structure: the
    // on-off burst stream must show a worse p99 than smooth Poisson.
    let mesh = || sim(6, 6);
    let base = TrafficSpec::new(ArrivalSpec::poisson(1_500.0).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(30.0)
        .warmup_ms(0.0)
        .window_ms(1.0)
        .slo_ms(2.0)
        .steady(None);
    let smooth = mesh().run_traffic_with(&base, 3).unwrap();
    // 20x the mean rate inside 1 ms bursts (19 ms silent): same offered
    // load, heavy in-burst contention and queueing.
    let bursty_spec = TrafficSpec {
        arrivals: ArrivalSpec::on_off(30_000.0, 0.0, 1e6, 19e6).kinds(&[ModelKind::ResNet18]),
        ..base
    };
    let bursty = mesh().run_traffic_with(&bursty_spec, 3).unwrap();
    let mean_smooth = smooth.stats.overall.hist.mean();
    let mean_bursty = bursty.stats.overall.hist.mean();
    assert!(
        mean_bursty > mean_smooth,
        "bursts must hurt latency: bursty {mean_bursty} !> smooth {mean_smooth}"
    );
    assert!(
        bursty.stats.overall.hist.quantile(0.99) >= smooth.stats.overall.hist.quantile(0.99),
        "bursty p99 must not beat smooth p99"
    );
}
