//! Cross-module integration tests: workload → mapper → co-sim → power →
//! thermal, plus baseline/co-sim relationships that the paper's
//! evaluation depends on.

use chipsim::baselines::BaselineEstimator;
use chipsim::config::{HardwareConfig, NocFidelity, SimParams, WorkloadConfig};
use chipsim::metrics::inaccuracy_pct;
use chipsim::sim::Simulation;
use chipsim::thermal::{native::NativeSolver, ThermalModel};
use chipsim::workload::{ModelKind, ALL_CNNS};

fn params(pipelined: bool, inferences: u32) -> SimParams {
    SimParams {
        pipelined,
        inferences_per_model: inferences,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    }
}

/// Shared builder-API assembly for this target.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid test configuration")
}

#[test]
fn every_cnn_runs_end_to_end_on_the_paper_mesh() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    for kind in ALL_CNNS {
        let report = sim(hw.clone(), params(false, 2))
            .run(WorkloadConfig::single(kind))
            .unwrap();
        assert_eq!(report.outcomes.len(), 1, "{kind:?}");
        assert_eq!(report.outcomes[0].inference_latency_ns.len(), 2);
        assert!(report.outcomes[0].mean_latency_ns() > 0.0);
        assert!(report.compute_energy_pj > 0.0);
        assert!(report.comm_energy_pj > 0.0);
    }
}

#[test]
fn vit_runs_on_the_io_corner_mesh() {
    let hw = HardwareConfig::vit_mesh(10, 10);
    let report = sim(hw, params(true, 2))
        .run(WorkloadConfig::single(ModelKind::VitB16))
        .unwrap();
    assert_eq!(report.outcomes.len(), 1);
    // Weight loading happens before inference 0 starts: mapped -> first
    // inference start gap must be large (86 MB over the NoI).
    let o = &report.outcomes[0];
    assert!(o.finished_ns > o.mapped_ns);
}

#[test]
fn pipelining_increases_throughput_but_not_below_single_inference_latency() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let seq = sim(hw.clone(), params(false, 8))
        .run(WorkloadConfig::single(ModelKind::ResNet34))
        .unwrap();
    let pipe = sim(hw, params(true, 8))
        .run(WorkloadConfig::single(ModelKind::ResNet34))
        .unwrap();
    let total_seq = seq.outcomes[0].finished_ns - seq.outcomes[0].mapped_ns;
    let total_pipe = pipe.outcomes[0].finished_ns - pipe.outcomes[0].mapped_ns;
    assert!(total_pipe < total_seq, "pipelined {total_pipe} !< sequential {total_seq}");
}

#[test]
fn error_grows_with_inference_count_pipelined() {
    // The paper's central claim (Fig. 6): baseline inaccuracy grows with
    // utilization (inferences per model instance).
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let mut base = BaselineEstimator::new(hw.clone());
    let cc = base.comm_compute(ModelKind::ResNet18).unwrap().inference_latency_ns;
    let mut errs = Vec::new();
    for inf in [1u32, 10] {
        let report = sim(hw.clone(), params(true, inf))
            .run(WorkloadConfig::cnn_stream(12, inf, 0xC0FFEE))
            .unwrap();
        let cs = report.mean_latency_of(ModelKind::ResNet18).unwrap();
        errs.push(inaccuracy_pct(cs, cc));
    }
    assert!(
        errs[1] > errs[0],
        "inaccuracy must grow with inferences: {errs:?}"
    );
}

#[test]
fn heterogeneous_mesh_shifts_time_toward_compute() {
    let homog = HardwareConfig::homogeneous_mesh(10, 10);
    let hetero = HardwareConfig::heterogeneous_mesh(10, 10);
    let share = |hw: HardwareConfig| {
        let report = sim(hw, params(true, 3))
            .run(WorkloadConfig::cnn_stream(8, 3, 0xC0FFEE))
            .unwrap();
        let (comp, comm) = report.mean_compute_comm_of(ModelKind::ResNet18).unwrap();
        comp / (comp + comm)
    };
    let s_homog = share(homog);
    let s_hetero = share(hetero);
    assert!(
        s_hetero > s_homog,
        "hetero compute share {s_hetero} !> homog {s_homog}"
    );
    // Paper §V-C1: computation reaches 42-54% of total on the hetero system.
    assert!(s_hetero > 0.25, "hetero compute share too small: {s_hetero}");
}

#[test]
fn floret_topology_runs_the_full_stream() {
    let hw = HardwareConfig::floret(10, 10, 10);
    let report = sim(hw, params(true, 2))
        .run(WorkloadConfig::cnn_stream(8, 2, 0xC0FFEE))
        .unwrap();
    assert!(report.outcomes.len() >= 7);
}

#[test]
fn flit_and_packet_fidelity_agree_on_ordering() {
    // The flit engine is slower but must preserve the big picture: same
    // models complete, latencies within a modest factor.
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let mut p_packet = params(false, 1);
    p_packet.noc_fidelity = NocFidelity::Packet;
    let mut p_flit = params(false, 1);
    p_flit.noc_fidelity = NocFidelity::Flit;
    let wl = WorkloadConfig::single(ModelKind::ResNet18);
    let r_packet = sim(hw.clone(), p_packet).run(wl.clone()).unwrap();
    let r_flit = sim(hw, p_flit).run(wl).unwrap();
    let lp = r_packet.outcomes[0].mean_latency_ns();
    let lf = r_flit.outcomes[0].mean_latency_ns();
    let ratio = lf / lp;
    assert!(
        (0.4..=2.5).contains(&ratio),
        "flit {lf} vs packet {lp} (ratio {ratio})"
    );
}

#[test]
fn power_profile_feeds_thermal_and_heats_busy_chiplets() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let report = sim(hw.clone(), params(true, 4))
        .run(WorkloadConfig::cnn_stream(4, 4, 0xF00D))
        .unwrap();
    let tm = ThermalModel::build(&hw);
    let stride = 10;
    let rows = report.power.matrix_w(stride);
    assert!(!rows.is_empty());
    let solver = NativeSolver::new(&tm, stride as f64 * 1e-6).unwrap();
    let steps: Vec<Vec<f64>> = rows.iter().map(|r| tm.node_power(r)).collect();
    let traj = solver.transient(&vec![0.0; tm.n], &steps);
    let last = traj.last().unwrap();
    // Some chiplet must be above the floor (baseline idle power heats all).
    let max_t = (0..hw.num_chiplets())
        .map(|c| tm.chiplet_temp(last, c))
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(max_t > 0.0);
}

#[test]
fn dropped_models_are_reported_not_lost() {
    let hw = HardwareConfig::homogeneous_mesh(3, 3); // 18 MiB: AlexNet won't fit
    let report = sim(hw, params(false, 1))
        .run(WorkloadConfig::from_kinds(&[
            ModelKind::ResNet18,
            ModelKind::AlexNet,
            ModelKind::ResNet18,
        ]))
        .unwrap();
    let total = report.outcomes.len() + report.dropped.len();
    assert_eq!(total, 3);
    assert!(report.dropped.iter().any(|&(_, k)| k == ModelKind::AlexNet));
}

#[test]
fn packet_and_flit_agree_on_uncontended_latency_and_contended_ranking() {
    // Post-rewrite regression guard: the active-set flit engine must
    // still (a) match the packet engine on uncontended latency to within
    // the router-pipeline approximation, and (b) rank contended flows
    // identically.
    use chipsim::config::LinkParams;
    use chipsim::noc::engine::PacketEngine;
    use chipsim::noc::flit::FlitEngine;
    use chipsim::noc::topology::mesh;
    use chipsim::noc::{FlowSpec, NetworkSim};

    // (a) Uncontended: one flow at a time across sizes and hop counts.
    for (hops, bytes) in [(1usize, 512u64), (3, 4_096), (5, 32_768)] {
        let topo = mesh(1, hops + 1, &LinkParams::default());
        let mut fe = FlitEngine::new(topo.clone());
        let fid = fe.inject(FlowSpec { src: 0, dst: hops, bytes }, 0);
        while fe.advance_until(u64::MAX).is_some() {}
        let mut pe = PacketEngine::new(topo);
        let pid = pe.inject(FlowSpec { src: 0, dst: hops, bytes }, 0);
        while pe.advance_until(u64::MAX).is_some() {}
        let fl = fe.stats(fid).unwrap().latency_ns() as f64;
        let pl = pe.stats(pid).unwrap().latency_ns() as f64;
        let ratio = fl / pl;
        assert!(
            (0.5..=1.6).contains(&ratio),
            "hops={hops} bytes={bytes}: flit {fl} vs packet {pl} (ratio {ratio})"
        );
    }

    // (b) Contended: four flows over the same 0->3 path with strongly
    // separated sizes, plus one flow on a disjoint row.  Latency ranking
    // must be identical across fidelities.
    let rank = |make: &dyn Fn(chipsim::noc::topology::Topology) -> Box<dyn NetworkSim>| {
        let topo = mesh(2, 4, &LinkParams::default());
        let mut e = make(topo);
        let specs = [
            FlowSpec { src: 0, dst: 3, bytes: 2_048 },
            FlowSpec { src: 0, dst: 3, bytes: 16_384 },
            FlowSpec { src: 0, dst: 3, bytes: 131_072 },
            FlowSpec { src: 4, dst: 7, bytes: 8_192 }, // disjoint row
        ];
        let ids: Vec<_> = specs.iter().map(|&s| e.inject(s, 0)).collect();
        while e.advance_until(u64::MAX).is_some() {}
        let mut order: Vec<usize> = (0..ids.len()).collect();
        order.sort_by_key(|&i| e.stats(ids[i]).unwrap().latency_ns());
        order
    };
    let flit_order = rank(&|t| Box::new(FlitEngine::new(t)));
    let packet_order = rank(&|t| Box::new(PacketEngine::new(t)));
    assert_eq!(
        flit_order, packet_order,
        "contended flow ranking diverges between fidelities"
    );
}

#[test]
fn report_summary_renders() {
    let hw = HardwareConfig::homogeneous_mesh(4, 4);
    let report = sim(hw, params(false, 1))
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    let s = report.summary();
    assert!(s.contains("ResNet18"));
    assert!(s.contains("mean inference latency"));
}

#[test]
fn hwemu_table7_shape_single_digit_percent() {
    // Table VII's claim: CHIPSIM tracks the (emulated) hardware closely.
    use chipsim::hwemu;
    let traces = vec![hwemu::model_trace(ModelKind::AlexNet)];
    let hw_t = hwemu::emulate(&traces);
    let sim_t = hwemu::chipsim_ccd_run(&traces);
    let diff = hwemu::percent_diff(sim_t[0], hw_t[0]);
    assert!(diff < 15.0, "one-chiplet AlexNet diff {diff}%");
}
