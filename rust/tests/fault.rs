//! Integration tests for the fault-injection subsystem: per-seed
//! byte-identical `FaultReport`s, the zero-perturbation rule (an armed
//! but event-free plan must not move a single bit), partition-kills-flow
//! on both network fidelities, and fleet-level board-crash recovery
//! (goodput floor + request conservation).

use chipsim::config::{HardwareConfig, LinkParams, NocFidelity, SimParams};
use chipsim::fault::FaultPlan;
use chipsim::fleet::{parse_routing, Fleet, FleetSpec};
use chipsim::noc::engine::PacketEngine;
use chipsim::noc::flit::FlitEngine;
use chipsim::noc::topology::mesh;
use chipsim::noc::{FlowSpec, NetworkSim};
use chipsim::serving::{ArrivalSpec, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;
use chipsim::TimeNs;

fn serving_params(fidelity: NocFidelity) -> SimParams {
    SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        noc_fidelity: fidelity,
        ..SimParams::default()
    }
}

fn board(fidelity: NocFidelity, plan: Option<FaultPlan>) -> anyhow::Result<Simulation> {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(serving_params(fidelity))
        .faults(plan)
        .build()
}

/// Single-kind load keeps debug-build runs fast (same idiom as the
/// serving/fleet tests).
fn light_spec(rate: f64, horizon_ms: f64) -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(rate).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(horizon_ms)
        .warmup_ms(2.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None)
}

// ------------------------------------------------------ per-seed identity

#[test]
fn fault_reports_are_byte_identical_per_seed() {
    // Same seed + same plan => byte-identical FaultReport and SimReport
    // fingerprints, run after run.  The plan exercises a transient
    // chiplet kill plus a lying sensor so both abort and overlay paths
    // execute.
    let plan = FaultPlan::parse("chiplet:7@3ms+5ms, sensor:3:stuck=95@2ms").unwrap();
    let run = || {
        board(NocFidelity::Packet, Some(plan.clone()))
            .unwrap()
            .run_traffic_with(&light_spec(1_500.0, 12.0), 0xFA17)
            .unwrap()
    };
    let a = run();
    let b = run();
    let fa = a.sim.fault.as_ref().expect("fault plan fired");
    let fb = b.sim.fault.as_ref().expect("fault plan fired");
    assert!(fa.injected >= 1, "chiplet kill must inject");
    assert!(fa.repairs >= 1, "transient fault must repair");
    assert!(fa.sensor_faults >= 1, "sensor overlay must arm");
    assert!(!fa.timeline.is_empty());
    // The executed timeline is time-ordered.
    assert!(fa.timeline.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
    assert_eq!(fa.fingerprint(), fb.fingerprint(), "FaultReport diverged across runs");
    assert_eq!(a.sim.fingerprint(), b.sim.fingerprint(), "SimReport diverged across runs");
    assert_eq!(a.stats.fingerprint(), b.stats.fingerprint());
}

// ------------------------------------------------------ zero perturbation

#[test]
fn armed_but_empty_plan_is_fingerprint_identical_to_faultless() {
    // Two flavors of "armed but nothing fires on a board": a plan with
    // zero events, and a board-only plan (the sim skips board: events —
    // they belong to the fleet dispatcher).  Both must leave the run
    // fingerprint-identical to no plan at all, on both fidelities.
    for fidelity in [NocFidelity::Packet, NocFidelity::Flit] {
        let spec = light_spec(1_200.0, 8.0);
        let run = |plan: Option<FaultPlan>| {
            board(fidelity, plan).unwrap().run_traffic_with(&spec, 7).unwrap()
        };
        let clean = run(None);
        assert!(clean.sim.fault.is_none(), "faultless run must not carry a report");
        for armed in ["seed=1234", "board:2@5ms"] {
            let r = run(Some(FaultPlan::parse(armed).unwrap()));
            assert!(
                r.sim.fault.is_none(),
                "'{armed}' resolved to zero toggles and must attach no report"
            );
            assert_eq!(
                clean.sim.fingerprint(),
                r.sim.fingerprint(),
                "armed-but-empty plan '{armed}' perturbed a {fidelity:?} run"
            );
            assert_eq!(clean.stats.fingerprint(), r.stats.fingerprint());
        }
    }
}

// ------------------------------------------------- partition kills flows

#[test]
fn partitioned_destination_fails_the_flow_on_both_fidelities() {
    // 1x2 mesh: one undirected link is the only route.  Killing both
    // directed halves partitions node 1; the in-flight flow must be
    // dropped by `apply_fault` (no reroute exists) and never complete.
    let run = |mut engine: Box<dyn NetworkSim>| {
        let id = engine.inject(FlowSpec { src: 0, dst: 1, bytes: 4096 }, 0);
        let topo = mesh(1, 2, &LinkParams::default());
        let down = vec![true; topo.links.len()];
        let mut masked = topo.clone();
        masked.apply_link_mask(&down);
        assert_eq!(masked.hops(0, 1), None, "destination must be partitioned");
        assert_eq!(masked.path(0, 1), None);
        let dropped = engine.apply_fault(&masked, &down);
        assert_eq!(
            dropped,
            vec![(id, FlowSpec { src: 0, dst: 1, bytes: 4096 })],
            "the crossing flow must be handed back for abort"
        );
        assert!(
            engine.advance_until(TimeNs::MAX).is_none(),
            "a dropped flow must never complete"
        );
    };
    let topo = mesh(1, 2, &LinkParams::default());
    run(Box::new(PacketEngine::new(topo.clone())));
    run(Box::new(FlitEngine::new(topo)));
}

// ------------------------------------------- fleet board-crash recovery

#[test]
fn fleet_single_board_crash_recovers_and_conserves_requests() {
    // 4 boards at a rate 3 survivors can absorb; board 1 crashes at 6 ms
    // of a 15 ms horizon.  The dispatcher must migrate its queued work,
    // retry its in-flight requests, conserve every offered request, and
    // keep goodput at >= (N-1)/N of the healthy baseline.
    let spec = light_spec(6_000.0, 15.0);
    let seed = 0xB0A2D;
    let run = |plan: Option<FaultPlan>, threads: usize| {
        Fleet::new(
            FleetSpec::new(spec.clone(), 4).threads(threads).faults(plan),
            || board(NocFidelity::Packet, None),
            parse_routing("least-outstanding").unwrap(),
        )
        .run(seed)
        .unwrap()
    };
    let healthy = run(None, 1);
    assert!(healthy.fault.is_none());
    assert!(healthy.goodput_rps() > 0.0);

    let plan = FaultPlan::parse("board:1@6ms, retry=3:200us:2ms:20ms").unwrap();
    let crashed = run(Some(plan.clone()), 1);
    let f = crashed.fault.as_ref().expect("board crash must attach a FaultReport");
    assert!(crashed.replicas[1].crashed, "board 1 must be marked crashed");
    assert_eq!(crashed.replicas.iter().filter(|r| r.crashed).count(), 1);
    assert!(f.injected >= 1);
    assert!(f.timeline.iter().any(|e| e.kind == "board" && e.target == 1 && !e.up));
    assert!(f.availability > 0.0 && f.availability < 1.0, "one dead board of four");
    // Aborted in-flight work was retried, and anything dropped was
    // dropped by exhausting the policy, not lost.
    assert!(f.retries >= f.recovered);
    // Request conservation: every pulled request completed, finished
    // inside warm-up, or was counted dropped.
    assert_eq!(
        crashed.offered,
        crashed.global.completed() + crashed.global.warmup_skipped + crashed.global.dropped,
        "requests were silently lost across the crash"
    );
    assert_eq!(
        healthy.offered,
        healthy.global.completed() + healthy.global.warmup_skipped + healthy.global.dropped,
    );
    // Graceful degradation: 3 surviving boards keep at least 3/4 of the
    // healthy goodput at this (sub-saturation) rate.
    assert!(
        crashed.goodput_rps() >= 0.75 * healthy.goodput_rps(),
        "goodput under crash {:.0} req/s < 75% of healthy {:.0} req/s",
        crashed.goodput_rps(),
        healthy.goodput_rps()
    );
    // And the whole crash-migrate-retry pipeline stays thread-deterministic.
    let crashed4 = run(Some(plan), 4);
    assert_eq!(
        crashed.fingerprint(),
        crashed4.fingerprint(),
        "worker thread count changed the faulted fleet outcome"
    );
    assert_eq!(
        f.fingerprint(),
        crashed4.fault.as_ref().unwrap().fingerprint(),
        "worker thread count changed the FaultReport"
    );
}
