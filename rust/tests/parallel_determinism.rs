//! PR 10 determinism gate: the parallel sharded NoI core is
//! *byte-identical* to the sequential engines — the same report
//! fingerprints for `--threads 1/2/8` on both fidelities, with an
//! active fault plan, through both `ExecSpec` seams (builder `.exec()`
//! and post-build `set_exec`), and when whole runs execute inside an
//! outer worker pool (the `SweepRunner` batch case), where nested
//! parallelism must be suppressed, not stacked.

use chipsim::config::{HardwareConfig, NocFidelity, SimParams};
use chipsim::fault::FaultPlan;
use chipsim::par::{ExecSpec, Partitioner};
use chipsim::scenario::{Registry, SweepRunner};
use chipsim::serving::{ArrivalSpec, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::util::pool::WorkerPool;
use chipsim::workload::ModelKind;

fn serving_params(fidelity: NocFidelity) -> SimParams {
    SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        noc_fidelity: fidelity,
        ..SimParams::default()
    }
}

fn board(
    fidelity: NocFidelity,
    exec: ExecSpec,
    plan: Option<FaultPlan>,
) -> Simulation {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(serving_params(fidelity))
        .exec(exec)
        .faults(plan)
        .build()
        .expect("valid board")
}

/// Single-kind load keeps debug-build runs fast (same idiom as the
/// serving/fleet/fault suites).
fn light_spec(rate: f64, horizon_ms: f64) -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(rate).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(horizon_ms)
        .warmup_ms(2.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None)
}

// ------------------------------------------------- threads 1/2/8 identity

#[test]
fn traffic_fingerprints_identical_across_thread_counts() {
    for fidelity in [NocFidelity::Packet, NocFidelity::Flit] {
        let spec = light_spec(1_200.0, 8.0);
        let base = board(fidelity, ExecSpec::sequential(), None)
            .run_traffic_with(&spec, 0x9A27)
            .unwrap();
        assert!(base.stats.completed() > 0, "workload must exercise the NoI");
        for threads in [2, 8] {
            let r = board(fidelity, ExecSpec::threads(threads), None)
                .run_traffic_with(&spec, 0x9A27)
                .unwrap();
            assert_eq!(
                base.fingerprint(),
                r.fingerprint(),
                "{fidelity:?} run diverged at --threads {threads}"
            );
        }
    }
}

// ------------------------------------------------- with a live fault plan

#[test]
fn fault_plan_armed_runs_identical_across_thread_counts() {
    // A link flap that fires (and repairs) inside the horizon, so the
    // parallel engine's apply_fault purge path executes, not just the
    // steady-state stepping.  Nodes 14-15 are row-adjacent on the 6x6.
    let plan = FaultPlan::parse("link:14-15@2ms+1ms").unwrap();
    let spec = light_spec(1_200.0, 8.0);
    let run = |exec: ExecSpec| {
        board(NocFidelity::Flit, exec, Some(plan.clone()))
            .run_traffic_with(&spec, 0xFA17)
            .unwrap()
    };
    let base = run(ExecSpec::sequential());
    let f = base.sim.fault.as_ref().expect("plan must fire inside the horizon");
    assert!(f.injected >= 1 && f.repairs >= 1);
    for threads in [2, 8] {
        let r = run(ExecSpec::threads(threads));
        assert_eq!(
            base.fingerprint(),
            r.fingerprint(),
            "faulted flit run diverged at --threads {threads}"
        );
        assert_eq!(
            f.fingerprint(),
            r.sim.fault.as_ref().expect("fault fires at any thread count").fingerprint(),
            "FaultReport diverged at --threads {threads}"
        );
    }
}

// ---------------------------------------- decomposition/lookahead knobs

#[test]
fn partitioner_and_lookahead_variants_do_not_perturb_results() {
    let spec = light_spec(1_000.0, 6.0);
    let base = board(NocFidelity::Flit, ExecSpec::sequential(), None)
        .run_traffic_with(&spec, 77)
        .unwrap();
    for exec in [
        ExecSpec::threads(3).with_partitioner(Partitioner::Stripes(5)),
        ExecSpec::threads(2).with_lookahead(1),
        // Over-large lookahead must be clamped to the safe bound, never
        // honoured.
        ExecSpec::threads(4).with_lookahead(1_000_000),
        // 0 = all cores, whatever this host has.
        ExecSpec::threads(0),
    ] {
        let r = board(NocFidelity::Flit, exec, None).run_traffic_with(&spec, 77).unwrap();
        assert_eq!(base.fingerprint(), r.fingerprint(), "diverged under {exec:?}");
    }
}

// ------------------------------------------------------- both exec seams

#[test]
fn builder_exec_and_post_build_set_exec_are_equivalent() {
    let spec = light_spec(1_000.0, 6.0);
    let via_builder = board(NocFidelity::Flit, ExecSpec::threads(4), None)
        .run_traffic_with(&spec, 41)
        .unwrap();
    let mut sim = board(NocFidelity::Flit, ExecSpec::sequential(), None);
    sim.set_exec(ExecSpec::threads(4));
    let via_setter = sim.run_traffic_with(&spec, 41).unwrap();
    assert_eq!(via_builder.fingerprint(), via_setter.fingerprint());
}

// ------------------------------------------- nested under an outer pool

#[test]
fn parallel_runs_inside_an_outer_pool_stay_identical() {
    // A sharded run launched from a pool worker (the SweepRunner /
    // fleet shape) must run its regions inline — and still produce the
    // exact sequential fingerprint.
    let spec = light_spec(1_000.0, 6.0);
    let base = board(NocFidelity::Flit, ExecSpec::threads(4), None)
        .run_traffic_with(&spec, 5)
        .unwrap()
        .fingerprint();
    let out = WorkerPool::new(3).map_catching(3, |_| {
        board(NocFidelity::Flit, ExecSpec::threads(4), None)
            .run_traffic_with(&spec, 5)
            .unwrap()
            .fingerprint()
    });
    for r in out {
        assert_eq!(r.unwrap(), base, "nested run diverged from the direct one");
    }
}

#[test]
fn sweep_runner_batches_are_thread_invariant() {
    // The whole batch path: identical seeds must yield byte-identical
    // SimReports whether scenarios run sequentially or across the
    // shared worker pool.
    let reg = Registry::builtin();
    let names = ["mesh-6x6-quickstart", "hetero-mesh"];
    let run = |threads: usize| SweepRunner::new().threads(threads).run(&reg, &names).unwrap();
    let a = run(1);
    let b = run(4);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.seed, y.seed);
        let (rx, ry) = (x.result.as_ref().unwrap(), y.result.as_ref().unwrap());
        assert_eq!(
            rx.fingerprint(),
            ry.fingerprint(),
            "batch scenario '{}' diverged across pool sizes",
            x.scenario
        );
    }
}
