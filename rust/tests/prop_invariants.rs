//! Property-based tests over the coordinator's invariants (routing,
//! batching/mapping, network conservation, event-loop state) using the
//! in-tree propkit driver.  Replay a failure with
//! `CHIPSIM_PROP_SEED=<seed> cargo test --test prop_invariants`.

use chipsim::config::{HardwareConfig, LinkParams, SimParams, WorkloadConfig};
use chipsim::mapping::{MemoryLedger, NearestNeighborMapper};
use chipsim::noc::engine::PacketEngine;
use chipsim::noc::topology::{custom, floret, mesh, Topology};
use chipsim::noc::{FlowSpec, NetworkSim};
use chipsim::prop_assert;
use chipsim::sim::Simulation;
use chipsim::util::propkit::check;
use chipsim::util::rng::Rng;
use chipsim::workload::{ModelKind, NeuralModel, ALL_CNNS};

/// Shared builder-API assembly for this target.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid test configuration")
}

// ------------------------------------------------------------- routing

#[test]
fn prop_mesh_routes_are_minimal_and_loop_free() {
    check("mesh-minimal-routes", 40, |rng| {
        let rows = 2 + rng.below_usize(9);
        let cols = 2 + rng.below_usize(9);
        let t = mesh(rows, cols, &LinkParams::default());
        let s = rng.below_usize(rows * cols);
        let d = rng.below_usize(rows * cols);
        if s == d {
            return Ok(());
        }
        let path = t.path(s, d).expect("mesh is connected");
        let manhattan =
            (s / cols).abs_diff(d / cols) + (s % cols).abs_diff(d % cols);
        prop_assert!(
            path.len() == manhattan,
            "path {} != manhattan {} for {s}->{d} in {rows}x{cols}",
            path.len(),
            manhattan
        );
        // Loop-free: no node repeats.
        let mut seen = std::collections::HashSet::new();
        seen.insert(s);
        let mut cur = s;
        for &l in &path {
            cur = t.links[l].dst;
            prop_assert!(seen.insert(cur), "routing loop at node {cur}");
        }
        prop_assert!(cur == d);
        Ok(())
    });
}

#[test]
fn prop_floret_all_pairs_reachable() {
    check("floret-reachability", 25, |rng| {
        let rows = 3 + rng.below_usize(8);
        let cols = 3 + rng.below_usize(8);
        let petals = 1 + rng.below_usize(12);
        let t = floret(rows, cols, petals, &LinkParams::default());
        let n = rows * cols;
        let s = rng.below_usize(n);
        let d = rng.below_usize(n);
        if s != d {
            let path = t.path(s, d).expect("floret is connected");
            prop_assert!(!path.is_empty());
            prop_assert!(path.len() < 2 * n, "path absurdly long: {}", path.len());
        }
        Ok(())
    });
}

#[test]
fn prop_random_connected_topology_routes() {
    check("custom-topology-routes", 25, |rng| {
        let n = 3 + rng.below_usize(20);
        // Random spanning tree + extra edges => connected by construction.
        let mut links = Vec::new();
        for v in 1..n {
            links.push((v, rng.below_usize(v)));
        }
        for _ in 0..rng.below_usize(n) {
            let a = rng.below_usize(n);
            let b = rng.below_usize(n);
            if a != b {
                links.push((a, b));
            }
        }
        let t = custom(n, &links, &LinkParams::default());
        for s in 0..n {
            for d in 0..n {
                if s != d {
                    prop_assert!(
                        t.path(s, d).is_some_and(|p| !p.is_empty()),
                        "no path {s}->{d}"
                    );
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- network

#[test]
fn prop_network_conserves_flows_and_energy() {
    check("packet-engine-conservation", 30, |rng| {
        let rows = 2 + rng.below_usize(6);
        let cols = 2 + rng.below_usize(6);
        let topo = mesh(rows, cols, &LinkParams::default());
        let mut e = PacketEngine::new(topo.clone());
        let n_flows = 1 + rng.below_usize(30);
        let mut expected_energy = 0.0;
        let mut ids = Vec::new();
        for _ in 0..n_flows {
            let src = rng.below_usize(rows * cols);
            let dst = rng.below_usize(rows * cols);
            let bytes = 1 + rng.below(100_000);
            let at = rng.below(10_000);
            ids.push(e.inject(FlowSpec { src, dst, bytes }, at));
            expected_energy += bytes as f64 * topo.hops(src, dst).unwrap_or(0) as f64 * 1.2;
        }
        let mut completions = 0;
        let mut last_time = 0;
        while let Some(c) = e.advance_until(u64::MAX) {
            completions += 1;
            prop_assert!(c.time >= last_time, "completions out of order");
            last_time = c.time;
        }
        prop_assert!(completions == n_flows, "{completions} != {n_flows} flows completed");
        prop_assert!(!e.has_active(), "engine still active after drain");
        // Energy: packet padding books the padded flit bytes per hop, so
        // booked >= exact payload energy and within one flit per packet-hop.
        let booked = e.comm_energy_pj();
        prop_assert!(
            booked >= expected_energy - 1e-6,
            "energy under-booked: {booked} < {expected_energy}"
        );
        for id in ids {
            let s = e.stats(id).unwrap();
            prop_assert!(s.completed_ns >= s.injected_ns);
        }
        Ok(())
    });
}

#[test]
fn prop_adding_background_traffic_never_speeds_a_flow() {
    check("contention-monotonicity", 20, |rng| {
        let topo = mesh(4, 4, &LinkParams::default());
        let src = rng.below_usize(16);
        let mut dst = rng.below_usize(16);
        if dst == src {
            dst = (dst + 1) % 16;
        }
        let probe = FlowSpec { src, dst, bytes: 8_192 };
        let solo = {
            let mut e = PacketEngine::new(topo.clone());
            let id = e.inject(probe, 0);
            while e.advance_until(u64::MAX).is_some() {}
            e.stats(id).unwrap().latency_ns()
        };
        let busy = {
            let mut e = PacketEngine::new(topo.clone());
            let id = e.inject(probe, 0);
            for _ in 0..rng.below_usize(12) {
                let s = rng.below_usize(16);
                let d = rng.below_usize(16);
                e.inject(FlowSpec { src: s, dst: d, bytes: 1 + rng.below(50_000) }, 0);
            }
            while e.advance_until(u64::MAX).is_some() {}
            e.stats(id).unwrap().latency_ns()
        };
        prop_assert!(busy >= solo, "background traffic sped up a flow: {busy} < {solo}");
        Ok(())
    });
}

// ------------------------------------------------------------- mapping

#[test]
fn prop_mapping_respects_capacity_and_restores_on_release() {
    check("mapping-ledger-invariants", 30, |rng| {
        let rows = 3 + rng.below_usize(8);
        let cols = 3 + rng.below_usize(8);
        let hw = HardwareConfig::homogeneous_mesh(rows, cols);
        let topo = Topology::build(&hw);
        let mut ledger = MemoryLedger::new(&hw);
        let initial = ledger.total_free();
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let mut mappings = Vec::new();
        for _ in 0..1 + rng.below_usize(6) {
            let kind = *rng.choice(&ALL_CNNS);
            if let Some(m) = mapper.try_map(&NeuralModel::build(kind), &mut ledger) {
                // Every layer fully covered by fractions.
                for layer in &m.layers {
                    let fsum: f64 = layer.iter().map(|s| s.frac).sum();
                    prop_assert!((fsum - 1.0).abs() < 1e-9, "fracs sum to {fsum}");
                }
                mappings.push(m);
            }
        }
        // No chiplet over-committed.
        for c in 0..hw.num_chiplets() {
            prop_assert!(ledger.free_bytes(c) <= ledger.capacity(c));
        }
        for m in &mappings {
            ledger.release_mapping(m);
        }
        prop_assert!(
            ledger.total_free() == initial,
            "ledger not restored: {} != {initial}",
            ledger.total_free()
        );
        Ok(())
    });
}

// ----------------------------------------------------------- event loop

#[test]
fn prop_cosim_conserves_models_and_time_is_monotone() {
    check("cosim-conservation", 8, |rng| {
        let hw = HardwareConfig::homogeneous_mesh(6 + rng.below_usize(3), 6 + rng.below_usize(3));
        let n = 2 + rng.below_usize(6);
        let inferences = 1 + rng.below(3) as u32;
        let params = SimParams {
            pipelined: rng.chance(0.5),
            inferences_per_model: inferences,
            warmup_ns: 0,
            cooldown_ns: 0,
            seed: rng.next_u64(),
            ..SimParams::default()
        };
        let report = sim(hw, params)
            .run(WorkloadConfig::cnn_stream(n, inferences, rng.next_u64()))
            .unwrap();
        prop_assert!(
            report.outcomes.len() + report.dropped.len() == n,
            "models lost: {} + {} != {n}",
            report.outcomes.len(),
            report.dropped.len()
        );
        for o in &report.outcomes {
            prop_assert!(o.inference_latency_ns.len() == inferences as usize);
            prop_assert!(o.mapped_ns >= o.arrival_ns);
            prop_assert!(o.finished_ns >= o.mapped_ns);
            prop_assert!(o.finished_ns <= report.span_ns);
            for &lat in &o.inference_latency_ns {
                prop_assert!(lat > 0, "zero-latency inference");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_power_bins_conserve_booked_energy() {
    check("power-conservation", 6, |rng| {
        let hw = HardwareConfig::homogeneous_mesh(6, 6);
        let params = SimParams {
            pipelined: true,
            inferences_per_model: 2,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        let report = sim(hw.clone(), params)
            .run(WorkloadConfig::cnn_stream(3, 2, rng.next_u64()))
            .unwrap();
        // Dynamic energy in bins == compute + comm energy booked.
        let binned: f64 =
            (0..hw.num_chiplets()).map(|c| report.power.dynamic_energy_pj(c)).sum();
        let booked = report.compute_energy_pj + report.comm_energy_pj;
        let rel = (binned - booked).abs() / booked.max(1.0);
        prop_assert!(rel < 1e-6, "power bins lost energy: {binned} vs {booked}");
        Ok(())
    });
}

#[test]
fn prop_cosim_deterministic_for_same_seed() {
    check("cosim-determinism", 4, |rng| {
        let seed = rng.next_u64();
        let run = || {
            let hw = HardwareConfig::homogeneous_mesh(6, 6);
            let params = SimParams {
                pipelined: true,
                inferences_per_model: 2,
                warmup_ns: 0,
                cooldown_ns: 0,
                ..SimParams::default()
            };
            sim(hw, params)
                .run(WorkloadConfig::cnn_stream(4, 2, seed))
                .unwrap()
        };
        let (a, b) = (run(), run());
        prop_assert!(a.span_ns == b.span_ns, "span differs");
        prop_assert!(a.noc_work == b.noc_work, "noc work differs");
        Ok(())
    });
}

// -------------------------------------------------------------- hwemu

#[test]
fn prop_hwemu_more_ccds_never_faster_per_trace() {
    check("hwemu-contention-monotone", 12, |rng| {
        let bytes = 1_000_000 + rng.below(500_000_000);
        let trace = vec![chipsim::hwemu::Phase::Load(bytes)];
        let solo = chipsim::hwemu::emulate(&[trace.clone()])[0];
        let k = 2 + rng.below_usize(7);
        let many: Vec<_> = (0..k).map(|_| trace.clone()).collect();
        let crowd = chipsim::hwemu::emulate(&many)[0];
        prop_assert!(crowd >= solo - 1.0, "more CCDs made a load faster");
        Ok(())
    });
}

#[test]
fn prop_workload_stream_reproducible() {
    check("stream-reproducible", 20, |rng| {
        let seed = rng.next_u64();
        let a = WorkloadConfig::cnn_stream(20, 5, seed);
        let b = WorkloadConfig::cnn_stream(20, 5, seed);
        prop_assert!(a.kinds == b.kinds);
        // All four kinds eventually appear for most seeds with n=20; only
        // require non-degeneracy (at least 2 distinct kinds).
        let distinct: std::collections::HashSet<ModelKind> = a.kinds.iter().copied().collect();
        prop_assert!(distinct.len() >= 2, "degenerate stream");
        Ok(())
    });
}
