//! Integration tests for closed-loop dynamic thermal management:
//! per-seed determinism (including sensor noise), the thermal-ceiling
//! guarantee of the threshold governor against the uncontrolled NoOp
//! baseline, and the streaming-thermal regression (drained power windows
//! must reach the thermal solve, not just the live tail).

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::dtm::{GovernorSpec, SensorSpec};
use chipsim::scenario::Registry;
use chipsim::serving::{ArrivalSpec, StreamingSource, TrafficReport, TrafficSpec};
use chipsim::sim::{BatchSource, NullSink, RequestSource, Simulation, ThermalSpec};
use chipsim::thermal::consts::T_AMBIENT;
use chipsim::workload::ModelKind;

fn serving_params() -> SimParams {
    SimParams { pipelined: true, warmup_ns: 0, cooldown_ns: 0, ..SimParams::default() }
}

/// A hot, saturating load: more offered work than a 4x4 mesh serves, so
/// chiplets stay as busy as the NoI allows for the whole horizon.
fn hot_spec() -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(5_000.0).kinds(&[ModelKind::ResNet18]).inferences(2))
        .horizon_ms(20.0)
        .warmup_ms(0.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None)
}

fn run_dtm(governor: GovernorSpec, window_ns: u64, seed: u64) -> TrafficReport {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(4, 4))
        .params(serving_params())
        .thermal(ThermalSpec::InLoop { window_ns, governor })
        .build()
        .expect("valid configuration")
        .run_traffic_with(&hot_spec(), seed)
        .expect("traffic run")
}

#[test]
fn threshold_throttle_caps_temperature_where_noop_exceeds_it() {
    // Self-calibrating ceiling: measure the uncontrolled excursion above
    // ambient, then place the ceiling at 60 % of it and the hysteresis
    // band below that.  NoOp exceeds the ceiling by construction; the
    // throttle governor must stay under it.
    let noop = run_dtm(GovernorSpec::noop(1_000.0).sensors(SensorSpec::ideal()), 50_000, 9);
    let noop_dtm = noop.dtm().expect("dtm report");
    let rise = noop_dtm.peak_c - T_AMBIENT;
    assert!(
        rise > 0.05,
        "calibration workload too cold to discriminate: peak {:.3} °C",
        noop_dtm.peak_c
    );
    let ceiling = T_AMBIENT + 0.6 * rise;
    assert!(noop_dtm.peak_c > ceiling, "uncontrolled run must exceed the ceiling");

    let governor = GovernorSpec::threshold_band(
        T_AMBIENT + 0.30 * rise, // hot: start throttling well under the ceiling
        T_AMBIENT + 0.15 * rise, // cold: release with hysteresis
        ceiling,
    )
    .sensors(SensorSpec::ideal());
    let capped = run_dtm(governor, 50_000, 9);
    let capped_dtm = capped.dtm().expect("dtm report");
    assert!(
        capped_dtm.peak_c < ceiling,
        "throttle must cap the hottest chiplet: peak {:.3} °C !< ceiling {:.3} °C \
         (noop peaked at {:.3} °C)",
        capped_dtm.peak_c,
        ceiling,
        noop_dtm.peak_c
    );
    assert_eq!(capped_dtm.ceiling_violations, 0);
    assert!(capped_dtm.throttle_residency > 0.0, "the governor must actually throttle");
    assert!(capped_dtm.transitions > 0);
    // The thermal win costs serving capacity: the throttled run cannot
    // complete more work than the uncontrolled one.
    assert!(capped.stats.completed() <= noop.stats.completed());
}

#[test]
fn dtm_scenarios_are_byte_identical_per_seed_including_sensor_noise() {
    let reg = Registry::builtin();
    for name in ["dtm-thermal-ceiling", "dtm-throttle-slo"] {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing builtin '{name}'"));
        let a = sc.run_traffic(21).expect("dtm traffic run");
        let b = sc.run_traffic(21).expect("dtm traffic run");
        let (da, db) = (a.dtm().expect("dtm report"), b.dtm().expect("dtm report"));
        assert_eq!(da.fingerprint(), db.fingerprint(), "{name}: DtmReport must match");
        assert_eq!(a.fingerprint(), b.fingerprint(), "{name}: TrafficReport must match");
        assert!(da.windows > 0 && da.steps > 0, "{name}: the control loop must have run");
        // A different arrival seed must show up in the thermal trace.
        let c = sc.run_traffic(22).expect("dtm traffic run");
        assert_ne!(
            da.fingerprint(),
            c.dtm().expect("dtm report").fingerprint(),
            "{name}: seed must matter"
        );
    }
}

#[test]
fn streaming_thermal_covers_drained_windows_not_just_the_tail() {
    // Regression for the pre-DTM bug: a traffic run drained power
    // windows as time advanced, and the post-run thermal solve then only
    // saw the live tail.  The incremental stepper must make a streaming
    // run's thermal summary match a batch run over the identical request
    // stream (same bins, same stride grouping, same step sequence).
    let hw = HardwareConfig::homogeneous_mesh(4, 4);
    let spec = TrafficSpec::new(ArrivalSpec::poisson(2_000.0).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(8.0)
        .warmup_ms(0.0)
        .window_ms(2.0) // 2000 bins per drain, a whole multiple of the stride
        .slo_ms(2.0)
        .steady(None);
    let thermal = ThermalSpec::Native { stride_bins: 20 };
    let seed = 77;

    let streaming = Simulation::builder()
        .hardware(hw.clone())
        .params(serving_params())
        .thermal(thermal.clone())
        .build()
        .unwrap()
        .run_traffic_with(&spec, seed)
        .unwrap();
    assert!(
        streaming.sim.power.drained_bins() > 0,
        "test premise: the traffic run must have drained windows"
    );

    // Batch reference: the same requests through the same event loop,
    // with every power bin kept live until the end-of-run solve.
    let mut source =
        StreamingSource::new(spec.arrivals.build(seed).unwrap(), spec.horizon_ns);
    let mut requests = Vec::new();
    while let Some(r) = source.next_request() {
        requests.push(r);
    }
    let batch = Simulation::builder()
        .hardware(hw)
        .params(serving_params())
        .thermal(thermal)
        .build()
        .unwrap()
        .run_with(&mut BatchSource::new(requests), &mut NullSink)
        .unwrap();
    assert_eq!(batch.power.drained_bins(), 0, "batch reference must not drain");
    assert_eq!(streaming.sim.span_ns, batch.span_ns, "identical event streams expected");

    let th_stream = streaming.sim.thermal.as_ref().expect("streaming thermal summary");
    let th_batch = batch.thermal.as_ref().expect("batch thermal summary");
    assert_eq!(th_stream.steps, th_batch.steps, "both must integrate the whole horizon");
    assert!(
        (th_stream.hottest_c - th_batch.hottest_c).abs() < 1e-9,
        "hottest: streaming {} vs batch {}",
        th_stream.hottest_c,
        th_batch.hottest_c
    );
    assert!(
        (th_stream.coolest_c - th_batch.coolest_c).abs() < 1e-9,
        "coolest: streaming {} vs batch {}",
        th_stream.coolest_c,
        th_batch.coolest_c
    );
}
