//! Integration tests for the self-profiler (`chipsim::prof`): the
//! zero-perturbation guarantee (per-seed report fingerprints are
//! byte-identical with the profiler armed, on both NoC fidelities),
//! counter/report cross-checks (the flit-hop counter must reproduce the
//! engine's own work accounting), and structural invariants of the
//! collected [`ProfileReport`] (self ≤ total, children sum ≤ parent,
//! inferno-shaped collapsed lines).
//!
//! The profiler is process-global state, so every test serializes on
//! one lock and re-arms (which resets collection) before running.
#![cfg(feature = "prof")]

use std::sync::{Mutex, MutexGuard};

use chipsim::config::{HardwareConfig, NocFidelity, SimParams};
use chipsim::prof;
use chipsim::serving::{ArrivalSpec, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::workload::ModelKind;

/// Tests in one binary run concurrently; the profiler is global.
fn serialize() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn sim(fidelity: NocFidelity) -> Simulation {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(SimParams {
            pipelined: true,
            warmup_ns: 0,
            cooldown_ns: 0,
            noc_fidelity: fidelity,
            ..SimParams::default()
        })
        .build()
        .expect("valid configuration")
}

fn light_spec() -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(1_000.0).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(10.0)
        .warmup_ms(0.0)
        .window_ms(1.0)
        .slo_ms(2.0)
        .steady(None)
}

/// Nested scopes split elapsed time exactly: parent self + child total
/// == parent total, and the nesting path is recorded for the
/// flamegraph.
#[test]
fn nested_scopes_split_self_and_total() {
    let _g = serialize();
    prof::enable();
    {
        let _outer = prof::scope(prof::Subsystem::FleetDispatch);
        std::thread::sleep(std::time::Duration::from_millis(2));
        {
            let _inner = prof::scope(prof::Subsystem::Mapping);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let r = prof::snapshot(10_000_000).expect("enabled");
    prof::disable();
    let outer = r.subsystems.iter().find(|s| s.name == "fleet_dispatch").unwrap();
    let inner = r.subsystems.iter().find(|s| s.name == "mapping").unwrap();
    assert!(inner.total_ns <= outer.total_ns, "child cannot exceed parent");
    assert_eq!(
        outer.self_ns + inner.total_ns,
        outer.total_ns,
        "parent self + child total must equal parent total"
    );
    assert!(r.paths.iter().any(|p| p.stack == "chipsim;fleet_dispatch;mapping"));
    assert!(r.cpu_ns >= outer.total_ns);
    let share_sum: f64 = r.subsystems.iter().map(|s| s.share).sum();
    assert!(share_sum <= 1.0 + 1e-9, "shares sum {share_sum} > 1");
}

/// Counters accumulate across bumps and derive a rate against the
/// snapshot's wall-clock.
#[test]
fn counters_accumulate_and_rate() {
    let _g = serialize();
    prof::enable();
    prof::count(prof::Counter::FlitHops, 3);
    prof::count(prof::Counter::FlitHops, 4);
    let r = prof::snapshot(1_000_000_000).expect("enabled");
    prof::disable();
    let c = r.counters.iter().find(|c| c.name == "flit_hops").unwrap();
    assert_eq!(c.value, 7);
    assert!((c.per_s - 7.0).abs() < 1e-9);
}

/// Golden shape for the collapsed export: `frame;frame value` lines,
/// rooted at `chipsim`, nesting rendered left-to-right.
#[test]
fn collapsed_lines_are_inferno_shaped() {
    let _g = serialize();
    prof::enable();
    {
        let _a = prof::scope(prof::Subsystem::EventLoop);
        let _b = prof::scope(prof::Subsystem::FlitEngine);
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let r = prof::snapshot(1).expect("enabled");
    prof::disable();
    let folded = r.collapsed();
    assert!(!folded.is_empty());
    for line in folded.lines() {
        let (stack, value) = line.rsplit_once(' ').expect("`stack value` shape");
        assert!(stack.starts_with("chipsim"), "{line}");
        assert!(value.parse::<u64>().is_ok(), "{line}");
    }
    assert!(folded.contains("chipsim;event_loop;flit_engine "));
}

/// The JSON document carries the schema tag and every section.
#[test]
fn report_roundtrips_to_json() {
    let _g = serialize();
    prof::enable();
    {
        let _a = prof::scope(prof::Subsystem::EventLoop);
    }
    prof::count(prof::Counter::Events, 1);
    let r = prof::snapshot(1000).expect("enabled");
    prof::disable();
    let doc = r.to_json();
    assert_eq!(doc.get("schema").and_then(|v| v.as_str()).unwrap(), "chipsim-profile-v1");
    for section in ["subsystems", "counters", "workers", "paths", "collapsed"] {
        assert!(doc.get(section).and_then(|v| v.as_arr()).is_ok(), "missing '{section}'");
    }
}

/// The profiler observes; it must never steer.  Same seed, profiler off
/// vs armed: the serving fingerprint (which hashes every simulated
/// quantity, not host timings) must be byte-identical.
#[test]
fn profiling_does_not_perturb_packet_fidelity() {
    let _g = serialize();
    prof::disable();
    let baseline = sim(NocFidelity::Packet).run_traffic_with(&light_spec(), 42).unwrap();
    assert!(baseline.sim.profile.is_none(), "disabled profiler must not attach");
    prof::enable();
    let profiled = sim(NocFidelity::Packet).run_traffic_with(&light_spec(), 42).unwrap();
    prof::disable();
    assert_eq!(baseline.fingerprint(), profiled.fingerprint());
    assert!(profiled.sim.profile.is_some(), "armed profiler must attach its report");
}

/// Same guarantee on the cycle-stepped flit engine, whose inner loop is
/// the hottest hook site.
#[test]
fn profiling_does_not_perturb_flit_fidelity() {
    let _g = serialize();
    prof::disable();
    let baseline = sim(NocFidelity::Flit).run_traffic_with(&light_spec(), 7).unwrap();
    prof::enable();
    let profiled = sim(NocFidelity::Flit).run_traffic_with(&light_spec(), 7).unwrap();
    prof::disable();
    assert_eq!(baseline.fingerprint(), profiled.fingerprint());
}

/// The monotonic counters must agree with the simulator's own report:
/// every flit-hop moves one link-width of bytes, so `flit_hops x
/// width_bytes` must equal the engine's `noc_work` on a uniform-width
/// topology, and `requests_completed` must match the serving stats.
#[test]
fn counters_match_report_totals() {
    let _g = serialize();
    prof::enable();
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let width = hw.link.width_bytes;
    let report = sim(NocFidelity::Flit).run_traffic_with(&light_spec(), 0xFEED).unwrap();
    let hops = prof::counter_value(prof::Counter::FlitHops);
    let completed = prof::counter_value(prof::Counter::RequestsCompleted);
    let events = prof::counter_value(prof::Counter::Events);
    let sims = prof::counter_value(prof::Counter::SimsCompleted);
    prof::disable();
    assert!(hops > 0, "flit run must traverse links");
    assert_eq!(hops * width, report.sim.noc_work);
    assert_eq!(completed, report.stats.completed() + report.stats.warmup_skipped);
    assert!(events > 0, "event loop must process events");
    assert_eq!(sims, 1, "one finalized run");
}

/// Structural invariants of a real collected profile: per-subsystem
/// self ≤ total, shares in [0, 1] summing to ≤ 1, per-path children
/// totals bounded by their parent, and collapsed lines shaped for
/// inferno (`frame;frame value`).
#[test]
fn collected_profile_is_self_consistent() {
    let _g = serialize();
    prof::enable();
    let report = sim(NocFidelity::Packet).run_traffic_with(&light_spec(), 9).unwrap();
    prof::disable();
    let p = report.sim.profile.expect("armed profiler attaches");
    assert!(p.wall_ns > 0);
    assert!(!p.subsystems.is_empty(), "serving run exercises scoped subsystems");
    let mut share_sum = 0.0;
    for s in &p.subsystems {
        assert!(s.self_ns <= s.total_ns, "{}: self {} > total {}", s.name, s.self_ns, s.total_ns);
        assert!(s.calls > 0, "{}: listed but never entered", s.name);
        assert!((0.0..=1.0).contains(&s.share), "{}: share {}", s.name, s.share);
        share_sum += s.share;
    }
    assert!(share_sum <= 1.0 + 1e-9, "self-time shares sum to {share_sum}");
    // The event loop dominates a serving run and nests the rest.
    assert!(p.subsystems.iter().any(|s| s.name == "event_loop"));
    for parent in &p.paths {
        assert!(parent.self_ns <= parent.total_ns, "path {}", parent.stack);
        let child_prefix = format!("{};", parent.stack);
        let children_total: u64 = p
            .paths
            .iter()
            .filter(|q| {
                q.stack.starts_with(&child_prefix)
                    && !q.stack[child_prefix.len()..].contains(';')
            })
            .map(|q| q.total_ns)
            .sum();
        assert!(
            children_total <= parent.total_ns,
            "children of {} sum to {} > parent total {}",
            parent.stack,
            children_total,
            parent.total_ns
        );
    }
    for line in p.collapsed().lines() {
        let (stack, value) = line.rsplit_once(' ').expect("collapsed line has a value");
        assert!(stack.starts_with("chipsim"), "{line}");
        assert!(value.parse::<u64>().is_ok(), "{line}");
        for frame in stack.split(';').skip(1) {
            assert!(
                p.subsystems.iter().any(|s| s.name == frame),
                "unknown frame '{frame}' in {line}"
            );
        }
    }
}
