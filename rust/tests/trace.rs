//! Integration tests for the flight recorder: per-seed trace
//! determinism, latency-breakdown exactness on both NoI fidelities, and
//! the zero-perturbation guarantee (installing a recorder must not
//! change what the simulation computes).

use chipsim::config::{HardwareConfig, NocFidelity, SimParams, WorkloadConfig};
use chipsim::serving::{ArrivalSpec, TrafficSpec};
use chipsim::sim::Simulation;
use chipsim::trace::TraceConfig;
use chipsim::workload::ModelKind;

fn sim(fidelity: NocFidelity) -> Simulation {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(SimParams {
            pipelined: true,
            warmup_ns: 0,
            cooldown_ns: 0,
            noc_fidelity: fidelity,
            ..SimParams::default()
        })
        .build()
        .expect("valid configuration")
}

fn light_spec(horizon_ms: f64) -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(1_000.0).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(horizon_ms)
        .warmup_ms(0.0)
        .window_ms(1.0)
        .slo_ms(2.0)
        .steady(None)
}

/// Same seed, same spec, fresh recorders: the exported trace documents
/// must be byte-identical; a different seed must diverge.
#[cfg(feature = "trace")]
#[test]
fn trace_is_byte_identical_per_seed() {
    let spec = light_spec(10.0);
    let run = |seed: u64| {
        let mut s = sim(NocFidelity::Packet);
        let h = s.set_trace(TraceConfig::default());
        s.run_traffic_with(&spec, seed).unwrap();
        h.lock().unwrap().fingerprint()
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "trace must be byte-identical per seed");
    let c = run(43);
    assert_ne!(a, c, "seed must matter");
}

/// Every async `request` track in an exported trace balances its
/// begin/end events and ends in a terminal state.
#[cfg(feature = "trace")]
#[test]
fn every_request_reaches_a_terminal_state() {
    use chipsim::util::json::Value;
    use std::collections::HashMap;
    let mut s = sim(NocFidelity::Packet);
    let h = s.set_trace(TraceConfig::default());
    s.run_traffic_with(&light_spec(10.0), 0xFEED).unwrap();
    let doc = h.lock().unwrap().export();
    let events = match doc.get("traceEvents").unwrap() {
        Value::Arr(v) => v,
        _ => panic!("traceEvents must be an array"),
    };
    assert!(!events.is_empty(), "recorder traced nothing");
    // id -> (begins, ends, last end carries a state)
    let mut tracks: HashMap<String, (u32, u32, bool)> = HashMap::new();
    for ev in events {
        if ev.get("name").and_then(|n| n.as_str()) != Some("request") {
            continue;
        }
        let Some(id) = ev.get("id").and_then(|i| i.as_str()) else {
            continue;
        };
        let t = tracks.entry(id.to_string()).or_default();
        match ev.get("ph").and_then(|p| p.as_str()) {
            Some("b") => t.0 += 1,
            Some("e") => {
                t.1 += 1;
                t.2 = ev
                    .get("args")
                    .and_then(|a| a.get("state"))
                    .and_then(|s| s.as_str())
                    .is_some_and(|s| !s.is_empty());
            }
            _ => {}
        }
    }
    assert!(!tracks.is_empty(), "no request lifecycle tracks recorded");
    for (id, (b, e, terminal)) in &tracks {
        assert_eq!(b, e, "request {id}: begins and ends must balance");
        assert!(*terminal, "request {id}: final end must carry a terminal state");
    }
}

#[cfg(feature = "trace")]
fn assert_breakdowns_exact(fidelity: NocFidelity, models: usize, inferences: u32) {
    let mut s = sim(fidelity);
    let _h = s.set_trace(TraceConfig::default());
    let report = s.run(WorkloadConfig::cnn_stream(models, inferences, 0xC0FFEE)).unwrap();
    assert!(!report.outcomes.is_empty());
    for o in &report.outcomes {
        let bd = o.breakdown.as_ref().expect("breakdown enabled by default");
        assert_eq!(
            bd.total_ns(),
            o.finished_ns - o.arrival_ns,
            "request {}: components must sum exactly to end-to-end latency ({:?})",
            o.id,
            bd
        );
    }
}

#[cfg(feature = "trace")]
#[test]
fn breakdown_sums_exactly_on_packet_fidelity() {
    assert_breakdowns_exact(NocFidelity::Packet, 6, 2);
}

/// Smaller workload: flit fidelity simulates every flit-hop, which is
/// orders of magnitude more events per byte in debug test builds.
#[cfg(feature = "trace")]
#[test]
fn breakdown_sums_exactly_on_flit_fidelity() {
    assert_breakdowns_exact(NocFidelity::Flit, 2, 1);
}

/// Installing a recorder must not perturb the simulation: the report of
/// a traced run fingerprints bitwise-identically to a never-instrumented
/// one, on both the batch and the streaming-traffic paths.  (Holds with
/// and without the `trace` cargo feature — without it the hooks compile
/// out entirely.)
#[test]
fn tracing_does_not_perturb_the_simulation() {
    let wl = || WorkloadConfig::cnn_stream(6, 2, 0xC0FFEE);
    let plain = sim(NocFidelity::Packet).run(wl()).unwrap();
    let mut s = sim(NocFidelity::Packet);
    s.set_trace(TraceConfig::default());
    let traced = s.run(wl()).unwrap();
    assert_eq!(plain.fingerprint(), traced.fingerprint());

    let spec = light_spec(10.0);
    let plain = sim(NocFidelity::Packet).run_traffic_with(&spec, 7).unwrap();
    let mut s = sim(NocFidelity::Packet);
    s.set_trace(TraceConfig::default());
    let traced = s.run_traffic_with(&spec, 7).unwrap();
    assert_eq!(plain.fingerprint(), traced.fingerprint());
    assert_eq!(plain.offered, traced.offered);
}

/// Without the feature (or without a recorder) no breakdowns appear —
/// the observable surface stays identical to the pre-recorder era.
#[test]
fn no_recorder_means_no_breakdowns() {
    let report = sim(NocFidelity::Packet).run(WorkloadConfig::cnn_stream(3, 1, 1)).unwrap();
    assert!(report.outcomes.iter().all(|o| o.breakdown.is_none()));
}
