//! Integration tests for the fleet-scale serving subsystem: worker-pool
//! thread counts must not change a single bit of the report, a
//! one-replica fleet must reproduce the single-board traffic engine
//! exactly, autoscaling/migration must actually fire, and the headline
//! acceptance bar — a 4-replica least-outstanding fleet sustains at
//! least 3.5x the single-board saturation-knee goodput.

use chipsim::config::{HardwareConfig, SimParams};
use chipsim::dtm::GovernorSpec;
use chipsim::fleet::{parse_autoscaler, parse_routing, Fleet, FleetSpec};
use chipsim::serving::{ArrivalSpec, LoadSweep, TrafficSpec};
use chipsim::sim::{Simulation, ThermalSpec};
use chipsim::workload::ModelKind;

fn serving_params() -> SimParams {
    SimParams { pipelined: true, warmup_ns: 0, cooldown_ns: 0, ..SimParams::default() }
}

fn board() -> anyhow::Result<Simulation> {
    Simulation::builder()
        .hardware(HardwareConfig::homogeneous_mesh(6, 6))
        .params(serving_params())
        .build()
}

/// Single-kind load keeps debug-build runs fast (same idiom as the
/// serving tests).
fn light_spec(rate: f64, horizon_ms: f64) -> TrafficSpec {
    TrafficSpec::new(ArrivalSpec::poisson(rate).kinds(&[ModelKind::ResNet18]))
        .horizon_ms(horizon_ms)
        .warmup_ms(2.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None)
}

// --------------------------------------------------- thread determinism

#[test]
fn fleet_fingerprint_is_identical_across_worker_thread_counts() {
    // Bursty arrivals onto a fixed 3-board fleet: the parallel advance
    // must be invisible — 1 worker thread and 4 produce byte-identical
    // reports for the same seed.
    let spec =
        TrafficSpec::new(ArrivalSpec::on_off(8_000.0, 500.0, 2e6, 2e6).kinds(&[
            ModelKind::ResNet18,
        ]))
        .horizon_ms(10.0)
        .warmup_ms(2.0)
        .window_ms(2.0)
        .slo_ms(2.0)
        .steady(None);
    let run = |threads: usize| {
        Fleet::new(
            FleetSpec::new(spec.clone(), 3).threads(threads),
            board,
            parse_routing("round-robin").unwrap(),
        )
        .run(0xF1EE7)
        .unwrap()
    };
    let one = run(1);
    let many = run(4);
    assert!(one.global.completed() > 0, "fleet served nothing");
    assert_eq!(
        one.fingerprint(),
        many.fingerprint(),
        "worker thread count changed the fleet outcome"
    );
}

#[test]
fn autoscaling_fleet_is_thread_deterministic_too() {
    // Scale-ups/downs happen at barriers on frozen snapshots, so they
    // must also be independent of the worker pool size.
    let spec = TrafficSpec::new(
        ArrivalSpec::diurnal(5_000.0, 0.8, 6_000_000).kinds(&[ModelKind::ResNet18]),
    )
    .horizon_ms(12.0)
    .warmup_ms(2.0)
    .window_ms(2.0)
    .slo_ms(2.0)
    .steady(None);
    let run = |threads: usize| {
        Fleet::new(
            FleetSpec::new(spec.clone(), 2).max_replicas(5).threads(threads),
            board,
            parse_routing("least-outstanding").unwrap(),
        )
        .autoscaler(parse_autoscaler("queue:16").unwrap())
        .run(0xACE)
        .unwrap()
    };
    let one = run(1);
    let many = run(8);
    assert_eq!(one.fingerprint(), many.fingerprint());
    assert_eq!(one.scale_events, many.scale_events);
}

// ------------------------------------------------ single-board identity

#[test]
fn one_replica_round_robin_fleet_equals_the_single_board_engine() {
    // A fleet of one board behind round-robin is just the traffic engine
    // with extra bookkeeping: stats, offered count, and the board-level
    // simulation report must match `run_traffic_with` exactly.
    let spec = light_spec(1_500.0, 12.0);
    let seed = 42;
    let fleet = Fleet::new(
        FleetSpec::new(spec.clone(), 1),
        board,
        parse_routing("round-robin").unwrap(),
    )
    .run(seed)
    .unwrap();
    let single = board().unwrap().run_traffic_with(&spec, seed).unwrap();
    assert!(single.stats.completed() > 0);
    assert_eq!(fleet.offered, single.offered, "offered streams diverged");
    assert_eq!(
        fleet.replicas[0].stats.fingerprint(),
        single.stats.fingerprint(),
        "serving stats diverged"
    );
    assert_eq!(
        fleet.replicas[0].sim.fingerprint(),
        single.sim.fingerprint(),
        "board-level co-simulation diverged"
    );
    // The global merge of one replica is that replica.
    assert_eq!(fleet.global.fingerprint(), single.stats.fingerprint());
}

// ------------------------------------------------- autoscale / migrate

#[test]
fn queue_autoscaler_grows_the_fleet_under_overload() {
    // 8 krps into one 6x6 board (~3 krps capacity): the queue-depth
    // policy must scale up, and cold boards must not serve before their
    // ready time.
    let spec = light_spec(8_000.0, 15.0);
    let report = Fleet::new(
        FleetSpec::new(spec, 1).max_replicas(4),
        board,
        parse_routing("least-outstanding").unwrap(),
    )
    .autoscaler(parse_autoscaler("queue:16").unwrap())
    .run(0xBEEF)
    .unwrap();
    assert!(!report.scale_events.is_empty(), "overload never triggered a scale-up");
    assert!(report.peak_replicas() > 1);
    for r in &report.replicas {
        if r.ready_at > 0 && r.stats.completed() > 0 {
            // Every request served by a cold-started board finished
            // after the board was ready.
            assert!(r.sim.span_ns > 0);
        }
    }
    // Scale-ups actually carried load: the late boards served requests.
    let late_served: u64 =
        report.replicas.iter().filter(|r| r.ready_at > 0).map(|r| r.stats.completed()).sum();
    assert!(late_served > 0, "cold-started boards never served anything");
}

#[test]
fn thermal_emergency_migrates_queued_work_off_hot_boards() {
    // DTM boards under saturating load, with the migration threshold set
    // below the governor's ceiling so it trips while queues are non-empty.
    let dtm_board = || {
        Simulation::builder()
            .hardware(HardwareConfig::homogeneous_mesh(6, 6))
            .params(serving_params())
            .thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::threshold_band(47.0, 46.2, 48.0),
            })
            .build()
    };
    let spec = light_spec(9_000.0, 15.0);
    let run = |threads: usize| {
        Fleet::new(
            FleetSpec::new(spec.clone(), 3).emergency_c(46.0).threads(threads),
            dtm_board,
            parse_routing("thermal").unwrap(),
        )
        .run(0x7E47)
        .unwrap()
    };
    let report = run(1);
    assert!(report.global.completed() > 0);
    // Thermal telemetry flowed into the report.
    assert!(
        report.replicas.iter().any(|r| !r.temp_timeline.is_empty()),
        "in-loop boards must report temperatures"
    );
    // Migration bookkeeping is consistent even if the threshold never
    // tripped at a barrier with queued work.
    let out: u64 = report.replicas.iter().map(|r| r.migrated_out).sum();
    assert_eq!(out, report.migrations);
    // And the whole thing stays thread-deterministic with thermal state.
    assert_eq!(report.fingerprint(), run(4).fingerprint());
}

// --------------------------------------------------- acceptance scaling

#[test]
fn four_replica_fleet_sustains_3_5x_the_single_board_knee() {
    // Find the single-board saturation knee, then offer 4x that rate to
    // a 4-replica least-outstanding fleet: goodput must reach at least
    // 3.5x the single board's knee goodput.
    let spec = light_spec(1_000.0, 15.0);
    let sweep = LoadSweep::new(spec.clone(), 500.0, 6_000.0).iters(4);
    let result = sweep.run(|| board(), 7).unwrap();
    assert!(result.knee_rps > 0.0, "6x6 board must sustain something in range");
    let knee_goodput = result
        .probes
        .iter()
        .filter(|p| p.meets_slo)
        .map(|p| p.goodput_rps)
        .fold(0.0_f64, f64::max);
    assert!(knee_goodput > 0.0);

    let fleet_spec = TrafficSpec {
        arrivals: spec.arrivals.with_rate(4.0 * result.knee_rps).unwrap(),
        ..spec
    };
    let report = Fleet::new(
        FleetSpec::new(fleet_spec, 4),
        board,
        parse_routing("least-outstanding").unwrap(),
    )
    .run(7)
    .unwrap();
    assert!(
        report.goodput_rps() >= 3.5 * knee_goodput,
        "fleet goodput {:.0} req/s < 3.5x single-board knee goodput {:.0} req/s",
        report.goodput_rps(),
        knee_goodput
    );
}

// -------------------------------------------------------- LoadSweep probe

#[test]
fn load_sweep_probe_closure_drives_a_fleet() {
    // The knee bisection is system-agnostic: run_with_probe over a
    // 2-board fleet finds a knee at least as high as one board's.
    let spec = light_spec(1_000.0, 10.0);
    let single = LoadSweep::new(spec.clone(), 500.0, 8_000.0).iters(3).run(|| board(), 9).unwrap();
    let fleet = LoadSweep::new(spec, 500.0, 8_000.0).iters(3).run_with_probe(|probe_spec| {
        let report = Fleet::new(
            FleetSpec::new(probe_spec.clone(), 2),
            board,
            parse_routing("least-outstanding")?,
        )
        .run(9)?;
        Ok(report.global)
    })
    .unwrap();
    assert!(
        fleet.knee_rps >= single.knee_rps,
        "2 boards ({:.0} rps) must not saturate before 1 ({:.0} rps)",
        fleet.knee_rps,
        single.knee_rps
    );
}
