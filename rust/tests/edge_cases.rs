//! Edge-case and failure-injection tests across modules: degenerate
//! configurations, boundary timing, ViT weight-stationary start-up, sim
//! truncation, and serialization corner cases.

use chipsim::config::{
    HardwareConfig, LinkParams, SimParams, TopologyKind, WorkloadConfig,
};
use chipsim::noc::engine::PacketEngine;
use chipsim::noc::topology::{ccd_star, mesh, Topology};
use chipsim::noc::{FlowSpec, NetworkSim};
use chipsim::sim::Simulation;
use chipsim::workload::{ModelKind, NeuralModel};
use chipsim::TimeNs;

fn params(pipelined: bool, inf: u32) -> SimParams {
    SimParams {
        pipelined,
        inferences_per_model: inf,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    }
}

/// Shared builder-API assembly for this target.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid test configuration")
}

// ------------------------------------------------------------ NoC edges

#[test]
fn one_byte_flow_still_takes_a_flit() {
    let mut e = PacketEngine::new(mesh(1, 2, &LinkParams::default()));
    let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 1 }, 0);
    let c = e.advance_until(TimeNs::MAX).unwrap();
    assert_eq!(c.id, id);
    // hop(4) + 1 cycle serialization minimum.
    assert!(e.stats(id).unwrap().latency_ns() >= 5);
}

#[test]
fn zero_byte_flow_clamped_to_one() {
    let mut e = PacketEngine::new(mesh(1, 2, &LinkParams::default()));
    let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 0 }, 7);
    let c = e.advance_until(TimeNs::MAX).unwrap();
    assert_eq!(c.id, id);
    assert!(e.stats(id).unwrap().completed_ns > 7);
}

#[test]
fn tail_packet_smaller_serialization() {
    // 513 B = one full 512 B packet + one 1 B tail packet; the tail's
    // serialization must be 1 cycle, not a full packet.
    let mut e = PacketEngine::new(mesh(1, 2, &LinkParams::default()));
    let id = e.inject(FlowSpec { src: 0, dst: 1, bytes: 513 }, 0);
    while e.advance_until(TimeNs::MAX).is_some() {}
    let lat = e.stats(id).unwrap().latency_ns();
    // full packet: ser 16 + hop 4 = 20; tail starts at 16, +4+1 => 21.
    assert_eq!(lat, 21, "tail packet mis-serialized");
}

#[test]
fn ccd_star_read_faster_than_write() {
    // Asymmetric GMI3: IOD->CCD (32 B/cy) vs CCD->IOD (16 B/cy).
    let topo = ccd_star(8, &LinkParams { clock_ghz: 1.0, ..LinkParams::default() });
    let mut e = PacketEngine::new(topo.clone());
    let read = e.inject(FlowSpec { src: 8, dst: 0, bytes: 65536 }, 0);
    while e.advance_until(TimeNs::MAX).is_some() {}
    let t_read = e.stats(read).unwrap().latency_ns();
    let mut e2 = PacketEngine::new(topo);
    let write = e2.inject(FlowSpec { src: 0, dst: 8, bytes: 65536 }, 0);
    while e2.advance_until(TimeNs::MAX).is_some() {}
    let t_write = e2.stats(write).unwrap().latency_ns();
    assert!(
        t_write as f64 > 1.7 * t_read as f64,
        "write {t_write} should be ~2x read {t_read}"
    );
}

#[test]
fn single_node_topology_all_local() {
    let hw = HardwareConfig {
        rows: 1,
        cols: 1,
        chiplet_types: vec![chipsim::config::ChipletTypeParams::imc_type_a()],
        type_of: vec![0],
        topology: TopologyKind::Custom { links: vec![] },
        link: LinkParams::default(),
        io_chiplets: vec![],
    };
    let topo = Topology::build(&hw);
    assert_eq!(topo.num_nodes, 1);
    let mut e = PacketEngine::new(topo);
    let id = e.inject(FlowSpec { src: 0, dst: 0, bytes: 12345 }, 5);
    let c = e.advance_until(TimeNs::MAX).unwrap();
    assert_eq!((c.id, c.time), (id, 5));
}

// ------------------------------------------------------------ sim edges

#[test]
fn max_sim_time_truncates_cleanly() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let mut p = params(false, 50);
    p.max_sim_time_ns = 100_000; // 100 µs — far less than 50 inferences
    let report = sim(hw, p)
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    // Model won't finish; no outcome, but no panic/hang either.
    assert!(report.outcomes.is_empty());
    assert!(report.span_ns >= 100_000);
}

#[test]
fn zero_inference_model_is_noop_safe() {
    let hw = HardwareConfig::homogeneous_mesh(4, 4);
    let report = sim(hw, params(true, 1))
        .run(WorkloadConfig::from_kinds(&[]))
        .unwrap();
    assert!(report.outcomes.is_empty());
    assert!(report.dropped.is_empty());
}

#[test]
fn vit_weight_load_delays_first_inference() {
    // With I/O corners, the first inference can only start after the
    // 86 MB weight stream; compare against a no-IO mesh where layer 0
    // starts immediately.
    let with_io = HardwareConfig::vit_mesh(10, 10);
    let no_io = HardwareConfig::homogeneous_mesh(10, 10);
    let run = |hw: HardwareConfig| {
        sim(hw, params(true, 1))
            .run(WorkloadConfig::single(ModelKind::VitB16))
            .unwrap()
    };
    let a = run(with_io);
    let b = run(no_io);
    let total_io = a.outcomes[0].finished_ns - a.outcomes[0].mapped_ns;
    let total_plain = b.outcomes[0].finished_ns - b.outcomes[0].mapped_ns;
    assert!(
        total_io > total_plain + 100_000,
        "weight load not visible: {total_io} vs {total_plain}"
    );
}

#[test]
fn repeated_runs_do_not_leak_chiplet_state() {
    // Two sequential models on a tiny system: second must see all memory
    // returned by the first (regression guard for unmap accounting).
    let hw = HardwareConfig::homogeneous_mesh(4, 4);
    let report = sim(hw, params(false, 1))
        .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 4]))
        .unwrap();
    assert_eq!(report.outcomes.len(), 4);
    // Latency of the last should be in-family with the first (same system).
    let l0 = report.outcomes[0].mean_latency_ns();
    let l3 = report.outcomes[3].mean_latency_ns();
    assert!(l3 < l0 * 3.0, "state leak suspected: {l0} -> {l3}");
}

#[test]
fn warmup_cooldown_window_filters_stats() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let mut p = params(false, 1);
    p.warmup_ns = u64::MAX / 2; // absurd warmup: window empty
    let report = sim(hw, p)
        .run(WorkloadConfig::single(ModelKind::ResNet18))
        .unwrap();
    // Falls back to all instances instead of returning nothing.
    assert!(report.mean_latency_of(ModelKind::ResNet18).is_some());
}

// --------------------------------------------------------- config edges

#[test]
fn hardware_json_file_roundtrip_on_disk() {
    let hw = HardwareConfig::heterogeneous_mesh(4, 4);
    let path = std::env::temp_dir().join("chipsim_hw_test.json");
    std::fs::write(&path, chipsim::util::json::to_string_pretty(&hw.to_json())).unwrap();
    let back = HardwareConfig::load(path.to_str().unwrap()).unwrap();
    assert_eq!(back.type_of, hw.type_of);
    let _ = std::fs::remove_file(path);
}

#[test]
fn malformed_hw_json_rejected_with_context() {
    let path = std::env::temp_dir().join("chipsim_bad_hw.json");
    std::fs::write(&path, "{\"rows\": 2, \"cols\":").unwrap();
    let err = HardwareConfig::load(path.to_str().unwrap()).unwrap_err();
    assert!(format!("{err}").contains("parse"), "{err}");
    let _ = std::fs::remove_file(path);
}

#[test]
fn custom_topology_from_json() {
    let text = r#"{"kind": "custom", "links": [[0,1],[1,2]]}"#;
    let hw_json = chipsim::util::json::parse(&format!(
        r#"{{"rows":1,"cols":3,
            "chiplet_types":[{{"name":"t","class":"imc","mem_bytes":1048576,
             "mac_rate_gops":100,"e_mac_pj":1,"e_adc_pj":1,
             "t_adc_ns_per_elem":0.01,"base_latency_ns":10,"leak_mw":1,
             "idle_mw":1,"width_mm":2,"height_mm":2}}],
            "type_of":[0,0,0],
            "topology":{text},
            "link":{{"width_bytes":32,"clock_ghz":1,"hop_latency_cycles":4,
                     "e_per_byte_pj":1,"router_static_mw":1}},
            "io_chiplets":[]}}"#
    ))
    .unwrap();
    let hw = HardwareConfig::from_json(&hw_json).unwrap();
    let topo = Topology::build(&hw);
    assert_eq!(topo.hops(0, 2), Some(2));
}

// ------------------------------------------------------- workload edges

#[test]
fn all_models_have_monotone_spatial_dims() {
    // Activation volumes must never grow through pooling, and first-layer
    // input must match 224x224x3 for the CNNs.
    for kind in chipsim::workload::ALL_CNNS {
        let m = NeuralModel::build(kind);
        assert_eq!(m.layers[0].in_bytes, 224 * 224 * 3, "{kind:?}");
        for l in &m.layers {
            assert!(l.out_bytes > 0 && l.macs > 0, "{kind:?}/{}", l.name);
        }
    }
}

#[test]
fn traffic_generator_consistency() {
    // Bytes leaving layer i must equal layer i+1's declared input.
    for kind in chipsim::workload::ALL_CNNS {
        let m = NeuralModel::build(kind);
        for w in m.layers.windows(2) {
            assert_eq!(
                w[0].out_bytes, w[1].in_bytes,
                "{kind:?}: {} -> {}",
                w[0].name, w[1].name
            );
        }
    }
}
