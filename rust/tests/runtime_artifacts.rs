//! PJRT runtime + AOT artifact integration: load the HLO-text artifacts
//! produced by `make artifacts`, execute them, and cross-check against
//! the in-process oracles.  These tests are skipped (with a notice) when
//! artifacts have not been built.

use chipsim::compute::{AnalyticalImc, ComputeBackend, SegmentWork};
use chipsim::config::{ChipletTypeParams, HardwareConfig};
use chipsim::runtime::Runtime;
use chipsim::thermal::{native::NativeSolver, pjrt::PjrtThermalSolver, ThermalModel};
use chipsim::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_expected_entries() {
    let Some(rt) = runtime_or_skip() else { return };
    for n in [64usize, 256, 640, 1024] {
        assert!(rt.manifest.entries.contains_key(&format!("thermal_transient_n{n}")));
        assert!(rt.manifest.entries.contains_key(&format!("thermal_steady_n{n}")));
    }
    assert!(rt.manifest.entries.contains_key("imc_batch_b128"));
    assert_eq!(rt.manifest.constant_usize("transient_chunk"), Some(256));
}

#[test]
fn pjrt_imc_backend_matches_analytical_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut pjrt = match chipsim::compute::pjrt::PjrtImcBackend::new(rt) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let mut oracle = AnalyticalImc;
    let chiplet = ChipletTypeParams::imc_type_a();
    let mut rng = Rng::new(42);
    let works: Vec<SegmentWork> = (0..200)
        .map(|_| SegmentWork {
            macs: 1 + rng.below(100_000_000),
            weight_bytes: rng.below(2_000_000),
            in_bytes: rng.below(500_000),
            out_elems: 1 + rng.below(500_000),
            rows_used: 256,
            cols_used: 256,
        })
        .collect();
    let items: Vec<(&ChipletTypeParams, SegmentWork)> =
        works.iter().map(|w| (&chiplet, *w)).collect();
    let got = pjrt.evaluate_batch(&items);
    for (w, r) in works.iter().zip(&got) {
        let want = oracle.evaluate(&chiplet, w);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-9);
        // f32 artifact vs f64 oracle.
        assert!(rel(r.latency_ns, want.latency_ns) < 1e-4, "{r:?} vs {want:?}");
        assert!(rel(r.energy_pj, want.energy_pj) < 1e-4);
        assert!(rel(r.avg_power_mw, want.avg_power_mw) < 1e-3);
    }
}

#[test]
fn pjrt_thermal_transient_matches_native_solver() {
    let hw = HardwareConfig::homogeneous_mesh(3, 3); // 36+200 nodes -> n_pad 256
    let tm = ThermalModel::build(&hw);
    let dt = 1e-5;
    let mut pjrt = match PjrtThermalSolver::open_default(&tm, dt) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let native = NativeSolver::new(&tm, dt).unwrap();
    let mut rng = Rng::new(3);
    // 300 steps => spans two PJRT chunks (chunk = 256), exercising the
    // carry logic.
    let steps: Vec<Vec<f64>> = (0..300)
        .map(|_| {
            let chips: Vec<f64> = (0..hw.num_chiplets()).map(|_| rng.range_f64(0.0, 2.0)).collect();
            tm.node_power(&chips)
        })
        .collect();
    let want = native.transient(&vec![0.0; tm.n], &steps);
    let got = pjrt.transient(&vec![0.0; tm.n], &steps).unwrap();
    assert_eq!(got.len(), want.len());
    for (k, (g, w)) in got.iter().zip(&want).enumerate() {
        for i in 0..tm.n {
            let denom = w[i].abs().max(1e-3);
            assert!(
                (g[i] - w[i]).abs() / denom < 2e-3,
                "step {k} node {i}: pjrt {} vs native {}",
                g[i],
                w[i]
            );
        }
    }
}

#[test]
fn pjrt_thermal_steady_matches_direct_solve() {
    let hw = HardwareConfig::homogeneous_mesh(3, 3);
    let tm = ThermalModel::build(&hw);
    let mut pjrt = match PjrtThermalSolver::open_default(&tm, 1e-5) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let p = tm.node_power(&vec![1.5; hw.num_chiplets()]);
    let want = NativeSolver::steady(&tm, &p).unwrap();
    let got = pjrt.steady(&p, 1e-10, 64).unwrap();
    for i in 0..tm.n {
        let rel = (got[i] - want[i]).abs() / want[i].abs().max(1e-6);
        assert!(rel < 5e-3, "node {i}: {} vs {}", got[i], want[i]);
    }
}

#[test]
fn exec_rejects_shape_mismatch() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let bad = chipsim::runtime::F32Tensor::new(vec![2, 6], vec![0.0; 12]);
    let params = chipsim::runtime::F32Tensor::new(vec![6], vec![0.0; 6]);
    assert!(rt.exec_f32("imc_batch_b128", &[bad, params]).is_err());
}

#[test]
fn exec_rejects_unknown_artifact() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.exec_f32("nonexistent", &[]).is_err());
}
