//! Regenerates Fig. 11: bandwidth envelope of the emulated CCD platform.
fn main() {
    chipsim::util::logging::init();
    let table = chipsim::experiments::fig11();
    table.print();
    let _ = chipsim::metrics::write_json("fig11.json", &table.to_json());
}
