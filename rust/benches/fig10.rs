//! Regenerates the paper's Fig10 (see DESIGN.md §6 experiment index).
//! Run: `cargo bench --bench fig10` (add CHIPSIM_QUICK=1 for CI size).
fn main() {
    chipsim::util::logging::init();
    let quick = std::env::var("CHIPSIM_QUICK").is_ok();
    let t0 = std::time::Instant::now();
    let table = chipsim::experiments::fig10(quick);
    table.print();
    let _ = chipsim::metrics::write_json("fig10.json", &table.to_json());
    println!("[fig10 completed in {:.1?}]", t0.elapsed());
}
