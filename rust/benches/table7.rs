//! Regenerates Table VII: CHIPSIM vs hardware-emulator validation.
fn main() {
    chipsim::util::logging::init();
    let t0 = std::time::Instant::now();
    let table = chipsim::experiments::table7();
    table.print();
    let _ = chipsim::metrics::write_json("table7.json", &table.to_json());
    println!("[table7 completed in {:.1?}]", t0.elapsed());
}
