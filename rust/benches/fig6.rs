//! Regenerates the paper's Fig6 (see DESIGN.md §6 experiment index).
//! Run: `cargo bench --bench fig6` (add CHIPSIM_QUICK=1 for CI size).
fn main() {
    chipsim::util::logging::init();
    let quick = std::env::var("CHIPSIM_QUICK").is_ok();
    let t0 = std::time::Instant::now();
    let table = chipsim::experiments::fig6(quick);
    table.print();
    let _ = chipsim::metrics::write_json("fig6.json", &table.to_json());
    println!("[fig6 completed in {:.1?}]", t0.elapsed());
}
