//! Regenerates the paper's Table4 (see DESIGN.md §6 experiment index).
//! Run: `cargo bench --bench table4` (add CHIPSIM_QUICK=1 for CI size).
fn main() {
    chipsim::util::logging::init();
    let quick = std::env::var("CHIPSIM_QUICK").is_ok();
    let t0 = std::time::Instant::now();
    let table = chipsim::experiments::table4(quick);
    table.print();
    let _ = chipsim::metrics::write_json("table4.json", &table.to_json());
    println!("[table4 completed in {:.1?}]", t0.elapsed());
}
