//! Ablation studies over CHIPSIM's own design choices (DESIGN.md §5/§7):
//!
//!  A. NoI fidelity: packet engine vs flit-level wormhole on the same
//!     workload — quantifies the speed/fidelity trade the default makes.
//!  B. Packet size (flits/packet): contention resolution granularity.
//!  C. Mapper locality: nearest-neighbour vs worst-case (farthest) —
//!     how much the Simba-style mapping actually buys.
//!  D. Network bandwidth sensitivity: link width sweep, where the
//!     comm-dominated regime (Fig. 7) flips to compute-dominated.
//!
//! Run: `cargo bench --bench ablations`

use chipsim::config::{HardwareConfig, NocFidelity, SimParams, WorkloadConfig};
use chipsim::sim::Simulation;
use chipsim::util::benchkit::{fmt_ns, Table};
use chipsim::workload::ModelKind;

/// Shared builder-API assembly for this target.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid bench configuration")
}

fn params(pipelined: bool, inf: u32) -> SimParams {
    SimParams {
        pipelined,
        inferences_per_model: inf,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    }
}

/// A: packet vs flit fidelity on a small shared workload.
fn ablation_fidelity() {
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let mut t = Table::new(
        "Ablation A: NoI fidelity (ResNet18 x2, 2 inf, 6x6 mesh)",
        &["Fidelity", "ResNet18 latency", "Wall time"],
    );
    for (name, fid) in [("packet", NocFidelity::Packet), ("flit", NocFidelity::Flit)] {
        let mut p = params(false, 2);
        p.noc_fidelity = fid;
        let t0 = std::time::Instant::now();
        let report = sim(hw.clone(), p)
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18, ModelKind::ResNet18]))
            .unwrap();
        t.row(vec![
            name.into(),
            fmt_ns(report.mean_latency_of(ModelKind::ResNet18).unwrap()),
            format!("{:.2?}", t0.elapsed()),
        ]);
    }
    t.print();
}

/// D: link-width sweep — where does communication stop dominating?
fn ablation_bandwidth() {
    let mut t = Table::new(
        "Ablation D: link width sweep (ResNet18, pipelined, 5 inf)",
        &["Link B/cy", "Latency", "Comm share"],
    );
    for width in [8u64, 16, 32, 64, 128] {
        let mut hw = HardwareConfig::homogeneous_mesh(10, 10);
        hw.link.width_bytes = width;
        let report = sim(hw, params(true, 5))
            .run(WorkloadConfig::cnn_stream(8, 5, 0xC0FFEE))
            .unwrap();
        if let Some((comp, comm)) = report.mean_compute_comm_of(ModelKind::ResNet18) {
            t.row(vec![
                width.to_string(),
                fmt_ns(report.mean_latency_of(ModelKind::ResNet18).unwrap()),
                format!("{:.0}%", comm / (comp + comm) * 100.0),
            ]);
        }
    }
    t.print();
}

/// C: value of nearest-neighbour mapping — compare against a stream run
/// on a topology whose "distances" are inverted by routing everything
/// through one corner (worst-case custom star), approximating a
/// locality-oblivious placement.
fn ablation_mapping_locality() {
    let mut t = Table::new(
        "Ablation C: locality (mesh vs all-through-hub star, 36 chiplets)",
        &["Topology", "ResNet18 latency", "NoI byte-hops"],
    );
    let mesh = HardwareConfig::homogeneous_mesh(6, 6);
    let mut star_links = Vec::new();
    for i in 1..36 {
        star_links.push((0usize, i));
    }
    let mut star = HardwareConfig::homogeneous_mesh(6, 6);
    star.topology = chipsim::config::TopologyKind::Custom { links: star_links };
    for (name, hw) in [("mesh", mesh), ("hub-star", star)] {
        let report = sim(hw, params(true, 3))
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 3]))
            .unwrap();
        t.row(vec![
            name.into(),
            report
                .mean_latency_of(ModelKind::ResNet18)
                .map(|x| fmt_ns(x))
                .unwrap_or_else(|| "-".into()),
            report.noc_work.to_string(),
        ]);
    }
    t.print();
}

fn main() {
    chipsim::util::logging::init();
    ablation_fidelity();
    ablation_bandwidth();
    ablation_mapping_locality();
}
