//! Hot-path microbenchmarks driving the §Perf optimization pass
//! (EXPERIMENTS.md §Perf records before/after for each iteration).
//!
//! Covered paths:
//!   L3  packet NoI engine       (bytes·hops/s under load)
//!   L3  flit NoI engine         (flit-hops/s, wormhole fidelity)
//!   L3  flit NoI engine, large  (96 flows x 64KB on 12x12 — infeasible
//!                                before the active-set rewrite)
//!   L3  flit NoI, parallel      (64x64 mesh sharded over 8 workers vs
//!                                the sequential engine; `speedup` metric)
//!   L3  mapper                  (models mapped/s on a busy ledger)
//!   L3  end-to-end co-sim       (wall time per simulated model)
//!   L3  streaming traffic       (requests/s through the serving engine)
//!   L3  multi-tenant mix        (co-executed requests/s, 2 tenants sharing the NoI)
//!   L3  closed-loop DTM         (control windows/s incl. in-loop thermal)
//!   L3  fleet serving           (fleet requests/s: 4 boards, epoch dispatcher)
//!   L2  native thermal step     (node-updates/s)
//!   L2  PJRT thermal transient  (steps/s incl. dispatch overhead)
//!
//! Run: `cargo bench --bench perf_hotpaths`

use chipsim::config::{HardwareConfig, LinkParams, SimParams, WorkloadConfig};
use chipsim::mapping::{MemoryLedger, NearestNeighborMapper};
use chipsim::noc::engine::PacketEngine;
use chipsim::noc::flit::FlitEngine;
use chipsim::noc::topology::{mesh, Topology};
use chipsim::noc::{FlowSpec, NetworkSim};
use chipsim::sim::Simulation;
use chipsim::thermal::{native::NativeSolver, ThermalModel};
use chipsim::util::benchkit::{bench, fmt_ns};
use chipsim::util::rng::Rng;
use chipsim::workload::{ModelKind, NeuralModel};

/// Shared builder-API assembly for this target's cases.
fn sim(hw: HardwareConfig, params: SimParams) -> Simulation {
    Simulation::builder()
        .hardware(hw)
        .params(params)
        .build()
        .expect("valid bench configuration")
}

fn bench_packet_engine() {
    let topo = mesh(10, 10, &LinkParams::default());
    let r = bench("noc/packet: 200 flows x 64KB on 10x10 mesh", 5, 1500, || {
        let mut e = PacketEngine::new(topo.clone());
        let mut rng = Rng::new(7);
        for i in 0..200 {
            let src = rng.below_usize(100);
            let dst = (src + 1 + rng.below_usize(99)) % 100;
            e.inject(FlowSpec { src, dst, bytes: 65_536 }, i as u64 * 100);
        }
        while e.advance_until(u64::MAX).is_some() {}
        std::hint::black_box(e.work_done());
    });
    r.print();
    // Throughput: bytes*hops per wall-second.
    let mut e = PacketEngine::new(topo);
    let mut rng = Rng::new(7);
    for i in 0..200 {
        let src = rng.below_usize(100);
        let dst = (src + 1 + rng.below_usize(99)) % 100;
        e.inject(FlowSpec { src, dst, bytes: 65_536 }, i as u64 * 100);
    }
    while e.advance_until(u64::MAX).is_some() {}
    let byte_hops = e.work_done() as f64;
    println!(
        "  -> {:.1} M byte-hops/s",
        byte_hops / (r.mean_ns / 1e9) / 1e6
    );
}

/// Run one flit-engine case and record flit-hops/s (the regression metric
/// `python/bench_check.py` guards in CI) into the JSON artifact.
fn flit_case(
    name: &'static str,
    rows: usize,
    cols: usize,
    flows: usize,
    bytes: u64,
    seed: u64,
    min_iters: usize,
    min_time_ms: u64,
) {
    let p = LinkParams::default();
    let topo = mesh(rows, cols, &p);
    let nodes = rows * cols;
    let run = |topo: &Topology| -> u64 {
        let mut e = FlitEngine::new(topo.clone());
        let mut rng = Rng::new(seed);
        for i in 0..flows {
            let src = rng.below_usize(nodes);
            let dst = (src + 1 + rng.below_usize(nodes - 1)) % nodes;
            e.inject(FlowSpec { src, dst, bytes }, i as u64 * 50);
        }
        while e.advance_until(u64::MAX).is_some() {}
        e.work_done()
    };
    // Capture work_done from inside the timed closure (deterministic
    // across iterations) instead of paying one extra un-timed run.
    let work = std::cell::Cell::new(0u64);
    let r = bench(name, min_iters, min_time_ms, || {
        work.set(std::hint::black_box(run(&topo)));
    });
    // work_done counts byte-hops; one flit is `width_bytes` bytes.
    let flit_hops = (work.get() / p.width_bytes) as f64;
    let rate = flit_hops / (r.mean_ns / 1e9);
    let r = r.with_metric("flit_hops_per_s", rate);
    if let Err(e) = r.save_json(&chipsim::util::benchkit::bench_json_dir()) {
        eprintln!("benchkit: could not persist flit metrics: {e:#}");
    }
    r.print();
    println!("  -> {:.2} M flit-hops/s", rate / 1e6);
}

fn bench_flit_engine() {
    flit_case("noc/flit: 24 flows x 8KB on 6x6 mesh", 6, 6, 24, 8_192, 9, 3, 1500);
}

fn bench_flit_engine_large() {
    // Serving-scale wormhole case: was O(links²) per cycle before the
    // active-set rewrite and did not finish in bench time.
    flit_case("noc/flit-large: 96 flows x 64KB on 12x12 mesh", 12, 12, 96, 65_536, 11, 2, 1500);
}

/// Sharded parallel flit engine vs the sequential baseline on a mesh
/// big enough to amortize the sync barriers.  Records `speedup`
/// (sequential mean / parallel mean) plus `flit_hops_per_s` into
/// `BENCH_noc_flit_parallel_*.json`; `python/bench_check.py` reports
/// the speedup floor advisorily until a measured baseline is ratcheted
/// in.  The thread count is pinned (not "all cores") so the committed
/// metric is comparable across hosts.
fn bench_flit_parallel() {
    use chipsim::par::{ExecSpec, ShardedFlitEngine};
    const ROWS: usize = 64;
    const COLS: usize = 64;
    const FLOWS: usize = 256;
    const BYTES: u64 = 16_384;
    const THREADS: usize = 8;
    let p = LinkParams::default();
    let topo = mesh(ROWS, COLS, &p);
    let nodes = ROWS * COLS;
    let inject = |e: &mut dyn NetworkSim| {
        let mut rng = Rng::new(13);
        for i in 0..FLOWS {
            let src = rng.below_usize(nodes);
            let dst = (src + 1 + rng.below_usize(nodes - 1)) % nodes;
            e.inject(FlowSpec { src, dst, bytes: BYTES }, i as u64 * 50);
        }
    };
    let drain = |e: &mut dyn NetworkSim| -> u64 {
        while e.advance_until(u64::MAX).is_some() {}
        e.work_done()
    };
    let seq_work = std::cell::Cell::new(0u64);
    let seq = bench("noc/flit-seq-baseline: 256 flows x 16KB on 64x64 mesh", 1, 800, || {
        let mut e = FlitEngine::new(topo.clone());
        inject(&mut e);
        seq_work.set(std::hint::black_box(drain(&mut e)));
    });
    seq.print();
    let par_work = std::cell::Cell::new(0u64);
    let r = bench("noc/flit-parallel: 256 flows x 16KB on 64x64 mesh, 8 threads", 1, 800, || {
        let mut e = ShardedFlitEngine::new(topo.clone(), ExecSpec::threads(THREADS));
        inject(&mut e);
        par_work.set(std::hint::black_box(drain(&mut e)));
    });
    // The determinism contract the whole PR rests on: identical work.
    assert_eq!(seq_work.get(), par_work.get(), "sharded engine diverged from sequential");
    let flit_hops = (par_work.get() / p.width_bytes) as f64;
    let rate = flit_hops / (r.mean_ns / 1e9);
    let speedup = seq.mean_ns / r.mean_ns;
    let r = r.with_metric("flit_hops_per_s", rate).with_metric("speedup", speedup);
    if let Err(e) = r.save_json(&chipsim::util::benchkit::bench_json_dir()) {
        eprintln!("benchkit: could not persist parallel flit metrics: {e:#}");
    }
    r.print();
    println!("  -> {:.2} M flit-hops/s, {speedup:.2}x vs sequential", rate / 1e6);
}

fn bench_mapper() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let topo = Topology::build(&hw);
    let model = NeuralModel::build(ModelKind::ResNet50);
    let r = bench("mapping: ResNet50 map+unmap on 10x10", 20, 1000, || {
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = mapper.try_map(&model, &mut ledger).unwrap();
        ledger.release_mapping(&m);
        std::hint::black_box(m.total_segments());
    });
    r.print();
}

fn bench_end_to_end() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let params = SimParams {
        pipelined: true,
        inferences_per_model: 3,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let r = bench("cosim: 10-model pipelined stream on 10x10", 2, 2000, || {
        let report = sim(hw.clone(), params.clone())
            .run(WorkloadConfig::cnn_stream(10, 3, 0xAB))
            .unwrap();
        std::hint::black_box(report.span_ns);
    });
    r.print();
    println!("  -> {} per simulated model", fmt_ns(r.mean_ns / 10.0));
}

fn bench_traffic_steady_state() {
    use chipsim::serving::{ArrivalSpec, TrafficSpec};
    let hw = HardwareConfig::homogeneous_mesh(8, 8);
    let params = SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let spec = TrafficSpec::new(
        ArrivalSpec::poisson(3_000.0).kinds(&[ModelKind::ResNet18, ModelKind::ResNet34]),
    )
    .horizon_ms(20.0)
    .warmup_ms(2.0)
    .window_ms(2.0)
    .slo_ms(1.0)
    .steady(None);
    let mut served = 0u64;
    let r = bench("serving: 3 krps poisson x 20 ms on 8x8 mesh", 2, 2000, || {
        let report = sim(hw.clone(), params.clone())
            .run_traffic_with(&spec, 0xFEED)
            .unwrap();
        served = report.stats.completed() + report.stats.warmup_skipped;
        std::hint::black_box(report.span_ns());
    });
    r.print();
    println!(
        "  -> {:.1} k simulated requests/s of wall time ({} per run)",
        served as f64 / (r.mean_ns / 1e9) / 1e3,
        served
    );
}

fn bench_mix_coexecution() {
    use chipsim::mapping::PlacementPolicy;
    use chipsim::serving::mix::{run_mix, TenantSpec, WorkloadMix};
    let hw = HardwareConfig::homogeneous_mesh(8, 8);
    let params = SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let mix = WorkloadMix::new(vec![
        TenantSpec::poisson("a", ModelKind::ResNet18, 1_500.0).slo_ms(2.0),
        TenantSpec::poisson("b", ModelKind::ResNet34, 1_500.0).slo_ms(2.0),
    ])
    .placement(PlacementPolicy::DisjointPartition)
    .horizon_ms(10.0)
    .warmup_ms(1.0)
    .window_ms(2.0);
    let mut served = 0u64;
    let r = bench("mix: 2 tenants x 1.5 krps x 10 ms on 8x8 mesh", 2, 2000, || {
        let report = run_mix(
            || {
                Simulation::builder()
                    .hardware(hw.clone())
                    .params(params.clone())
                    .build()
            },
            &mix,
            0x1117,
        )
        .unwrap();
        served = report
            .tenants
            .iter()
            .map(|t| t.stats.completed() + t.stats.warmup_skipped)
            .sum();
        std::hint::black_box(report.span_ns());
    });
    r.print();
    println!(
        "  -> {:.1} k co-executed requests/s of wall time ({} per run)",
        served as f64 / (r.mean_ns / 1e9) / 1e3,
        served
    );
}

fn bench_dtm_closed_loop() {
    use chipsim::dtm::GovernorSpec;
    use chipsim::serving::{ArrivalSpec, TrafficSpec};
    use chipsim::sim::ThermalSpec;
    let hw = HardwareConfig::homogeneous_mesh(6, 6);
    let params = SimParams {
        pipelined: true,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    };
    let spec = TrafficSpec::new(
        ArrivalSpec::poisson(3_000.0).kinds(&[ModelKind::ResNet18, ModelKind::ResNet34]),
    )
    .horizon_ms(10.0)
    .warmup_ms(1.0)
    .window_ms(2.0)
    .slo_ms(2.0)
    .steady(None);
    let mut windows = 0u64;
    let r = bench("dtm: 3 krps x 10 ms closed loop on 6x6 mesh", 2, 2000, || {
        let report = Simulation::builder()
            .hardware(hw.clone())
            .params(params.clone())
            .thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::threshold(48.0),
            })
            .build()
            .expect("valid bench configuration")
            .run_traffic_with(&spec, 0xD7A)
            .unwrap();
        windows = report.dtm().map(|d| d.windows).unwrap_or(0);
        std::hint::black_box(report.span_ns());
    });
    r.print();
    println!(
        "  -> {:.1} k control windows/s of wall time ({} per run)",
        windows as f64 / (r.mean_ns / 1e9) / 1e3,
        windows
    );
}

/// Whole-fleet serving throughput: dispatcher + routing + the parallel
/// epoch advance of 4 replica boards.  `fleet_requests_per_s` lands in
/// the JSON artifact and is enforced by `python/bench_check.py` against
/// a conservative committed floor (ratchet it to measured numbers with
/// `--ratchet` once CI has baselines).
fn bench_fleet_serving() {
    use chipsim::fleet::{parse_routing, Fleet, FleetSpec};
    use chipsim::serving::{ArrivalSpec, TrafficSpec};
    let board = || {
        Simulation::builder()
            .hardware(HardwareConfig::homogeneous_mesh(6, 6))
            .params(SimParams {
                pipelined: true,
                warmup_ns: 0,
                cooldown_ns: 0,
                ..SimParams::default()
            })
            .build()
    };
    let spec = TrafficSpec::new(
        ArrivalSpec::poisson(8_000.0).kinds(&[ModelKind::ResNet18, ModelKind::ResNet34]),
    )
    .horizon_ms(10.0)
    .warmup_ms(1.0)
    .window_ms(2.0)
    .slo_ms(2.0)
    .steady(None);
    let mut served = 0u64;
    let r = bench("fleet: 4x 6x6 boards, 8 krps x 10 ms, least-outstanding", 2, 2000, || {
        let report = Fleet::new(
            FleetSpec::new(spec.clone(), 4),
            board,
            parse_routing("least-outstanding").unwrap(),
        )
        .run(0xF1EE7)
        .unwrap();
        served = report.global.completed() + report.global.warmup_skipped;
        std::hint::black_box(report.epochs);
    });
    let rate = served as f64 / (r.mean_ns / 1e9);
    let r = r.with_metric("fleet_requests_per_s", rate);
    if let Err(e) = r.save_json(&chipsim::util::benchkit::bench_json_dir()) {
        eprintln!("benchkit: could not persist fleet metrics: {e:#}");
    }
    r.print();
    println!("  -> {:.1} k fleet requests/s of wall time ({served} per run)", rate / 1e3);
}

fn bench_native_thermal() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let tm = ThermalModel::build(&hw);
    let solver = NativeSolver::new(&tm, 1e-5).unwrap();
    let p = tm.node_power(&vec![0.5; 100]);
    let steps = vec![p; 64];
    let r = bench("thermal/native: 64 steps x 600 nodes", 3, 1500, || {
        let traj = solver.transient(&vec![0.0; tm.n], &steps);
        std::hint::black_box(traj.len());
    });
    r.print();
    println!(
        "  -> {:.2} M node-updates/s",
        64.0 * tm.n as f64 / (r.mean_ns / 1e9) / 1e6
    );
}

fn bench_pjrt_thermal() {
    let hw = HardwareConfig::homogeneous_mesh(10, 10);
    let tm = ThermalModel::build(&hw);
    match chipsim::thermal::pjrt::PjrtThermalSolver::open_default(&tm, 1e-5) {
        Ok(mut s) => {
            let p = tm.node_power(&vec![0.5; 100]);
            let steps = vec![p; 256];
            let r = bench("thermal/pjrt: 256-step chunk x 640-pad nodes", 2, 2000, || {
                let traj = s.transient(&vec![0.0; tm.n], &steps).unwrap();
                std::hint::black_box(traj.len());
            });
            r.print();
            println!(
                "  -> {:.1} k steps/s through PJRT",
                256.0 / (r.mean_ns / 1e9) / 1e3
            );
        }
        Err(e) => println!("thermal/pjrt: skipped ({e}) — run `make artifacts`"),
    }
}

fn main() {
    chipsim::util::logging::init();
    // Self-profile every case: benchkit resets per timed window and
    // stamps per-subsystem `share_*` metrics into each BENCH_*.json,
    // so bench_check.py regressions are attributable to a subsystem.
    chipsim::prof::enable();
    println!("== perf_hotpaths ==");
    bench_packet_engine();
    bench_flit_engine();
    bench_flit_engine_large();
    bench_flit_parallel();
    bench_mapper();
    bench_end_to_end();
    bench_traffic_steady_state();
    bench_mix_coexecution();
    bench_dtm_closed_loop();
    bench_fleet_serving();
    bench_native_thermal();
    bench_pjrt_thermal();
}
