//! Typed configuration for hardware, workload, and simulation parameters.
//!
//! Three user inputs drive a CHIPSIM run (paper Fig. 3): the target DNN
//! workload, the hardware configuration, and the mapping function.  This
//! module defines the typed forms plus JSON load/save via `util::json`
//! (the launcher accepts `--hw config.json`).

use crate::util::json::{self, Value};
use crate::workload::ModelKind;
use crate::TimeNs;

// ---------------------------------------------------------------- chiplets

/// Broad chiplet class: selects the compute backend model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipletClass {
    /// In-memory-compute accelerator chiplet (CiMLoop-analog backend).
    Imc,
    /// CPU compute-complex die (analytical MACs/s backend, HW validation).
    Cpu,
    /// I/O die / weight-hosting chiplet (no compute; ViT + CCD-star IOD).
    Io,
}

/// Parameters of one chiplet type (paper: "chiplet properties such as MAC
/// units, memory hierarchy, and frequency").
#[derive(Debug, Clone)]
pub struct ChipletTypeParams {
    pub name: String,
    pub class: ChipletClass,
    /// Stationary weight memory capacity in bytes.
    pub mem_bytes: u64,
    /// Sustained MAC throughput, GOPS (== MACs/ns).
    pub mac_rate_gops: f64,
    /// Dynamic energy per MAC, pJ.
    pub e_mac_pj: f64,
    /// Energy per output-element ADC conversion, pJ (IMC only).
    pub e_adc_pj: f64,
    /// ADC serialization time per output element, ns (IMC only).
    pub t_adc_ns_per_elem: f64,
    /// Fixed per-segment issue overhead, ns.
    pub base_latency_ns: f64,
    /// Static (leakage) power while a segment is active, mW.
    pub leak_mw: f64,
    /// Idle power, mW (contributes to power bins when not computing).
    pub idle_mw: f64,
    /// Physical footprint for the thermal floorplan, mm.
    pub width_mm: f64,
    pub height_mm: f64,
}

impl ChipletTypeParams {
    /// Type A: NeuRRAM-like RRAM CIM chiplet [34] — fast, 2 MiB weights.
    /// The paper's homogeneous experiments use this type everywhere; with
    /// it, communication dominates end-to-end time (paper Fig. 7).
    pub fn imc_type_a() -> Self {
        ChipletTypeParams {
            name: "imc-a(neurram-like)".into(),
            class: ChipletClass::Imc,
            mem_bytes: 2 * 1024 * 1024,
            // 48 cores × 256×256 crossbar, all columns in parallel => tens
            // of TOPS effective; with this rate compute is a small share
            // of end-to-end time and the NoI dominates (paper Fig. 7).
            mac_rate_gops: 49_152.0,
            e_mac_pj: 0.35,
            e_adc_pj: 1.8,
            t_adc_ns_per_elem: 0.002,
            base_latency_ns: 200.0,
            leak_mw: 55.0,
            idle_mw: 4.0,
            width_mm: 2.0,
            height_mm: 2.0,
        }
    }

    /// Type B: RAELLA-like CIM chiplet [33] — denser (4 MiB) but slower;
    /// mixing it in makes computation 42–54 % of total time (paper §V-C1).
    pub fn imc_type_b() -> Self {
        ChipletTypeParams {
            name: "imc-b(raella-like)".into(),
            class: ChipletClass::Imc,
            mem_bytes: 4 * 1024 * 1024,
            // ~8× slower than type A: mixing B in pushes computation to
            // 42–54 % of total execution time (paper §V-C1).
            mac_rate_gops: 6_000.0,
            e_mac_pj: 0.12,
            e_adc_pj: 0.6,
            t_adc_ns_per_elem: 0.008,
            base_latency_ns: 400.0,
            leak_mw: 30.0,
            idle_mw: 3.0,
            width_mm: 2.0,
            height_mm: 2.0,
        }
    }

    /// A Zen-4 CCD: 8 cores, used by the hardware-validation study (§V-F).
    /// MAC rate comes from micro-kernel FLOPs/s profiling of the emulated
    /// platform (see `hwemu::`).
    pub fn cpu_ccd() -> Self {
        ChipletTypeParams {
            name: "cpu-ccd(zen4)".into(),
            class: ChipletClass::Cpu,
            mem_bytes: 512 * 1024 * 1024, // DRAM-backed; effectively large
            mac_rate_gops: 280.0,         // 8 cores * AVX-512 int8 sustained
            e_mac_pj: 1.4,
            e_adc_pj: 0.0,
            t_adc_ns_per_elem: 0.0,
            base_latency_ns: 2_000.0,
            leak_mw: 4_000.0,
            idle_mw: 900.0,
            width_mm: 8.0,
            height_mm: 8.0,
        }
    }

    /// I/O die: hosts weights / DDR interface; no compute.
    pub fn io_die() -> Self {
        ChipletTypeParams {
            name: "io-die".into(),
            class: ChipletClass::Io,
            mem_bytes: 16 * 1024 * 1024 * 1024,
            mac_rate_gops: 0.0,
            e_mac_pj: 0.0,
            e_adc_pj: 0.0,
            t_adc_ns_per_elem: 0.0,
            base_latency_ns: 0.0,
            leak_mw: 0.0,
            idle_mw: 1_500.0,
            width_mm: 12.0,
            height_mm: 12.0,
        }
    }
}

// ---------------------------------------------------------------- topology

/// NoI topology selector (paper §V-A/§V-C2: mesh, Floret, CCD-star, custom).
#[derive(Debug, Clone, PartialEq)]
pub enum TopologyKind {
    /// 2-D mesh with X-Y routing [23, 29].
    Mesh,
    /// Floret space-filling-curve topology [18]: petal chains sharing a
    /// central hub, optimized for feed-forward DNN flows.
    Floret { petals: usize },
    /// CCD↔IOD star with asymmetric links (AMD Threadripper, §V-F).
    CcdStar,
    /// Arbitrary link list (directed edges are added both ways).
    Custom { links: Vec<(usize, usize)> },
}

/// Physical link parameters (heterogeneous widths/clocks are expressed by
/// per-link overrides inside `noc::topology`).
#[derive(Debug, Clone)]
pub struct LinkParams {
    /// Flit width in bytes (UCIe-style parallel interface).
    pub width_bytes: u64,
    /// Link clock in GHz (cycles are `1/clock_ghz` ns).
    pub clock_ghz: f64,
    /// Router pipeline + link traversal latency per hop, cycles.
    pub hop_latency_cycles: u64,
    /// Dynamic energy per byte moved over a link, pJ.
    pub e_per_byte_pj: f64,
    /// Router static power, mW (booked per router into power bins).
    pub router_static_mw: f64,
}

impl Default for LinkParams {
    fn default() -> Self {
        // 32 B/cycle @ 1 GHz interposer links, 4-cycle hop (paper §V-A and
        // DESIGN.md §7).
        LinkParams {
            width_bytes: 32,
            clock_ghz: 1.0,
            hop_latency_cycles: 4,
            e_per_byte_pj: 1.2,
            router_static_mw: 2.0,
        }
    }
}

// ---------------------------------------------------------------- hardware

/// Full hardware configuration: chiplet grid + NoI.
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    pub rows: usize,
    pub cols: usize,
    pub chiplet_types: Vec<ChipletTypeParams>,
    /// Per-chiplet index into `chiplet_types` (len == rows*cols).
    pub type_of: Vec<usize>,
    pub topology: TopologyKind,
    pub link: LinkParams,
    /// Chiplets designated as I/O (weight hosting); ViT uses the corners.
    pub io_chiplets: Vec<usize>,
}

impl HardwareConfig {
    pub fn num_chiplets(&self) -> usize {
        self.rows * self.cols
    }

    pub fn chiplet_type(&self, id: usize) -> &ChipletTypeParams {
        &self.chiplet_types[self.type_of[id]]
    }

    /// The paper's primary system: homogeneous type-A mesh (10×10 in §V-B).
    pub fn homogeneous_mesh(rows: usize, cols: usize) -> Self {
        HardwareConfig {
            rows,
            cols,
            chiplet_types: vec![ChipletTypeParams::imc_type_a()],
            type_of: vec![0; rows * cols],
            topology: TopologyKind::Mesh,
            link: LinkParams::default(),
            io_chiplets: vec![],
        }
    }

    /// §V-C1: 50/50 type-A/type-B in an alternating (checkerboard) pattern
    /// so each chiplet neighbours the other type.
    pub fn heterogeneous_mesh(rows: usize, cols: usize) -> Self {
        let mut hw = Self::homogeneous_mesh(rows, cols);
        hw.chiplet_types.push(ChipletTypeParams::imc_type_b());
        for r in 0..rows {
            for c in 0..cols {
                hw.type_of[r * cols + c] = (r + c) % 2;
            }
        }
        hw
    }

    /// §V-C2: same chiplets, Floret NoI.
    pub fn floret(rows: usize, cols: usize, petals: usize) -> Self {
        let mut hw = Self::homogeneous_mesh(rows, cols);
        hw.topology = TopologyKind::Floret { petals };
        hw
    }

    /// §V-E: homogeneous mesh with the four corner chiplets as I/O dies
    /// hosting/distributing ViT weights (weight-stationary IMC).
    pub fn vit_mesh(rows: usize, cols: usize) -> Self {
        let mut hw = Self::homogeneous_mesh(rows, cols);
        hw.chiplet_types.push(ChipletTypeParams::io_die());
        let corners = [
            0,
            cols - 1,
            (rows - 1) * cols,
            rows * cols - 1,
        ];
        for &c in &corners {
            hw.type_of[c] = 1;
        }
        hw.io_chiplets = corners.to_vec();
        hw
    }

    /// §V-F: AMD Threadripper PRO 7985WX-like platform — 8 CCDs + 1 IOD +
    /// 1 DRAM node in a star.  Node layout: 0..8 = CCDs, 8 = IOD, 9 = DDR.
    /// Links are heterogeneous: GMI3 32 B/cy read / 16 B/cy write at
    /// 1.733 GHz (overridden per-direction inside the topology builder).
    pub fn ccd_star(num_ccds: usize) -> Self {
        let n = num_ccds + 2;
        let mut chiplet_types = vec![ChipletTypeParams::cpu_ccd()];
        chiplet_types.push(ChipletTypeParams::io_die());
        let mut type_of = vec![0; n];
        type_of[num_ccds] = 1; // IOD
        type_of[num_ccds + 1] = 1; // DDR endpoint modeled as an I/O node
        HardwareConfig {
            rows: 1,
            cols: n,
            chiplet_types,
            type_of,
            topology: TopologyKind::CcdStar,
            link: LinkParams {
                width_bytes: 32,
                clock_ghz: 1.733,
                hop_latency_cycles: 8,
                e_per_byte_pj: 3.5,
                router_static_mw: 50.0,
            },
            io_chiplets: vec![num_ccds, num_ccds + 1],
        }
    }

    // ------------------------------------------------------------- JSON I/O

    pub fn to_json(&self) -> Value {
        let topo = match &self.topology {
            TopologyKind::Mesh => Value::obj(vec![("kind", "mesh".into())]),
            TopologyKind::Floret { petals } => {
                Value::obj(vec![("kind", "floret".into()), ("petals", (*petals).into())])
            }
            TopologyKind::CcdStar => Value::obj(vec![("kind", "ccd_star".into())]),
            TopologyKind::Custom { links } => Value::obj(vec![
                ("kind", "custom".into()),
                (
                    "links",
                    Value::Arr(
                        links
                            .iter()
                            .map(|&(a, b)| Value::Arr(vec![a.into(), b.into()]))
                            .collect(),
                    ),
                ),
            ]),
        };
        Value::obj(vec![
            ("rows", self.rows.into()),
            ("cols", self.cols.into()),
            (
                "chiplet_types",
                Value::Arr(self.chiplet_types.iter().map(chiplet_type_to_json).collect()),
            ),
            (
                "type_of",
                Value::Arr(self.type_of.iter().map(|&t| t.into()).collect()),
            ),
            ("topology", topo),
            ("link", link_to_json(&self.link)),
            (
                "io_chiplets",
                Value::Arr(self.io_chiplets.iter().map(|&c| c.into()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let rows = v.get("rows")?.as_usize()?;
        let cols = v.get("cols")?.as_usize()?;
        let chiplet_types = v
            .get("chiplet_types")?
            .as_arr()?
            .iter()
            .map(chiplet_type_from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let type_of = v
            .get("type_of")?
            .as_arr()?
            .iter()
            .map(|t| Ok(t.as_usize()?))
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(type_of.len() == rows * cols, "type_of length mismatch");
        for &t in &type_of {
            anyhow::ensure!(t < chiplet_types.len(), "type index {t} out of range");
        }
        let tv = v.get("topology")?;
        let topology = match tv.get("kind")?.as_str()? {
            "mesh" => TopologyKind::Mesh,
            "floret" => TopologyKind::Floret { petals: tv.get("petals")?.as_usize()? },
            "ccd_star" => TopologyKind::CcdStar,
            "custom" => TopologyKind::Custom {
                links: tv
                    .get("links")?
                    .as_arr()?
                    .iter()
                    .map(|l| {
                        let pair = l.as_arr()?;
                        anyhow::ensure!(pair.len() == 2, "link must be [a, b]");
                        Ok((pair[0].as_usize()?, pair[1].as_usize()?))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            },
            other => anyhow::bail!("unknown topology kind '{other}'"),
        };
        let link = link_from_json(v.get("link")?)?;
        let io_chiplets = v
            .get("io_chiplets")?
            .as_arr()?
            .iter()
            .map(|c| Ok(c.as_usize()?))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(HardwareConfig { rows, cols, chiplet_types, type_of, topology, link, io_chiplets })
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        Self::from_json(&json::parse_file(path)?)
    }
}

fn chiplet_type_to_json(t: &ChipletTypeParams) -> Value {
    Value::obj(vec![
        ("name", t.name.clone().into()),
        (
            "class",
            match t.class {
                ChipletClass::Imc => "imc",
                ChipletClass::Cpu => "cpu",
                ChipletClass::Io => "io",
            }
            .into(),
        ),
        ("mem_bytes", t.mem_bytes.into()),
        ("mac_rate_gops", t.mac_rate_gops.into()),
        ("e_mac_pj", t.e_mac_pj.into()),
        ("e_adc_pj", t.e_adc_pj.into()),
        ("t_adc_ns_per_elem", t.t_adc_ns_per_elem.into()),
        ("base_latency_ns", t.base_latency_ns.into()),
        ("leak_mw", t.leak_mw.into()),
        ("idle_mw", t.idle_mw.into()),
        ("width_mm", t.width_mm.into()),
        ("height_mm", t.height_mm.into()),
    ])
}

fn chiplet_type_from_json(v: &Value) -> anyhow::Result<ChipletTypeParams> {
    Ok(ChipletTypeParams {
        name: v.get("name")?.as_str()?.to_string(),
        class: match v.get("class")?.as_str()? {
            "imc" => ChipletClass::Imc,
            "cpu" => ChipletClass::Cpu,
            "io" => ChipletClass::Io,
            other => anyhow::bail!("unknown chiplet class '{other}'"),
        },
        mem_bytes: v.get("mem_bytes")?.as_u64()?,
        mac_rate_gops: v.get("mac_rate_gops")?.as_f64()?,
        e_mac_pj: v.get("e_mac_pj")?.as_f64()?,
        e_adc_pj: v.get("e_adc_pj")?.as_f64()?,
        t_adc_ns_per_elem: v.get("t_adc_ns_per_elem")?.as_f64()?,
        base_latency_ns: v.get("base_latency_ns")?.as_f64()?,
        leak_mw: v.get("leak_mw")?.as_f64()?,
        idle_mw: v.get("idle_mw")?.as_f64()?,
        width_mm: v.get("width_mm")?.as_f64()?,
        height_mm: v.get("height_mm")?.as_f64()?,
    })
}

fn link_to_json(l: &LinkParams) -> Value {
    Value::obj(vec![
        ("width_bytes", l.width_bytes.into()),
        ("clock_ghz", l.clock_ghz.into()),
        ("hop_latency_cycles", l.hop_latency_cycles.into()),
        ("e_per_byte_pj", l.e_per_byte_pj.into()),
        ("router_static_mw", l.router_static_mw.into()),
    ])
}

fn link_from_json(v: &Value) -> anyhow::Result<LinkParams> {
    Ok(LinkParams {
        width_bytes: v.get("width_bytes")?.as_u64()?,
        clock_ghz: v.get("clock_ghz")?.as_f64()?,
        hop_latency_cycles: v.get("hop_latency_cycles")?.as_u64()?,
        e_per_byte_pj: v.get("e_per_byte_pj")?.as_f64()?,
        router_static_mw: v.get("router_static_mw")?.as_f64()?,
    })
}

// --------------------------------------------------------------- sim params

/// Which network model the co-simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NocFidelity {
    /// Contention-aware packet/virtual-cut-through model (default;
    /// coarsest, fastest).
    Packet,
    /// Flit-level wormhole with credit flow control.  The active-set,
    /// cycle-skipping engine scales with traffic (not cycles × links), so
    /// it is usable at serving scale whenever per-flit arbitration
    /// accuracy matters.
    Flit,
}

/// Which compute backend evaluates layer segments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeBackendKind {
    /// In-process analytical models (CiMLoop-analog / CPU).
    Analytical,
    /// Batched PJRT artifact (`imc_batch_*` from `make artifacts`).
    Pjrt,
}

/// Global simulation parameters (paper §V-A "Simulation Parameters").
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Power profile bin width; the paper uses 1 µs.
    pub power_bin_ns: TimeNs,
    /// Statistics warm-up window (not collected), 1 ms in the paper.
    pub warmup_ns: TimeNs,
    /// Statistics cool-down window, 1 ms in the paper.
    pub cooldown_ns: TimeNs,
    /// Pipeline layers of each model (paper §V-B2) vs layer-at-a-time.
    pub pipelined: bool,
    /// Back-to-back inferences per model instance.
    pub inferences_per_model: u32,
    /// Age threshold after which a queued model becomes non-skippable.
    pub age_threshold_ns: TimeNs,
    /// Workload sampling seed.
    pub seed: u64,
    pub noc_fidelity: NocFidelity,
    pub compute_backend: ComputeBackendKind,
    /// Safety valve: hard cap on simulated time (0 = unlimited).
    pub max_sim_time_ns: TimeNs,
    /// Thermal-aware mapping (THERMOS-style extension): hops of locality
    /// the mapper trades to avoid the hottest chiplet (0 = disabled).
    pub thermal_aware_hops: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            power_bin_ns: crate::POWER_BIN_NS,
            warmup_ns: 1_000_000,
            cooldown_ns: 1_000_000,
            pipelined: false,
            inferences_per_model: 10,
            age_threshold_ns: 20_000_000,
            seed: 0xC01D_CAFE,
            noc_fidelity: NocFidelity::Packet,
            compute_backend: ComputeBackendKind::Analytical,
            max_sim_time_ns: 0,
            thermal_aware_hops: 0.0,
        }
    }
}

// ---------------------------------------------------------------- workload

/// Workload configuration: the model stream fed to the Global Manager.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub kinds: Vec<ModelKind>,
    /// Interval between request arrivals (injection rate 1 => 1 ns).
    pub injection_interval_ns: TimeNs,
}

impl WorkloadConfig {
    /// Paper §V-A: `n` models uniformly sampled from the 4 CNN types.
    pub fn cnn_stream(n: usize, _inferences: u32, seed: u64) -> Self {
        use crate::util::rng::Rng;
        use crate::workload::ALL_CNNS;
        let mut rng = Rng::new(seed);
        WorkloadConfig {
            kinds: (0..n).map(|_| *rng.choice(&ALL_CNNS)).collect(),
            injection_interval_ns: 1,
        }
    }

    pub fn single(kind: ModelKind) -> Self {
        WorkloadConfig { kinds: vec![kind], injection_interval_ns: 1 }
    }

    pub fn from_kinds(kinds: &[ModelKind]) -> Self {
        WorkloadConfig { kinds: kinds.to_vec(), injection_interval_ns: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardware_json_roundtrip() {
        for hw in [
            HardwareConfig::homogeneous_mesh(10, 10),
            HardwareConfig::heterogeneous_mesh(10, 10),
            HardwareConfig::floret(10, 10, 10),
            HardwareConfig::vit_mesh(10, 10),
            HardwareConfig::ccd_star(8),
        ] {
            let j = hw.to_json();
            let back = HardwareConfig::from_json(&j).unwrap();
            assert_eq!(back.rows, hw.rows);
            assert_eq!(back.cols, hw.cols);
            assert_eq!(back.type_of, hw.type_of);
            assert_eq!(back.topology, hw.topology);
            assert_eq!(back.io_chiplets, hw.io_chiplets);
        }
    }

    #[test]
    fn heterogeneous_is_checkerboard() {
        let hw = HardwareConfig::heterogeneous_mesh(10, 10);
        let count_b = hw.type_of.iter().filter(|&&t| t == 1).count();
        assert_eq!(count_b, 50);
        // Each chiplet's E/W/N/S neighbours are the other type.
        for r in 0..10 {
            for c in 0..9 {
                assert_ne!(hw.type_of[r * 10 + c], hw.type_of[r * 10 + c + 1]);
            }
        }
    }

    #[test]
    fn vit_mesh_corners_are_io() {
        let hw = HardwareConfig::vit_mesh(10, 10);
        assert_eq!(hw.io_chiplets, vec![0, 9, 90, 99]);
        for &c in &hw.io_chiplets {
            assert_eq!(hw.chiplet_type(c).class, ChipletClass::Io);
        }
        assert_eq!(hw.chiplet_type(55).class, ChipletClass::Imc);
    }

    #[test]
    fn ccd_star_layout() {
        let hw = HardwareConfig::ccd_star(8);
        assert_eq!(hw.num_chiplets(), 10);
        assert_eq!(hw.chiplet_type(0).class, ChipletClass::Cpu);
        assert_eq!(hw.chiplet_type(8).class, ChipletClass::Io);
        assert_eq!(hw.chiplet_type(9).class, ChipletClass::Io);
    }

    #[test]
    fn bad_json_is_rejected() {
        let v = crate::util::json::parse(r#"{"rows": 2}"#).unwrap();
        assert!(HardwareConfig::from_json(&v).is_err());
    }
}
