//! Microsecond-granularity power profiling (paper §IV-C, Fig. 8).
//!
//! As the co-simulation progresses, every compute and communication
//! operation books its energy here *along with when it happened and which
//! chiplet did it*.  The tracker bins energy per chiplet at the paper's
//! 1 µs granularity (1 pJ / 1 ns == 1 mW, so bin power in mW is simply
//! accumulated pJ / bin_ns).  The resulting profiles feed the thermal
//! model and the Fig. 8 traces.
//!
//! Batch runs keep every bin alive for the end-of-run thermal solve.  The
//! sustained-traffic engine (`crate::serving`) instead calls
//! [`PowerTracker::drain_window`] as virtual time advances, so hour-long
//! simulated traces hold only the bins of the trailing window in memory;
//! drained energy stays accounted in [`PowerTracker::dynamic_energy_pj`].

use crate::TimeNs;

/// A finalized slice of the power profile returned by
/// [`PowerTracker::drain_window`]: per-chiplet bin energies over
/// `[start_ns, end_ns())`, removed from the tracker's live storage.
#[derive(Debug, Clone)]
pub struct PowerWindow {
    /// Virtual time of the first drained bin.
    pub start_ns: TimeNs,
    /// Bin width (same as the tracker's).
    pub bin_ns: TimeNs,
    /// `energy_pj[chiplet][bin]` — dynamic energy, pJ.
    pub energy_pj: Vec<Vec<f64>>,
    /// Baseline (idle + static) power per chiplet at drain time, mW.
    pub baseline_mw: Vec<f64>,
}

impl PowerWindow {
    /// Bins in the window (uniform across chiplets).
    pub fn bins(&self) -> usize {
        self.energy_pj.first().map_or(0, |r| r.len())
    }

    pub fn span_ns(&self) -> TimeNs {
        self.bins() as TimeNs * self.bin_ns
    }

    pub fn end_ns(&self) -> TimeNs {
        self.start_ns + self.span_ns()
    }

    /// Total dynamic energy in the window, pJ.
    pub fn dynamic_pj(&self) -> f64 {
        self.energy_pj.iter().map(|r| r.iter().sum::<f64>()).sum()
    }

    /// Mean total system power over the window (dynamic + baseline), W.
    pub fn mean_power_w(&self) -> f64 {
        let span = self.span_ns();
        if span == 0 {
            return 0.0;
        }
        // pJ/ns == mW; scale to W.
        let dynamic_w = self.dynamic_pj() / span as f64 * 1e-3;
        let baseline_w = self.baseline_mw.iter().sum::<f64>() * 1e-3;
        dynamic_w + baseline_w
    }
}

/// Per-chiplet time-binned power profile.
#[derive(Debug, Clone)]
pub struct PowerTracker {
    pub bin_ns: TimeNs,
    num_chiplets: usize,
    /// bins[chiplet][i] = accumulated energy in pJ of global bin
    /// `origin_bin + i` (bins before `origin_bin` have been drained).
    bins: Vec<Vec<f64>>,
    /// Constant baseline power per chiplet, mW (idle + router static).
    baseline_mw: Vec<f64>,
    max_time_ns: TimeNs,
    /// Global index of the first live bin; everything before it was
    /// handed out through `drain_window`.
    origin_bin: usize,
    /// Energy already drained per chiplet, pJ (keeps totals exact).
    drained_pj: Vec<f64>,
}

impl PowerTracker {
    pub fn new(num_chiplets: usize, bin_ns: TimeNs) -> PowerTracker {
        assert!(bin_ns > 0);
        PowerTracker {
            bin_ns,
            num_chiplets,
            bins: vec![Vec::new(); num_chiplets],
            baseline_mw: vec![0.0; num_chiplets],
            max_time_ns: 0,
            origin_bin: 0,
            drained_pj: vec![0.0; num_chiplets],
        }
    }

    pub fn num_chiplets(&self) -> usize {
        self.num_chiplets
    }

    /// Set the constant baseline (idle + static) power of a chiplet.
    pub fn set_baseline_mw(&mut self, chiplet: usize, mw: f64) {
        self.baseline_mw[chiplet] = mw;
    }

    /// Book energy into a *global* bin index.  Bookings that land before
    /// the drained origin fold into the drained total: conservation is
    /// kept even if a straggler event arrives behind the drain cursor.
    fn book_bin(&mut self, chiplet: usize, bin: usize, pj: f64) {
        if bin < self.origin_bin {
            self.drained_pj[chiplet] += pj;
            return;
        }
        let rel = bin - self.origin_bin;
        let v = &mut self.bins[chiplet];
        if v.len() <= rel {
            v.resize(rel + 1, 0.0);
        }
        v[rel] += pj;
    }

    /// Book `energy_pj` spread uniformly over [start, start+duration).
    pub fn add_energy(&mut self, chiplet: usize, start: TimeNs, duration_ns: TimeNs, energy_pj: f64) {
        if energy_pj <= 0.0 {
            return;
        }
        let duration = duration_ns.max(1);
        let end = start + duration;
        self.max_time_ns = self.max_time_ns.max(end);
        let first_bin = (start / self.bin_ns) as usize;
        let last_bin = ((end - 1) / self.bin_ns) as usize;
        if first_bin == last_bin {
            self.book_bin(chiplet, first_bin, energy_pj);
            return;
        }
        let per_ns = energy_pj / duration as f64;
        for bin in first_bin..=last_bin {
            let bin_start = bin as TimeNs * self.bin_ns;
            let bin_end = bin_start + self.bin_ns;
            let overlap = end.min(bin_end) - start.max(bin_start);
            self.book_bin(chiplet, bin, per_ns * overlap as f64);
        }
    }

    /// Book an instantaneous energy event into its bin.
    pub fn add_event(&mut self, chiplet: usize, t: TimeNs, energy_pj: f64) {
        if energy_pj <= 0.0 {
            return;
        }
        let bin = (t / self.bin_ns) as usize;
        self.book_bin(chiplet, bin, energy_pj);
        self.max_time_ns = self.max_time_ns.max(t + 1);
    }

    /// Number of bins covering the profiled interval (including drained
    /// ones — this is the *global* bin count).
    pub fn num_bins(&self) -> usize {
        (self.max_time_ns.div_ceil(self.bin_ns)) as usize
    }

    /// Bins currently held in memory.  Bounded in streaming mode, where
    /// [`drain_window`](Self::drain_window) retires the past; equals
    /// [`num_bins`](Self::num_bins) when nothing was drained.
    pub fn live_bins(&self) -> usize {
        self.bins.iter().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Global index of the first live bin (count of drained bins).
    pub fn drained_bins(&self) -> usize {
        self.origin_bin
    }

    /// Finalize and remove every bin that ends at or before `before_ns`,
    /// returning the drained slice as a [`PowerWindow`].  Subsequent
    /// bookings behind the cursor fold into the drained energy total, so
    /// [`dynamic_energy_pj`](Self::dynamic_energy_pj) stays exact.  The
    /// streaming traffic engine calls this one window behind virtual time
    /// to keep memory constant over arbitrarily long horizons.
    pub fn drain_window(&mut self, before_ns: TimeNs) -> PowerWindow {
        // The window's bin count follows the *requested* cutoff, not what
        // was booked: bins nothing landed in are zeros, so an idle window
        // still spans its full width and reports baseline power, and the
        // drain cursor stays on the caller's window boundaries.  Callers
        // drain incrementally (one window at a time) — allocation is
        // O(requested span / bin_ns).
        let cutoff = (before_ns / self.bin_ns) as usize; // first bin kept
        let n = cutoff.saturating_sub(self.origin_bin);
        let mut energy = Vec::with_capacity(self.num_chiplets);
        for c in 0..self.num_chiplets {
            let take = n.min(self.bins[c].len());
            let mut row: Vec<f64> = self.bins[c].drain(..take).collect();
            row.resize(n, 0.0);
            self.drained_pj[c] += row.iter().sum::<f64>();
            energy.push(row);
        }
        let window = PowerWindow {
            start_ns: self.origin_bin as TimeNs * self.bin_ns,
            bin_ns: self.bin_ns,
            energy_pj: energy,
            baseline_mw: self.baseline_mw.clone(),
        };
        self.origin_bin += n;
        window
    }

    /// Non-destructive snapshot of `[start_ns, end_ns)` as a
    /// [`PowerWindow`] (nothing is drained).  Bins already drained, or
    /// beyond the profiled extent, read as zeros.  The in-loop DTM
    /// controller uses this on state-retaining (batch) runs so the
    /// report keeps its full per-bin power trace.
    pub fn window_view(&self, start_ns: TimeNs, end_ns: TimeNs) -> PowerWindow {
        let first = (start_ns / self.bin_ns) as usize;
        let cutoff = (end_ns / self.bin_ns) as usize;
        let energy = (0..self.num_chiplets)
            .map(|c| {
                (first..cutoff)
                    .map(|bin| {
                        bin.checked_sub(self.origin_bin)
                            .and_then(|rel| self.bins[c].get(rel))
                            .copied()
                            .unwrap_or(0.0)
                    })
                    .collect()
            })
            .collect();
        PowerWindow {
            start_ns: first as TimeNs * self.bin_ns,
            bin_ns: self.bin_ns,
            energy_pj: energy,
            baseline_mw: self.baseline_mw.clone(),
        }
    }

    /// Non-destructive snapshot of all live bins as a [`PowerWindow`]
    /// (nothing is drained; the tracker is unchanged).  Convenience for
    /// consumers that want the whole live trace as one window — the
    /// end-of-run thermal tail instead streams bins directly via
    /// `ThermalStepper::ingest_live` to avoid the copy.
    pub fn live_window(&self) -> PowerWindow {
        let n = self.num_bins().saturating_sub(self.origin_bin);
        let energy = (0..self.num_chiplets)
            .map(|c| {
                let mut row = self.bins[c].clone();
                row.resize(n, 0.0);
                row
            })
            .collect();
        PowerWindow {
            start_ns: self.origin_bin as TimeNs * self.bin_ns,
            bin_ns: self.bin_ns,
            energy_pj: energy,
            baseline_mw: self.baseline_mw.clone(),
        }
    }

    /// Power of one chiplet in one (global) bin, mW (dynamic + baseline).
    /// Drained bins report baseline only — their dynamic share left with
    /// the [`PowerWindow`] that drained them.
    pub fn power_mw(&self, chiplet: usize, bin: usize) -> f64 {
        let dynamic = bin
            .checked_sub(self.origin_bin)
            .and_then(|rel| self.bins[chiplet].get(rel))
            .copied()
            .unwrap_or(0.0)
            / self.bin_ns as f64;
        dynamic + self.baseline_mw[chiplet]
    }

    /// Power series of one chiplet over the *live* bins, mW.  Covers the
    /// whole run when nothing was drained; after streaming drains it is
    /// the trailing window only (the drained past left with its
    /// [`PowerWindow`]s), so its length never scales with the horizon.
    pub fn series_mw(&self, chiplet: usize) -> Vec<f64> {
        (self.origin_bin..self.num_bins()).map(|b| self.power_mw(chiplet, b)).collect()
    }

    /// Total system power series over the live bins, W.
    pub fn total_series_w(&self) -> Vec<f64> {
        let mut total = vec![0.0; self.num_bins() - self.origin_bin];
        for c in 0..self.num_chiplets {
            for (i, t) in total.iter_mut().enumerate() {
                *t += self.power_mw(c, self.origin_bin + i) * 1e-3;
            }
        }
        total
    }

    /// Total energy booked for a chiplet, pJ (dynamic only, live +
    /// drained — draining never changes this total).
    pub fn dynamic_energy_pj(&self, chiplet: usize) -> f64 {
        self.drained_pj[chiplet] + self.bins[chiplet].iter().sum::<f64>()
    }

    /// Average power of a chiplet over the live bins, mW.
    pub fn avg_power_mw(&self, chiplet: usize) -> f64 {
        let n = (self.num_bins() - self.origin_bin).max(1);
        self.series_mw(chiplet).iter().sum::<f64>() / n as f64
    }

    /// Power matrix [bins x chiplets] in W, decimated by `stride` bins
    /// (averaged) — the thermal solver's input format.  Only live bins
    /// are emitted: after streaming drains, the thermal solve covers the
    /// trailing window instead of allocating O(horizon) rows of
    /// baseline-only power.
    pub fn matrix_w(&self, stride: usize) -> Vec<Vec<f64>> {
        let stride = stride.max(1);
        let nbins = self.num_bins();
        let nrows = (nbins - self.origin_bin).div_ceil(stride);
        let mut rows = Vec::with_capacity(nrows);
        for r in 0..nrows {
            let lo = self.origin_bin + r * stride;
            let hi = (lo + stride).min(nbins).max(lo + 1);
            let row: Vec<f64> = (0..self.num_chiplets)
                .map(|c| {
                    (lo..hi).map(|b| self.power_mw(c, b)).sum::<f64>() / (hi - lo) as f64 * 1e-3
                })
                .collect();
            rows.push(row);
        }
        rows
    }

    /// CSV export over the live bins: time_us, chiplet0_mw, ...  Time
    /// stamps stay global, so after streaming drains the rows are the
    /// trailing window at its true virtual time.
    pub fn to_csv(&self, chiplets: &[usize]) -> String {
        let mut s = String::from("time_us");
        for &c in chiplets {
            s.push_str(&format!(",chiplet{c}_mw"));
        }
        s.push('\n');
        for b in self.origin_bin..self.num_bins() {
            s.push_str(&format!("{}", b as f64 * self.bin_ns as f64 / 1e3));
            for &c in chiplets {
                s.push_str(&format!(",{:.3}", self.power_mw(c, b)));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_conserved_across_bins() {
        let mut p = PowerTracker::new(2, 1_000);
        p.add_energy(0, 500, 2_000, 6_000.0); // spans 3 bins
        let total: f64 = (0..p.num_bins()).map(|b| p.power_mw(0, b) * 1_000.0).sum();
        assert!((total - 6_000.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_spread_proportional_to_overlap() {
        let mut p = PowerTracker::new(1, 1_000);
        // [500, 2500): 500 ns in bin0, 1000 in bin1, 500 in bin2.
        p.add_energy(0, 500, 2_000, 4_000.0);
        assert!((p.power_mw(0, 0) - 1.0).abs() < 1e-9); // 1000 pJ / 1000 ns
        assert!((p.power_mw(0, 1) - 2.0).abs() < 1e-9);
        assert!((p.power_mw(0, 2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn one_pj_per_ns_is_one_mw() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_energy(0, 0, 1_000, 1_000.0);
        assert!((p.power_mw(0, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_adds_everywhere() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_event(0, 5_000, 500.0);
        p.set_baseline_mw(0, 3.0);
        assert!((p.power_mw(0, 0) - 3.0).abs() < 1e-12);
        assert!((p.power_mw(0, 5) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn total_series_sums_chiplets() {
        let mut p = PowerTracker::new(3, 1_000);
        for c in 0..3 {
            p.add_energy(c, 0, 1_000, 1_000.0);
        }
        let total = p.total_series_w();
        assert!((total[0] - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn matrix_decimation_averages() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_energy(0, 0, 1_000, 2_000.0); // bin0: 2 mW
        p.add_energy(0, 1_000, 1_000, 4_000.0); // bin1: 4 mW
        let m = p.matrix_w(2);
        assert_eq!(m.len(), 1);
        assert!((m[0][0] - 3e-3).abs() < 1e-12); // avg of 2,4 mW in W
    }

    #[test]
    fn drained_energy_equals_booked_energy() {
        // Includes the tail-bin rounding path of add_energy: spans that
        // start and end mid-bin split via per_ns * overlap, whose parts
        // must re-sum to the booked total across drains.
        let mut p = PowerTracker::new(2, 1_000);
        p.add_energy(0, 500, 2_250, 6_123.456); // mid-bin start and end
        p.add_energy(0, 7_999, 1, 42.0); // 1 ns tail at a bin boundary
        p.add_energy(1, 0, 5_000, 1_000.0);
        p.add_event(1, 9_300, 77.7);
        let booked = [6_123.456 + 42.0, 1_000.0 + 77.7];
        let mut drained = [0.0f64; 2];
        // Drain in three uneven pieces, then past the profiled extent.
        for cut in [1_500, 4_000, 9_000, 20_000] {
            let w = p.drain_window(cut);
            for c in 0..2 {
                drained[c] += w.energy_pj[c].iter().sum::<f64>();
            }
        }
        for c in 0..2 {
            assert!(
                (drained[c] - booked[c]).abs() < 1e-9,
                "chiplet {c}: drained {} != booked {}",
                drained[c],
                booked[c]
            );
            // dynamic_energy_pj is invariant under draining.
            assert!((p.dynamic_energy_pj(c) - booked[c]).abs() < 1e-9);
        }
        assert_eq!(p.live_bins(), 0);
    }

    #[test]
    fn drain_keeps_live_bins_bounded_and_power_queries_safe() {
        let mut p = PowerTracker::new(1, 1_000);
        p.set_baseline_mw(0, 2.0);
        p.add_energy(0, 0, 1_000, 1_000.0); // bin 0: 1 mW dynamic
        p.add_energy(0, 5_000, 1_000, 3_000.0); // bin 5: 3 mW dynamic
        let w = p.drain_window(2_000);
        assert_eq!(w.bins(), 2);
        assert_eq!(w.start_ns, 0);
        assert_eq!(w.end_ns(), 2_000);
        assert!((w.dynamic_pj() - 1_000.0).abs() < 1e-12);
        assert_eq!(p.drained_bins(), 2);
        // Drained bins report baseline only; live bins are unaffected.
        assert!((p.power_mw(0, 0) - 2.0).abs() < 1e-12);
        assert!((p.power_mw(0, 5) - 5.0).abs() < 1e-12);
        assert!(p.live_bins() < p.num_bins());
        // A straggler booked behind the cursor folds into drained totals.
        p.add_event(0, 500, 10.0);
        assert!((p.dynamic_energy_pj(0) - 4_010.0).abs() < 1e-9);
    }

    #[test]
    fn idle_window_spans_full_width_and_reports_baseline() {
        // A window in which nothing was booked must still cover its full
        // span (zero bins) so the trace shows baseline power, not 0 W.
        let mut p = PowerTracker::new(1, 1_000);
        p.set_baseline_mw(0, 5.0);
        let w = p.drain_window(3_000);
        assert_eq!(w.bins(), 3);
        assert_eq!(w.end_ns(), 3_000);
        assert_eq!(w.dynamic_pj(), 0.0);
        assert!((w.mean_power_w() - 5e-3).abs() < 1e-12);
        assert_eq!(p.drained_bins(), 3);
        // The next booking after the idle drain lands correctly.
        p.add_event(0, 3_500, 9.0);
        assert!((p.dynamic_energy_pj(0) - 9.0).abs() < 1e-12);
        assert!((p.power_mw(0, 3) - 5.009).abs() < 1e-12);
    }

    #[test]
    fn window_mean_power_includes_baseline() {
        let mut p = PowerTracker::new(2, 1_000);
        p.set_baseline_mw(0, 1.0);
        p.set_baseline_mw(1, 1.0);
        p.add_energy(0, 0, 2_000, 4_000.0); // 2 mW dynamic over 2 bins
        let w = p.drain_window(2_000);
        // dynamic: 4000 pJ / 2000 ns = 2 mW; baseline 2 mW total.
        assert!((w.mean_power_w() - 4e-3).abs() < 1e-12, "{}", w.mean_power_w());
    }

    #[test]
    fn window_view_reads_without_draining() {
        let mut p = PowerTracker::new(1, 1_000);
        p.set_baseline_mw(0, 2.0);
        p.add_energy(0, 0, 4_000, 8_000.0); // 2000 pJ in each of bins 0..4
        let w = p.window_view(1_000, 3_000);
        assert_eq!(w.start_ns, 1_000);
        assert_eq!(w.bins(), 2);
        assert!((w.dynamic_pj() - 4_000.0).abs() < 1e-9);
        assert_eq!(p.drained_bins(), 0, "a view must not drain");
        // Beyond the profiled extent and behind a drain cursor: zeros.
        let tail = p.window_view(3_000, 6_000);
        assert!((tail.dynamic_pj() - 2_000.0).abs() < 1e-9);
        p.drain_window(2_000);
        let behind = p.window_view(0, 2_000);
        assert_eq!(behind.dynamic_pj(), 0.0);
        // An empty/inverted span yields a zero-bin window.
        assert_eq!(p.window_view(5_000, 5_000).bins(), 0);
    }

    #[test]
    fn live_window_snapshot_is_nondestructive() {
        let mut p = PowerTracker::new(2, 1_000);
        p.set_baseline_mw(0, 1.0);
        p.add_energy(0, 0, 3_000, 9_000.0);
        p.add_event(1, 4_500, 50.0);
        let before_live = p.live_bins();
        let w = p.live_window();
        assert_eq!(w.start_ns, 0);
        assert_eq!(w.bins(), p.num_bins());
        assert!((w.dynamic_pj() - 9_050.0).abs() < 1e-9);
        assert_eq!(w.baseline_mw, vec![1.0, 0.0]);
        // Snapshot, not a drain: tracker state is untouched.
        assert_eq!(p.live_bins(), before_live);
        assert_eq!(p.drained_bins(), 0);
        // After draining, the snapshot covers only the remaining tail at
        // its true global offset.
        p.drain_window(2_000);
        let tail = p.live_window();
        assert_eq!(tail.start_ns, 2_000);
        assert_eq!(tail.bins(), p.num_bins() - 2);
        assert!((tail.dynamic_pj() - (3_000.0 + 50.0)).abs() < 1e-9);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut p = PowerTracker::new(2, 1_000);
        p.add_event(1, 100, 42.0);
        let csv = p.to_csv(&[0, 1]);
        assert!(csv.starts_with("time_us,chiplet0_mw,chiplet1_mw\n"));
        assert_eq!(csv.lines().count(), 2);
    }
}
