//! Microsecond-granularity power profiling (paper §IV-C, Fig. 8).
//!
//! As the co-simulation progresses, every compute and communication
//! operation books its energy here *along with when it happened and which
//! chiplet did it*.  The tracker bins energy per chiplet at the paper's
//! 1 µs granularity (1 pJ / 1 ns == 1 mW, so bin power in mW is simply
//! accumulated pJ / bin_ns).  The resulting profiles feed the thermal
//! model and the Fig. 8 traces.

use crate::TimeNs;

/// Per-chiplet time-binned power profile.
#[derive(Debug, Clone)]
pub struct PowerTracker {
    pub bin_ns: TimeNs,
    num_chiplets: usize,
    /// bins[chiplet][bin] = accumulated energy in pJ.
    bins: Vec<Vec<f64>>,
    /// Constant baseline power per chiplet, mW (idle + router static).
    baseline_mw: Vec<f64>,
    max_time_ns: TimeNs,
}

impl PowerTracker {
    pub fn new(num_chiplets: usize, bin_ns: TimeNs) -> PowerTracker {
        assert!(bin_ns > 0);
        PowerTracker {
            bin_ns,
            num_chiplets,
            bins: vec![Vec::new(); num_chiplets],
            baseline_mw: vec![0.0; num_chiplets],
            max_time_ns: 0,
        }
    }

    pub fn num_chiplets(&self) -> usize {
        self.num_chiplets
    }

    /// Set the constant baseline (idle + static) power of a chiplet.
    pub fn set_baseline_mw(&mut self, chiplet: usize, mw: f64) {
        self.baseline_mw[chiplet] = mw;
    }

    fn ensure_bin(&mut self, chiplet: usize, bin: usize) {
        let v = &mut self.bins[chiplet];
        if v.len() <= bin {
            v.resize(bin + 1, 0.0);
        }
    }

    /// Book `energy_pj` spread uniformly over [start, start+duration).
    pub fn add_energy(&mut self, chiplet: usize, start: TimeNs, duration_ns: TimeNs, energy_pj: f64) {
        if energy_pj <= 0.0 {
            return;
        }
        let duration = duration_ns.max(1);
        let end = start + duration;
        self.max_time_ns = self.max_time_ns.max(end);
        let first_bin = (start / self.bin_ns) as usize;
        let last_bin = ((end - 1) / self.bin_ns) as usize;
        self.ensure_bin(chiplet, last_bin);
        if first_bin == last_bin {
            self.bins[chiplet][first_bin] += energy_pj;
            return;
        }
        let per_ns = energy_pj / duration as f64;
        for bin in first_bin..=last_bin {
            let bin_start = bin as TimeNs * self.bin_ns;
            let bin_end = bin_start + self.bin_ns;
            let overlap = end.min(bin_end) - start.max(bin_start);
            self.bins[chiplet][bin] += per_ns * overlap as f64;
        }
    }

    /// Book an instantaneous energy event into its bin.
    pub fn add_event(&mut self, chiplet: usize, t: TimeNs, energy_pj: f64) {
        if energy_pj <= 0.0 {
            return;
        }
        let bin = (t / self.bin_ns) as usize;
        self.ensure_bin(chiplet, bin);
        self.bins[chiplet][bin] += energy_pj;
        self.max_time_ns = self.max_time_ns.max(t + 1);
    }

    /// Number of bins covering the profiled interval.
    pub fn num_bins(&self) -> usize {
        (self.max_time_ns.div_ceil(self.bin_ns)) as usize
    }

    /// Power of one chiplet in one bin, mW (dynamic + baseline).
    pub fn power_mw(&self, chiplet: usize, bin: usize) -> f64 {
        let dynamic = self.bins[chiplet].get(bin).copied().unwrap_or(0.0) / self.bin_ns as f64;
        dynamic + self.baseline_mw[chiplet]
    }

    /// Full power series of one chiplet, mW.
    pub fn series_mw(&self, chiplet: usize) -> Vec<f64> {
        (0..self.num_bins()).map(|b| self.power_mw(chiplet, b)).collect()
    }

    /// Total system power series, W.
    pub fn total_series_w(&self) -> Vec<f64> {
        let n = self.num_bins();
        let mut total = vec![0.0; n];
        for c in 0..self.num_chiplets {
            for (b, t) in total.iter_mut().enumerate() {
                *t += self.power_mw(c, b) * 1e-3;
            }
        }
        total
    }

    /// Total energy booked for a chiplet, pJ (dynamic only).
    pub fn dynamic_energy_pj(&self, chiplet: usize) -> f64 {
        self.bins[chiplet].iter().sum()
    }

    /// Average power of a chiplet over the run, mW.
    pub fn avg_power_mw(&self, chiplet: usize) -> f64 {
        let n = self.num_bins().max(1);
        self.series_mw(chiplet).iter().sum::<f64>() / n as f64
    }

    /// Power matrix [bins x chiplets] in W, decimated by `stride` bins
    /// (averaged) — the thermal solver's input format.
    pub fn matrix_w(&self, stride: usize) -> Vec<Vec<f64>> {
        let stride = stride.max(1);
        let nbins = self.num_bins();
        let nrows = nbins.div_ceil(stride);
        let mut rows = Vec::with_capacity(nrows);
        for r in 0..nrows {
            let lo = r * stride;
            let hi = ((r + 1) * stride).min(nbins).max(lo + 1);
            let row: Vec<f64> = (0..self.num_chiplets)
                .map(|c| {
                    (lo..hi).map(|b| self.power_mw(c, b)).sum::<f64>() / (hi - lo) as f64 * 1e-3
                })
                .collect();
            rows.push(row);
        }
        rows
    }

    /// CSV export: time_us, chiplet0_mw, chiplet1_mw, ...
    pub fn to_csv(&self, chiplets: &[usize]) -> String {
        let mut s = String::from("time_us");
        for &c in chiplets {
            s.push_str(&format!(",chiplet{c}_mw"));
        }
        s.push('\n');
        for b in 0..self.num_bins() {
            s.push_str(&format!("{}", b as f64 * self.bin_ns as f64 / 1e3));
            for &c in chiplets {
                s.push_str(&format!(",{:.3}", self.power_mw(c, b)));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_conserved_across_bins() {
        let mut p = PowerTracker::new(2, 1_000);
        p.add_energy(0, 500, 2_000, 6_000.0); // spans 3 bins
        let total: f64 = (0..p.num_bins()).map(|b| p.power_mw(0, b) * 1_000.0).sum();
        assert!((total - 6_000.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_spread_proportional_to_overlap() {
        let mut p = PowerTracker::new(1, 1_000);
        // [500, 2500): 500 ns in bin0, 1000 in bin1, 500 in bin2.
        p.add_energy(0, 500, 2_000, 4_000.0);
        assert!((p.power_mw(0, 0) - 1.0).abs() < 1e-9); // 1000 pJ / 1000 ns
        assert!((p.power_mw(0, 1) - 2.0).abs() < 1e-9);
        assert!((p.power_mw(0, 2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn one_pj_per_ns_is_one_mw() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_energy(0, 0, 1_000, 1_000.0);
        assert!((p.power_mw(0, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_adds_everywhere() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_event(0, 5_000, 500.0);
        p.set_baseline_mw(0, 3.0);
        assert!((p.power_mw(0, 0) - 3.0).abs() < 1e-12);
        assert!((p.power_mw(0, 5) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn total_series_sums_chiplets() {
        let mut p = PowerTracker::new(3, 1_000);
        for c in 0..3 {
            p.add_energy(c, 0, 1_000, 1_000.0);
        }
        let total = p.total_series_w();
        assert!((total[0] - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn matrix_decimation_averages() {
        let mut p = PowerTracker::new(1, 1_000);
        p.add_energy(0, 0, 1_000, 2_000.0); // bin0: 2 mW
        p.add_energy(0, 1_000, 1_000, 4_000.0); // bin1: 4 mW
        let m = p.matrix_w(2);
        assert_eq!(m.len(), 1);
        assert!((m[0][0] - 3e-3).abs() < 1e-12); // avg of 2,4 mW in W
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut p = PowerTracker::new(2, 1_000);
        p.add_event(1, 100, 42.0);
        let csv = p.to_csv(&[0, 1]);
        assert!(csv.starts_with("time_us,chiplet0_mw,chiplet1_mw\n"));
        assert_eq!(csv.lines().count(), 2);
    }
}
