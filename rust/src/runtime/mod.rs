//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them from the Rust hot path.
//!
//! The interchange format is HLO **text** (`artifacts/*.hlo.txt`): jax
//! ≥ 0.5 serializes HloModuleProto with 64-bit instruction ids that the
//! xla crate's xla_extension 0.5.1 rejects, while the text parser
//! reassigns ids and round-trips cleanly.  Entries are described by
//! `artifacts/manifest.json` (schema produced by `python/compile/aot.py`).
//!
//! One compiled executable is cached per artifact name; compilation
//! happens lazily on first use.  Python is never involved at runtime.

use std::collections::BTreeMap;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// The real `xla` crate is not vendored in this offline build; the stub
// mirrors the exact API surface used below so `--features pjrt` stays
// compile-checked (CI feature-matrix job).  Vendor the dependency and
// swap this alias for `use xla;` to execute artifacts for real.
#[cfg(feature = "pjrt")]
mod xla_stub;
#[cfg(feature = "pjrt")]
use xla_stub as xla;

use crate::util::json::{self, Value};

/// Shape + dtype of one artifact input.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT entry from the manifest.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub num_outputs: usize,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: BTreeMap<String, EntrySpec>,
    /// Compile-time constants baked into the graphs (chunk sizes etc.).
    pub constants: BTreeMap<String, f64>,
}

impl Manifest {
    pub fn parse(v: &Value) -> anyhow::Result<Manifest> {
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        shape: i
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| Ok(d.as_usize()?))
                            .collect::<anyhow::Result<Vec<_>>>()?,
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: e.get("file")?.as_str()?.to_string(),
                    inputs,
                    num_outputs: e.get("num_outputs")?.as_usize()?,
                },
            );
        }
        let mut constants = BTreeMap::new();
        if let Some(c) = v.opt("constants") {
            for (k, cv) in c.as_obj()? {
                if let Ok(x) = cv.as_f64() {
                    constants.insert(k.clone(), x);
                }
            }
        }
        Ok(Manifest { entries, constants })
    }

    pub fn constant_usize(&self, key: &str) -> Option<usize> {
        self.constants.get(key).map(|&x| x as usize)
    }
}

/// A host-side f32 tensor for artifact I/O.
#[derive(Debug, Clone)]
pub struct F32Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl F32Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> F32Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len().max(1));
        F32Tensor { shape, data }
    }

    pub fn vec(data: Vec<f32>) -> F32Tensor {
        F32Tensor { shape: vec![data.len()], data }
    }

    pub fn zeros(shape: Vec<usize>) -> F32Tensor {
        let n = shape.iter().product();
        F32Tensor { shape, data: vec![0.0; n] }
    }
}

/// The PJRT runtime: CPU client + artifact registry + executable cache.
///
/// The xla-backed client is gated behind the `pjrt` cargo feature (the
/// `xla` crate and its native archive are not vendored in this offline
/// build).  Without the feature the registry/manifest half still works;
/// [`Runtime::exec_f32`] returns an actionable error instead.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    dir: PathBuf,
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Dispatch counter (perf accounting).
    pub dispatches: u64,
}

impl Runtime {
    /// Resolve the artifacts directory: `CHIPSIM_ARTIFACTS` env var, else
    /// `./artifacts`, else `<crate root>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("CHIPSIM_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let local = PathBuf::from("artifacts");
        if local.join("manifest.json").exists() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Open the artifact registry at `dir` and create the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        anyhow::ensure!(
            manifest_path.exists(),
            "no manifest at {} — run `make artifacts` first",
            manifest_path.display()
        );
        let manifest = Manifest::parse(&json::parse_file(&manifest_path)?)?;
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
            Ok(Runtime { client, dir, manifest, cache: HashMap::new(), dispatches: 0 })
        }
        #[cfg(not(feature = "pjrt"))]
        Ok(Runtime { dir, manifest, dispatches: 0 })
    }

    /// Open at the default directory.
    pub fn open_default() -> anyhow::Result<Runtime> {
        Self::open(Self::default_dir())
    }

    #[cfg(feature = "pjrt")]
    fn compile(&mut self, name: &str) -> anyhow::Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling '{name}': {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with f32 inputs; returns the output tuple
    /// as flat f32 vectors.
    #[cfg(not(feature = "pjrt"))]
    pub fn exec_f32(&mut self, name: &str, _inputs: &[F32Tensor]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!(
            "cannot execute artifact '{name}': chipsim was built without the `pjrt` \
             feature (add the `xla` dependency and build with `--features pjrt`)"
        )
    }

    /// Execute artifact `name` with f32 inputs; returns the output tuple
    /// as flat f32 vectors.
    #[cfg(feature = "pjrt")]
    pub fn exec_f32(&mut self, name: &str, inputs: &[F32Tensor]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.compile(name)?;
        let entry = &self.manifest.entries[name];
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "'{name}' expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            anyhow::ensure!(
                t.shape == spec.shape,
                "'{name}' input {i}: shape {:?} != manifest {:?}",
                t.shape,
                spec.shape
            );
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshaping input {i} of '{name}': {e:?}"))?;
            literals.push(lit);
        }
        let exe = &self.cache[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e:?}"))?;
        self.dispatches += 1;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of '{name}': {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of '{name}': {e:?}"))?;
        anyhow::ensure!(
            parts.len() == entry.num_outputs,
            "'{name}' returned {} outputs, manifest says {}",
            parts.len(),
            entry.num_outputs
        );
        parts
            .iter()
            .enumerate()
            .map(|(i, l)| {
                l.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("reading output {i} of '{name}': {e:?}"))
            })
            .collect()
    }

    /// Names of available artifacts.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.manifest.entries.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_real_schema() {
        let text = r#"{
            "format": "hlo-text/return-tuple",
            "constants": {"transient_chunk": 256, "cg_iters": 64,
                          "imc_batch": 128, "thermal_sizes": [64, 256]},
            "entries": {
                "imc_batch_b128": {
                    "file": "imc_batch_b128.hlo.txt",
                    "inputs": [
                        {"shape": [128, 6], "dtype": "float32"},
                        {"shape": [6], "dtype": "float32"}
                    ],
                    "num_outputs": 1
                }
            }
        }"#;
        let m = Manifest::parse(&json::parse(text).unwrap()).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries["imc_batch_b128"];
        assert_eq!(e.inputs[0].shape, vec![128, 6]);
        assert_eq!(e.num_outputs, 1);
        assert_eq!(m.constant_usize("transient_chunk"), Some(256));
    }

    #[test]
    fn tensor_shape_checked() {
        let t = F32Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        F32Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/artifacts").is_err());
    }
}
