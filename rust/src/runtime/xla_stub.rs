//! Compile-time stand-in for the `xla` crate (LaurentMazare/xla-rs).
//!
//! The real crate and its `xla_extension` native archive are not vendored
//! in this offline build, but the PJRT glue in [`super`] must not rot
//! uncompiled either — CI type-checks it with `cargo check --features
//! pjrt` against this stub, which mirrors exactly the API surface the
//! glue uses (same type names, same signatures, same `Result` shapes).
//!
//! Every constructor that would touch native code returns an error, so a
//! `pjrt`-feature build without the real crate behaves like the
//! feature-less build: `Runtime::open` surfaces an actionable `Err`
//! instead of executing anything.  To use real PJRT, vendor the `xla`
//! dependency and replace the `use xla_stub as xla;` alias in
//! [`super`] with the crate import — no other code changes.

#![allow(dead_code)]

use std::path::Path;

/// Error type standing in for `xla::Error` (call sites only format it
/// with `{:?}`).
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "xla backend not vendored: this binary was built against the compile-check \
         stub (see rust/src/runtime/xla_stub.rs); add the real `xla` dependency to \
         execute PJRT artifacts"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}
