//! Workload-to-hardware mapping (paper §III-B, §V-A).
//!
//! The Global Manager allocates each layer of a DNN model to chiplets with
//! a user-supplied mapping function; CHIPSIM ships the Simba-inspired [29]
//! **nearest-neighbour** mapper: consecutive layers land on spatially
//! close chiplets to minimize NoI traffic, and a layer too large for any
//! single chiplet is divided into the fewest segments that fit, placed to
//! minimize communication cost.
//!
//! [`MemoryLedger`] tracks per-chiplet weight-memory occupancy so the
//! system state stays accurate across model map/unmap events.
//!
//! Multi-tenant co-execution adds a second dimension: [`placement`]
//! computes per-tenant chiplet masks (disjoint partition, interleaved,
//! greedy best-fit), and [`MapContext::allowed`] confines a request's
//! segments to its tenant's mask.

pub mod placement;

pub use placement::{PlacementPolicy, TenantDemand};

use crate::compute::SegmentWork;
use crate::config::{ChipletClass, HardwareConfig};
use crate::noc::topology::Topology;
use crate::workload::NeuralModel;

/// Minimum footprint charged for weight-less layers (pool/attention) so
/// they occupy a placement slot near their neighbours.
const MIN_LAYER_BYTES: u64 = 1024;

/// One placed segment of one layer.
#[derive(Debug, Clone)]
pub struct Segment {
    pub chiplet: usize,
    /// Fraction of the layer's work assigned to this segment.
    pub frac: f64,
    /// Memory bytes charged to the chiplet.
    pub mem_bytes: u64,
    pub work: SegmentWork,
}

/// Full mapping of a model: segments per layer.
#[derive(Debug, Clone)]
pub struct ModelMapping {
    pub layers: Vec<Vec<Segment>>,
}

impl ModelMapping {
    pub fn chiplets_of_layer(&self, l: usize) -> Vec<usize> {
        self.layers[l].iter().map(|s| s.chiplet).collect()
    }

    pub fn total_segments(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }
}

/// Per-chiplet free weight memory.
///
/// Supports cheap speculative probes: [`checkpoint`](Self::checkpoint)
/// opens a journal of subsequent alloc/release deltas, and
/// [`rollback`](Self::rollback) undoes them in O(changes) — the mapping
/// hot path used to clone the whole ledger (two `Vec<u64>` the size of
/// the system) for every placement attempt of every queued request.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    free: Vec<u64>,
    capacity: Vec<u64>,
    /// (chiplet, bytes, was_alloc) deltas since the outermost active
    /// checkpoint; empty (and not appended to) when no checkpoint is open.
    journal: Vec<(usize, u64, bool)>,
    journal_depth: usize,
}

/// Token returned by [`MemoryLedger::checkpoint`]; pass it back to
/// `rollback` or `commit`.
#[derive(Debug)]
#[must_use = "a checkpoint must be rolled back or committed"]
pub struct LedgerMark(usize);

impl MemoryLedger {
    pub fn new(hw: &HardwareConfig) -> MemoryLedger {
        let capacity: Vec<u64> = (0..hw.num_chiplets())
            .map(|i| {
                // I/O dies host weights for distribution, not mapped layers.
                if hw.chiplet_type(i).class == ChipletClass::Io {
                    0
                } else {
                    hw.chiplet_type(i).mem_bytes
                }
            })
            .collect();
        MemoryLedger {
            free: capacity.clone(),
            capacity,
            journal: Vec::new(),
            journal_depth: 0,
        }
    }

    /// Start journaling changes so they can be undone with
    /// [`rollback`](Self::rollback).  Checkpoints nest.
    pub fn checkpoint(&mut self) -> LedgerMark {
        self.journal_depth += 1;
        LedgerMark(self.journal.len())
    }

    /// Undo every alloc/release recorded since `mark`.
    pub fn rollback(&mut self, mark: LedgerMark) {
        while self.journal.len() > mark.0 {
            let (chiplet, bytes, was_alloc) = self.journal.pop().unwrap();
            if was_alloc {
                self.free[chiplet] += bytes;
            } else {
                self.free[chiplet] -= bytes;
            }
        }
        self.close_checkpoint();
    }

    /// Keep the changes recorded since `mark`.
    pub fn commit(&mut self, mark: LedgerMark) {
        debug_assert!(mark.0 <= self.journal.len());
        self.close_checkpoint();
    }

    fn close_checkpoint(&mut self) {
        self.journal_depth -= 1;
        if self.journal_depth == 0 {
            self.journal.clear();
        }
    }

    pub fn free_bytes(&self, chiplet: usize) -> u64 {
        self.free[chiplet]
    }

    pub fn capacity(&self, chiplet: usize) -> u64 {
        self.capacity[chiplet]
    }

    pub fn total_free(&self) -> u64 {
        self.free.iter().sum()
    }

    pub fn alloc(&mut self, chiplet: usize, bytes: u64) {
        assert!(self.free[chiplet] >= bytes, "over-allocation on chiplet {chiplet}");
        self.free[chiplet] -= bytes;
        if self.journal_depth > 0 {
            crate::prof::count(crate::prof::Counter::JournalOps, 1);
            self.journal.push((chiplet, bytes, true));
        }
    }

    pub fn release(&mut self, chiplet: usize, bytes: u64) {
        self.free[chiplet] += bytes;
        assert!(
            self.free[chiplet] <= self.capacity[chiplet],
            "double free on chiplet {chiplet}"
        );
        if self.journal_depth > 0 {
            crate::prof::count(crate::prof::Counter::JournalOps, 1);
            self.journal.push((chiplet, bytes, false));
        }
    }

    /// Release everything a mapping allocated.
    pub fn release_mapping(&mut self, mapping: &ModelMapping) {
        for layer in &mapping.layers {
            for seg in layer {
                self.release(seg.chiplet, seg.mem_bytes);
            }
        }
    }

    /// Occupancy fraction per chiplet (for utilization stats).
    pub fn occupancy(&self) -> Vec<f64> {
        self.free
            .iter()
            .zip(&self.capacity)
            .map(|(&f, &c)| if c == 0 { 0.0 } else { 1.0 - f as f64 / c as f64 })
            .collect()
    }
}

// ------------------------------------------------------------ Mapper trait

/// Read-only context handed to a [`Mapper`] on every placement attempt.
///
/// The co-simulation loop rebuilds this per attempt so the mapper always
/// sees the *current* system state (the thermal proxy in particular
/// changes as the run heats chiplets up).
pub struct MapContext<'a> {
    pub hw: &'a HardwareConfig,
    pub topo: &'a Topology,
    /// Per-chiplet heat proxy (the Global Manager passes accumulated
    /// dynamic energy) when thermal-aware mapping is enabled.
    pub heat: Option<&'a [f64]>,
    /// Hops of locality the mapper may trade to avoid the hottest chiplet.
    pub heat_weight_hops: f64,
    /// Per-chiplet placement mask of the requesting tenant: when `Some`,
    /// every segment must land on a chiplet with `allowed[c] == true`
    /// (multi-tenant placement, see [`placement`]).  `None` permits any
    /// compute chiplet — the single-tenant behaviour.
    pub allowed: Option<&'a [bool]>,
}

/// Pluggable mapping policy: how a model's layers land on chiplets.
///
/// Implementations must be pure placement policies: on success the ledger
/// reflects the allocation, on `None` it must be left untouched.  The
/// default is [`NearestNeighbor`]; inject alternatives through
/// `Simulation::builder().mapper(...)`.  `Send` so a simulation (which
/// owns its mapper) can move across fleet worker-pool threads.
pub trait Mapper: Send {
    fn name(&self) -> &'static str;

    /// Try to place the whole model; `None` (ledger untouched) if it does
    /// not fit right now.
    fn try_map(
        &self,
        ctx: &MapContext,
        model: &NeuralModel,
        ledger: &mut MemoryLedger,
    ) -> Option<ModelMapping>;
}

/// Stateless default policy: the Simba-style [`NearestNeighborMapper`]
/// behind the [`Mapper`] trait (honours the thermal-aware context).
#[derive(Debug, Clone, Copy, Default)]
pub struct NearestNeighbor;

impl Mapper for NearestNeighbor {
    fn name(&self) -> &'static str {
        "nearest-neighbor"
    }

    fn try_map(
        &self,
        ctx: &MapContext,
        model: &NeuralModel,
        ledger: &mut MemoryLedger,
    ) -> Option<ModelMapping> {
        let m = NearestNeighborMapper::new(ctx.hw, ctx.topo);
        let m = match ctx.heat {
            Some(h) if ctx.heat_weight_hops > 0.0 => m.with_heat(h, ctx.heat_weight_hops),
            _ => m,
        };
        let m = match ctx.allowed {
            Some(mask) => m.with_allowed(mask),
            None => m,
        };
        m.try_map(model, ledger)
    }
}

/// The Simba-style nearest-neighbour mapper, with an optional
/// **thermal-aware** extension (the THERMOS [7] direction the paper
/// cites): candidate chiplets are ranked by hop distance *plus* a heat
/// penalty derived from each chiplet's accumulated dissipation, steering
/// new models away from hotspots at a bounded locality cost.
pub struct NearestNeighborMapper<'a> {
    hw: &'a HardwareConfig,
    topo: &'a Topology,
    /// Optional per-chiplet heat score (any monotone temperature proxy —
    /// the Global Manager passes accumulated dynamic energy).
    heat: Option<Vec<f64>>,
    /// Hops of locality a mapper will trade to avoid the hottest chiplet.
    heat_weight_hops: f64,
    /// Optional tenant placement mask: segments only land where `true`.
    allowed: Option<&'a [bool]>,
}

impl<'a> NearestNeighborMapper<'a> {
    pub fn new(hw: &'a HardwareConfig, topo: &'a Topology) -> Self {
        NearestNeighborMapper { hw, topo, heat: None, heat_weight_hops: 0.0, allowed: None }
    }

    /// Confine placement to the chiplets a tenant's mask allows.
    pub fn with_allowed(mut self, mask: &'a [bool]) -> Self {
        self.allowed = Some(mask);
        self
    }

    /// Enable thermal-aware ranking: `heat` is normalized to [0, 1] and
    /// scaled to `weight_hops` equivalent hops of penalty.
    pub fn with_heat(mut self, heat: &[f64], weight_hops: f64) -> Self {
        let max = heat.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
        self.heat = Some(heat.iter().map(|&h| h / max).collect());
        self.heat_weight_hops = weight_hops;
        self
    }

    /// Ranking cost of a candidate: hop distance plus the heat penalty.
    fn cost(&self, c: usize, prev: &[usize]) -> f64 {
        let d = self.dist_to(c, prev) as f64;
        match &self.heat {
            Some(h) => d + h[c] * self.heat_weight_hops,
            None => d,
        }
    }

    fn mappable(&self, chiplet: usize) -> bool {
        let allowed = match self.allowed {
            Some(mask) => mask.get(chiplet).copied().unwrap_or(false),
            None => true,
        };
        allowed && self.hw.chiplet_type(chiplet).class != ChipletClass::Io
    }

    /// Hop distance from `c` to the nearest chiplet in `anchors`
    /// (0 if anchors empty — first layer placement is free; unreachable
    /// anchors score `usize::MAX` so faulted partitions repel placement).
    fn dist_to(&self, c: usize, anchors: &[usize]) -> usize {
        anchors.iter().map(|&a| self.topo.hops(a, c).unwrap_or(usize::MAX)).min().unwrap_or(0)
    }

    /// Try to map the whole model; returns `None` (ledger untouched) if it
    /// does not fit right now.
    ///
    /// Layers prefer chiplets not already hosting another layer of the
    /// same model: weight-stationary IMC dedicates crossbar banks per
    /// layer, and per-layer chiplets are what makes layer pipelining
    /// possible (two layers on one chiplet would serialize on its compute
    /// resource).  Reuse is allowed as a fallback when the system is full.
    pub fn try_map(&self, model: &NeuralModel, ledger: &mut MemoryLedger) -> Option<ModelMapping> {
        // Speculate directly on the ledger under a checkpoint: a failed
        // attempt rolls its allocations back in O(changes) instead of
        // paying a full ledger clone per probe (`place_layer` only
        // allocates on its success paths, so partial layers never leak).
        let mark = ledger.checkpoint();
        let mut layers: Vec<Vec<Segment>> = Vec::with_capacity(model.layers.len());
        let mut prev_chiplets: Vec<usize> = Vec::new();
        let mut used: std::collections::HashSet<usize> = std::collections::HashSet::new();
        for layer in &model.layers {
            let needed = layer.weight_bytes.max(MIN_LAYER_BYTES);
            let placed = self
                .place_layer(layer, needed, &prev_chiplets, &used, ledger)
                .or_else(|| {
                    // Fall back to allowing same-model chiplet reuse.
                    self.place_layer(layer, needed, &prev_chiplets, &Default::default(), ledger)
                });
            let Some(placed) = placed else {
                ledger.rollback(mark);
                return None;
            };
            for s in &placed {
                used.insert(s.chiplet);
            }
            prev_chiplets = placed.iter().map(|s| s.chiplet).collect();
            layers.push(placed);
        }
        ledger.commit(mark);
        Some(ModelMapping { layers })
    }

    /// Place one layer: single chiplet if it fits, else the fewest equal
    /// segments that fit, nearest-first.
    fn place_layer(
        &self,
        layer: &crate::workload::LayerDesc,
        needed: u64,
        prev: &[usize],
        exclude: &std::collections::HashSet<usize>,
        ledger: &mut MemoryLedger,
    ) -> Option<Vec<Segment>> {
        // Candidate chiplets sorted by distance to the previous layer
        // (ties by id => deterministic).
        let mut candidates: Vec<usize> = (0..self.hw.num_chiplets())
            .filter(|&c| self.mappable(c) && ledger.free_bytes(c) > 0 && !exclude.contains(&c))
            .collect();
        candidates.sort_by(|&a, &b| {
            self.cost(a, prev)
                .partial_cmp(&self.cost(b, prev))
                .unwrap()
                .then(a.cmp(&b))
        });

        // 1. Whole layer on the nearest chiplet with room.
        if let Some(&c) = candidates.iter().find(|&&c| ledger.free_bytes(c) >= needed) {
            ledger.alloc(c, needed);
            return Some(vec![Segment {
                chiplet: c,
                frac: 1.0,
                mem_bytes: needed,
                work: SegmentWork::from_layer(layer, 1.0),
            }]);
        }

        // 2. Fewest equal segments: try k = 2.. until k nearest chiplets
        // each hold needed/k bytes.
        let max_k = candidates.len().max(1);
        for k in 2..=max_k {
            let per = needed.div_ceil(k as u64);
            let fitting: Vec<usize> = candidates
                .iter()
                .copied()
                .filter(|&c| ledger.free_bytes(c) >= per)
                .take(k)
                .collect();
            if fitting.len() == k {
                let frac = 1.0 / k as f64;
                let segs = fitting
                    .into_iter()
                    .map(|c| {
                        ledger.alloc(c, per);
                        Segment {
                            chiplet: c,
                            frac,
                            mem_bytes: per,
                            work: SegmentWork::from_layer(layer, frac),
                        }
                    })
                    .collect();
                return Some(segs);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ModelKind, NeuralModel};

    fn setup(rows: usize, cols: usize) -> (HardwareConfig, Topology) {
        let hw = HardwareConfig::homogeneous_mesh(rows, cols);
        let topo = Topology::build(&hw);
        (hw, topo)
    }

    #[test]
    fn ledger_rollback_restores_free_bytes() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let mut ledger = MemoryLedger::new(&hw);
        let before: Vec<u64> = (0..4).map(|c| ledger.free_bytes(c)).collect();
        let mark = ledger.checkpoint();
        ledger.alloc(0, 1_000);
        ledger.alloc(1, 2_000);
        ledger.release(0, 500);
        ledger.rollback(mark);
        let after: Vec<u64> = (0..4).map(|c| ledger.free_bytes(c)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn ledger_commit_keeps_changes_and_nested_rollback_is_scoped() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let mut ledger = MemoryLedger::new(&hw);
        let outer = ledger.checkpoint();
        ledger.alloc(0, 1_000);
        let inner = ledger.checkpoint();
        ledger.alloc(0, 50);
        ledger.rollback(inner); // undoes only the inner 50
        ledger.commit(outer);
        assert_eq!(ledger.free_bytes(0), ledger.capacity(0) - 1_000);
        // Changes outside any checkpoint are plain mutations.
        ledger.release(0, 1_000);
        assert_eq!(ledger.free_bytes(0), ledger.capacity(0));
    }

    #[test]
    fn failed_try_map_leaves_ledger_untouched_without_cloning() {
        // AlexNet does not fit a 2x2 system: probe must roll back fully.
        let (hw, topo) = setup(2, 2);
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::AlexNet);
        let before = ledger.total_free();
        assert!(mapper.try_map(&m, &mut ledger).is_none());
        assert_eq!(ledger.total_free(), before);
        for c in 0..hw.num_chiplets() {
            assert_eq!(ledger.free_bytes(c), ledger.capacity(c));
        }
    }

    #[test]
    fn resnet18_maps_on_10x10() {
        let (hw, topo) = setup(10, 10);
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::ResNet18);
        let mapping = mapper.try_map(&m, &mut ledger).expect("fits");
        assert_eq!(mapping.layers.len(), m.layers.len());
        // Memory accounting: allocated == sum of segment bytes.
        let total: u64 = mapping.layers.iter().flatten().map(|s| s.mem_bytes).sum();
        let used = 100 * 2 * 1024 * 1024 - ledger.total_free();
        assert_eq!(total, used);
    }

    #[test]
    fn alexnet_fc_layers_are_split() {
        let (hw, topo) = setup(10, 10);
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::AlexNet);
        let mapping = mapper.try_map(&m, &mut ledger).expect("fits");
        // fc6 is ~37.7 MB > 2 MiB -> must be many segments.
        let fc6_idx = m.layers.iter().position(|l| l.name == "fc6").unwrap();
        assert!(mapping.layers[fc6_idx].len() >= 18, "{}", mapping.layers[fc6_idx].len());
        // Fractions sum to ~1.
        let fsum: f64 = mapping.layers[fc6_idx].iter().map(|s| s.frac).sum();
        assert!((fsum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn consecutive_layers_are_near() {
        let (hw, topo) = setup(10, 10);
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::ResNet18);
        let mapping = mapper.try_map(&m, &mut ledger).unwrap();
        // Average consecutive-layer hop distance should be small on an
        // empty 10x10 mesh (nearest-neighbour property).
        let mut total_hops = 0usize;
        let mut pairs = 0usize;
        for w in mapping.layers.windows(2) {
            for a in &w[0] {
                for b in &w[1] {
                    total_hops += topo.hops(a.chiplet, b.chiplet).expect("mesh is connected");
                    pairs += 1;
                }
            }
        }
        let avg = total_hops as f64 / pairs as f64;
        assert!(avg < 3.0, "avg consecutive-layer distance {avg}");
    }

    #[test]
    fn unmap_restores_ledger() {
        let (hw, topo) = setup(10, 10);
        let mut ledger = MemoryLedger::new(&hw);
        let before = ledger.total_free();
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::ResNet50);
        let mapping = mapper.try_map(&m, &mut ledger).unwrap();
        assert!(ledger.total_free() < before);
        ledger.release_mapping(&mapping);
        assert_eq!(ledger.total_free(), before);
    }

    #[test]
    fn failed_map_leaves_ledger_untouched() {
        let (hw, topo) = setup(2, 2); // 4 chiplets: 8 MiB total
        let mut ledger = MemoryLedger::new(&hw);
        let before = ledger.total_free();
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        // AlexNet (~61 MB) cannot fit.
        let m = NeuralModel::build(ModelKind::AlexNet);
        assert!(mapper.try_map(&m, &mut ledger).is_none());
        assert_eq!(ledger.total_free(), before);
    }

    #[test]
    fn io_chiplets_never_host_segments() {
        let hw = HardwareConfig::vit_mesh(10, 10);
        let topo = Topology::build(&hw);
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::VitB16);
        let mapping = mapper.try_map(&m, &mut ledger).expect("vit fits on 96 imc chiplets");
        for seg in mapping.layers.iter().flatten() {
            assert!(!hw.io_chiplets.contains(&seg.chiplet));
        }
    }

    #[test]
    fn allowed_mask_confines_segments() {
        let (hw, topo) = setup(6, 6);
        let mut ledger = MemoryLedger::new(&hw);
        // Allow only the top three rows (18 chiplets, 36 MiB): ResNet18
        // (~11.7 MB) fits inside the mask.
        let mask: Vec<bool> = (0..hw.num_chiplets()).map(|c| c < 18).collect();
        let mapper = NearestNeighborMapper::new(&hw, &topo).with_allowed(&mask);
        let m = NeuralModel::build(ModelKind::ResNet18);
        let mapping = mapper.try_map(&m, &mut ledger).expect("fits inside the mask");
        for seg in mapping.layers.iter().flatten() {
            assert!(mask[seg.chiplet], "segment on disallowed chiplet {}", seg.chiplet);
        }
        // Nothing outside the mask was charged.
        for c in 18..hw.num_chiplets() {
            assert_eq!(ledger.free_bytes(c), ledger.capacity(c));
        }
        // An all-false mask can never map anything, and rolls back fully.
        let none = vec![false; hw.num_chiplets()];
        let before = ledger.total_free();
        let blocked = NearestNeighborMapper::new(&hw, &topo).with_allowed(&none);
        assert!(blocked.try_map(&m, &mut ledger).is_none());
        assert_eq!(ledger.total_free(), before);
    }

    #[test]
    fn trait_object_matches_concrete_mapper() {
        let (hw, topo) = setup(10, 10);
        let ctx =
            MapContext { hw: &hw, topo: &topo, heat: None, heat_weight_hops: 0.0, allowed: None };
        let m = NeuralModel::build(ModelKind::ResNet18);
        let mut l1 = MemoryLedger::new(&hw);
        let mut l2 = MemoryLedger::new(&hw);
        let mapper: Box<dyn Mapper> = Box::new(NearestNeighbor);
        let a = mapper.try_map(&ctx, &m, &mut l1).expect("fits");
        let b = NearestNeighborMapper::new(&hw, &topo).try_map(&m, &mut l2).expect("fits");
        let ca: Vec<usize> = a.layers.iter().flatten().map(|s| s.chiplet).collect();
        let cb: Vec<usize> = b.layers.iter().flatten().map(|s| s.chiplet).collect();
        assert_eq!(ca, cb);
        assert_eq!(l1.total_free(), l2.total_free());
    }

    #[test]
    fn many_models_fill_and_then_reject() {
        let (hw, topo) = setup(4, 4); // 32 MiB total
        let mut ledger = MemoryLedger::new(&hw);
        let mapper = NearestNeighborMapper::new(&hw, &topo);
        let m = NeuralModel::build(ModelKind::ResNet18); // ~11.7 MB
        let m1 = mapper.try_map(&m, &mut ledger);
        assert!(m1.is_some());
        let m2 = mapper.try_map(&m, &mut ledger);
        assert!(m2.is_some());
        // Third won't fit (needs ~11.7 of ~8.6 MiB left).
        assert!(mapper.try_map(&m, &mut ledger).is_none());
    }
}
