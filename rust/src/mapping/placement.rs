//! Tenant placement policies for multi-tenant co-execution.
//!
//! A [`crate::serving::mix::WorkloadMix`] puts N tenants on one chiplet
//! system at the same time.  Before the co-simulation starts, a
//! [`PlacementPolicy`] turns the tenants' memory demands into per-tenant
//! chiplet masks; during the run, every mapping attempt of a tenant's
//! request is confined to its mask via [`super::MapContext::allowed`].
//!
//! All feasibility probing happens on a [`MemoryLedger`] under a journal
//! checkpoint: an infeasible mix rolls its speculative allocations back
//! in O(changes) and leaves the caller's ledger untouched — the same
//! mechanism the mapping hot path uses for failed placement attempts.

use crate::config::{ChipletClass, HardwareConfig};
use crate::mapping::MemoryLedger;
use crate::noc::topology::Topology;
use crate::workload::{ModelKind, NeuralModel};

/// How a workload mix divides the chiplet system among its tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Contiguous spatial partition: compute chiplets are split into
    /// disjoint runs (row-major order) sized proportionally to each
    /// tenant's memory demand.  No chiplet serves two tenants, so
    /// interference is confined to links their X-Y routes share.
    DisjointPartition,
    /// Every tenant may map anywhere: full sharing of compute chiplets
    /// and the NoI — the maximum-interference baseline.
    Interleaved,
    /// Greedy best-fit: tenants (largest demand first) grab the
    /// topologically tightest cluster of still-unassigned chiplets whose
    /// capacity covers their demand, journaled on the [`MemoryLedger`];
    /// leftover chiplets then fold into the nearest region so no
    /// capacity is stranded outside every mask.  Masks are disjoint,
    /// like [`PlacementPolicy::DisjointPartition`], but regions follow
    /// demand and topology instead of a fixed split.
    GreedyBestFit,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::DisjointPartition => "disjoint",
            PlacementPolicy::Interleaved => "interleaved",
            PlacementPolicy::GreedyBestFit => "greedy",
        }
    }

    pub fn from_name(s: &str) -> Option<PlacementPolicy> {
        match s {
            "disjoint" | "partition" | "disjoint-partition" => {
                Some(PlacementPolicy::DisjointPartition)
            }
            "interleaved" | "shared" => Some(PlacementPolicy::Interleaved),
            "greedy" | "best-fit" | "greedy-best-fit" => Some(PlacementPolicy::GreedyBestFit),
            _ => None,
        }
    }

    /// Whether this policy guarantees pairwise-disjoint tenant masks.
    pub fn is_disjoint(&self) -> bool {
        !matches!(self, PlacementPolicy::Interleaved)
    }
}

/// Memory demand of one tenant, derived from the models it serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantDemand {
    /// Sizing weight: bytes to co-host one instance of each distinct
    /// model kind the tenant serves (proportional-share numerator).
    pub weight_bytes: u64,
    /// Hard floor: the tenant's largest single model must fit its
    /// region, or no request of that kind can ever map.
    pub min_bytes: u64,
}

impl TenantDemand {
    pub fn new(weight_bytes: u64, min_bytes: u64) -> TenantDemand {
        TenantDemand { weight_bytes: weight_bytes.max(1), min_bytes }
    }

    /// Demand of a tenant serving the given model kinds.
    pub fn of_kinds(kinds: &[ModelKind]) -> TenantDemand {
        let mut distinct: Vec<ModelKind> = Vec::new();
        for &k in kinds {
            if !distinct.contains(&k) {
                distinct.push(k);
            }
        }
        let sizes: Vec<u64> =
            distinct.iter().map(|&k| NeuralModel::build(k).total_weight_bytes()).collect();
        TenantDemand::new(sizes.iter().sum(), sizes.iter().copied().max().unwrap_or(0))
    }
}

/// Compute chiplet ids (non-I/O), ascending — the row-major order the
/// disjoint partitioner splits.
fn compute_chiplets(hw: &HardwareConfig) -> Vec<usize> {
    (0..hw.num_chiplets()).filter(|&c| hw.chiplet_type(c).class != ChipletClass::Io).collect()
}

fn masks_of(regions: &[Vec<usize>], n: usize) -> Vec<Vec<bool>> {
    regions
        .iter()
        .map(|region| {
            let mut mask = vec![false; n];
            for &c in region {
                mask[c] = true;
            }
            mask
        })
        .collect()
}

/// Compute per-tenant placement masks for `demands` under `policy`.
///
/// The ledger is used as a speculative scratchpad (capacity probing under
/// a journal checkpoint) and is restored to its entry state before
/// returning — on success *and* on an infeasible mix.
pub fn compute_placements(
    policy: PlacementPolicy,
    hw: &HardwareConfig,
    topo: &Topology,
    demands: &[TenantDemand],
    ledger: &mut MemoryLedger,
) -> anyhow::Result<Vec<Vec<bool>>> {
    anyhow::ensure!(!demands.is_empty(), "placement needs at least one tenant");
    let compute = compute_chiplets(hw);
    anyhow::ensure!(
        !compute.is_empty(),
        "hardware has no compute chiplets to place tenants on"
    );
    match policy {
        PlacementPolicy::Interleaved => {
            let total: u64 = compute.iter().map(|&c| ledger.capacity(c)).sum();
            for (i, d) in demands.iter().enumerate() {
                anyhow::ensure!(
                    d.min_bytes <= total,
                    "tenant {i}: largest model ({} bytes) exceeds total system \
                     capacity ({total} bytes)",
                    d.min_bytes
                );
            }
            Ok(vec![masks_of(&[compute.clone()], hw.num_chiplets()).remove(0); demands.len()])
        }
        PlacementPolicy::DisjointPartition => {
            disjoint_partition(hw, demands, &compute, ledger)
        }
        PlacementPolicy::GreedyBestFit => greedy_best_fit(hw, topo, demands, &compute, ledger),
    }
}

/// Largest-remainder apportionment of `n` chiplets over demand weights;
/// every tenant gets at least one chiplet.
fn apportion(n: usize, demands: &[TenantDemand]) -> anyhow::Result<Vec<usize>> {
    let t = demands.len();
    anyhow::ensure!(
        t <= n,
        "{t} tenants cannot partition {n} compute chiplets (need one each)"
    );
    let total_w: u128 = demands.iter().map(|d| d.weight_bytes as u128).sum();
    let mut shares: Vec<usize> = Vec::with_capacity(t);
    let mut remainders: Vec<(u128, usize)> = Vec::with_capacity(t);
    for (i, d) in demands.iter().enumerate() {
        let exact = n as u128 * d.weight_bytes as u128;
        shares.push((exact / total_w) as usize);
        remainders.push((exact % total_w, i));
    }
    let mut assigned: usize = shares.iter().sum();
    // Hand leftovers to the largest remainders (ties resolved by tenant
    // index, so the split is deterministic).
    remainders.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut k = 0;
    while assigned < n {
        shares[remainders[k % t].1] += 1;
        assigned += 1;
        k += 1;
    }
    // Guarantee a non-empty region per tenant by shaving the largest.
    for i in 0..t {
        while shares[i] == 0 {
            let largest = (0..t).max_by_key(|&j| shares[j]).expect("t >= 1");
            anyhow::ensure!(
                shares[largest] > 1,
                "cannot give every tenant a chiplet: {n} compute chiplets, {t} tenants"
            );
            shares[largest] -= 1;
            shares[i] += 1;
        }
    }
    Ok(shares)
}

fn disjoint_partition(
    hw: &HardwareConfig,
    demands: &[TenantDemand],
    compute: &[usize],
    ledger: &mut MemoryLedger,
) -> anyhow::Result<Vec<Vec<bool>>> {
    let shares = apportion(compute.len(), demands)?;
    let mark = ledger.checkpoint();
    let mut regions: Vec<Vec<usize>> = Vec::with_capacity(demands.len());
    let mut next = 0usize;
    for (i, (&share, d)) in shares.iter().zip(demands).enumerate() {
        let region: Vec<usize> = compute[next..next + share].to_vec();
        next += share;
        let mut capacity = 0u64;
        for &c in &region {
            // Booking the chiplet's whole free capacity marks it taken in
            // the journal; `alloc` asserts nothing is booked twice.
            let free = ledger.free_bytes(c);
            ledger.alloc(c, free);
            capacity += free;
        }
        if capacity < d.min_bytes {
            ledger.rollback(mark);
            anyhow::bail!(
                "infeasible mix: tenant {i}'s partition ({} chiplets, {capacity} bytes) \
                 cannot hold its largest model ({} bytes)",
                region.len(),
                d.min_bytes
            );
        }
        regions.push(region);
    }
    // Placement is a pure probe: undo the speculative capacity bookings.
    ledger.rollback(mark);
    Ok(masks_of(&regions, hw.num_chiplets()))
}

fn greedy_best_fit(
    hw: &HardwareConfig,
    topo: &Topology,
    demands: &[TenantDemand],
    compute: &[usize],
    ledger: &mut MemoryLedger,
) -> anyhow::Result<Vec<Vec<bool>>> {
    anyhow::ensure!(
        demands.len() <= compute.len(),
        "{} tenants cannot partition {} compute chiplets (need one each)",
        demands.len(),
        compute.len()
    );
    // Largest demand first (ties by index) so big tenants still find
    // contiguous room; region growth is nearest-to-region, like the
    // nearest-neighbour mapper's layer chaining.
    let mut order: Vec<usize> = (0..demands.len()).collect();
    order.sort_by(|&a, &b| {
        demands[b].weight_bytes.cmp(&demands[a].weight_bytes).then(a.cmp(&b))
    });
    let mark = ledger.checkpoint();
    let mut taken = vec![false; hw.num_chiplets()];
    let mut regions: Vec<Vec<usize>> = vec![Vec::new(); demands.len()];
    for &i in &order {
        let d = &demands[i];
        let want = d.weight_bytes.max(d.min_bytes);
        let mut capacity = 0u64;
        let mut region: Vec<usize> = Vec::new();
        // Reserve one chiplet per still-unplaced tenant so later tenants
        // are never left regionless by an over-greedy earlier one.
        let placed_after = order.iter().filter(|&&j| regions[j].is_empty() && j != i).count();
        loop {
            let free_left = compute.iter().filter(|&&c| !taken[c]).count();
            if capacity >= want || free_left <= placed_after {
                break;
            }
            let candidate = compute
                .iter()
                .copied()
                .filter(|&c| !taken[c])
                .min_by_key(|&c| {
                    let dist = region
                        .iter()
                        .map(|&r| topo.hops(r, c).unwrap_or(usize::MAX))
                        .min()
                        .unwrap_or(0);
                    (dist, c)
                });
            let Some(c) = candidate else { break };
            let free = ledger.free_bytes(c);
            ledger.alloc(c, free);
            taken[c] = true;
            capacity += free;
            region.push(c);
        }
        if capacity < d.min_bytes || region.is_empty() {
            ledger.rollback(mark);
            anyhow::bail!(
                "infeasible mix: tenant {i} found only {capacity} bytes across {} \
                 chiplets for a {} byte model (journal rolled back)",
                region.len(),
                d.min_bytes
            );
        }
        regions[i] = region;
    }
    // Fold leftover chiplets into the nearest region (ties: lower tenant
    // index).  Stranding them outside every mask would cap each tenant at
    // roughly one resident model while part of the machine sits idle.
    let leftovers: Vec<usize> = compute.iter().copied().filter(|&c| !taken[c]).collect();
    for c in leftovers {
        let owner = (0..regions.len())
            .min_by_key(|&i| {
                let dist =
                    regions[i].iter().map(|&r| topo.hops(r, c).unwrap_or(usize::MAX)).min().unwrap_or(0);
                (dist, i)
            })
            .expect("every tenant has a region by now");
        regions[owner].push(c);
        taken[c] = true;
    }
    for region in &mut regions {
        region.sort_unstable();
    }
    ledger.rollback(mark);
    Ok(masks_of(&regions, hw.num_chiplets()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propkit::check;

    fn mesh(rows: usize, cols: usize) -> (HardwareConfig, Topology) {
        let hw = HardwareConfig::homogeneous_mesh(rows, cols);
        let topo = Topology::build(&hw);
        (hw, topo)
    }

    fn ledger_is_pristine(hw: &HardwareConfig, ledger: &MemoryLedger) -> bool {
        (0..hw.num_chiplets()).all(|c| ledger.free_bytes(c) == ledger.capacity(c))
    }

    #[test]
    fn interleaved_masks_cover_all_compute_chiplets() {
        let hw = HardwareConfig::vit_mesh(6, 6);
        let topo = Topology::build(&hw);
        let mut ledger = MemoryLedger::new(&hw);
        let demands = vec![TenantDemand::new(1_000, 1_000); 3];
        let masks = compute_placements(
            PlacementPolicy::Interleaved,
            &hw,
            &topo,
            &demands,
            &mut ledger,
        )
        .unwrap();
        assert_eq!(masks.len(), 3);
        for mask in &masks {
            for c in 0..hw.num_chiplets() {
                let is_io = hw.chiplet_type(c).class == ChipletClass::Io;
                assert_eq!(mask[c], !is_io, "chiplet {c}");
            }
        }
        assert!(ledger_is_pristine(&hw, &ledger));
    }

    #[test]
    fn disjoint_shares_follow_demand() {
        let (hw, topo) = mesh(6, 6);
        let mut ledger = MemoryLedger::new(&hw);
        // 3:1 demand ratio over 36 chiplets -> 27 + 9.
        let demands = vec![
            TenantDemand::new(3_000_000, 1_000_000),
            TenantDemand::new(1_000_000, 500_000),
        ];
        let masks = compute_placements(
            PlacementPolicy::DisjointPartition,
            &hw,
            &topo,
            &demands,
            &mut ledger,
        )
        .unwrap();
        let sizes: Vec<usize> =
            masks.iter().map(|m| m.iter().filter(|&&b| b).count()).collect();
        assert_eq!(sizes, vec![27, 9]);
        assert!(ledger_is_pristine(&hw, &ledger));
    }

    #[test]
    fn infeasible_mix_errors_and_rolls_the_journal_back() {
        let (hw, topo) = mesh(2, 2); // 4 chiplets x 2 MiB = 8 MiB
        let mut ledger = MemoryLedger::new(&hw);
        let huge = 64 * 1024 * 1024;
        for policy in [PlacementPolicy::DisjointPartition, PlacementPolicy::GreedyBestFit] {
            let demands =
                vec![TenantDemand::new(huge, huge), TenantDemand::new(1_000, 1_000)];
            let err = compute_placements(policy, &hw, &topo, &demands, &mut ledger)
                .err()
                .expect("mix cannot fit");
            assert!(err.to_string().contains("infeasible"), "{err}");
            assert!(
                ledger_is_pristine(&hw, &ledger),
                "{policy:?} left speculative allocations behind"
            );
        }
    }

    #[test]
    fn greedy_folds_leftover_chiplets_into_regions() {
        let (hw, topo) = mesh(8, 8); // 64 chiplets, far more than demand needs
        let mut ledger = MemoryLedger::new(&hw);
        let demands = vec![
            TenantDemand::new(20 * 1024 * 1024, 20 * 1024 * 1024),
            TenantDemand::new(8 * 1024 * 1024, 8 * 1024 * 1024),
        ];
        let masks = compute_placements(
            PlacementPolicy::GreedyBestFit,
            &hw,
            &topo,
            &demands,
            &mut ledger,
        )
        .unwrap();
        // Every compute chiplet belongs to exactly one tenant: nothing
        // is stranded outside both masks.
        for c in 0..hw.num_chiplets() {
            let owners = masks.iter().filter(|m| m[c]).count();
            assert_eq!(owners, 1, "chiplet {c} owned by {owners} tenants");
        }
        assert!(ledger_is_pristine(&hw, &ledger));
    }

    #[test]
    fn more_tenants_than_chiplets_is_an_error() {
        let (hw, topo) = mesh(2, 2);
        let mut ledger = MemoryLedger::new(&hw);
        let demands = vec![TenantDemand::new(1_000, 100); 5];
        for policy in [PlacementPolicy::DisjointPartition, PlacementPolicy::GreedyBestFit] {
            assert!(compute_placements(policy, &hw, &topo, &demands, &mut ledger).is_err());
            assert!(ledger_is_pristine(&hw, &ledger));
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [
            PlacementPolicy::DisjointPartition,
            PlacementPolicy::Interleaved,
            PlacementPolicy::GreedyBestFit,
        ] {
            assert_eq!(PlacementPolicy::from_name(p.name()), Some(p));
        }
        assert!(PlacementPolicy::from_name("no-such-policy").is_none());
        assert!(PlacementPolicy::DisjointPartition.is_disjoint());
        assert!(PlacementPolicy::GreedyBestFit.is_disjoint());
        assert!(!PlacementPolicy::Interleaved.is_disjoint());
    }

    /// The headline invariant: disjoint policies never double-book a
    /// chiplet, every tenant gets a non-empty region, and the ledger is
    /// restored whether the mix fits or not.
    #[test]
    fn prop_disjoint_policies_never_double_book() {
        check("placement-disjoint", 60, |rng| {
            let rows = 2 + rng.below_usize(5);
            let cols = 2 + rng.below_usize(5);
            let (hw, topo) = mesh(rows, cols);
            let tenants = 1 + rng.below_usize(4);
            let demands: Vec<TenantDemand> = (0..tenants)
                .map(|_| {
                    let min = rng.range_u64(1_000, 6 * 1024 * 1024);
                    TenantDemand::new(min + rng.range_u64(0, 8 * 1024 * 1024), min)
                })
                .collect();
            let policy = if rng.chance(0.5) {
                PlacementPolicy::DisjointPartition
            } else {
                PlacementPolicy::GreedyBestFit
            };
            let mut ledger = MemoryLedger::new(&hw);
            let result = compute_placements(policy, &hw, &topo, &demands, &mut ledger);
            prop_assert!(
                ledger_is_pristine(&hw, &ledger),
                "{policy:?} must restore the ledger (feasible or not)"
            );
            if let Ok(masks) = result {
                prop_assert!(masks.len() == tenants, "one mask per tenant");
                let mut owner = vec![usize::MAX; hw.num_chiplets()];
                for (t, mask) in masks.iter().enumerate() {
                    let mut region = 0usize;
                    for (c, &allowed) in mask.iter().enumerate() {
                        if !allowed {
                            continue;
                        }
                        region += 1;
                        prop_assert!(
                            hw.chiplet_type(c).class != ChipletClass::Io,
                            "tenant {t} was handed I/O chiplet {c}"
                        );
                        prop_assert!(
                            owner[c] == usize::MAX,
                            "chiplet {c} double-booked by tenants {} and {t}",
                            owner[c]
                        );
                        owner[c] = t;
                    }
                    prop_assert!(region > 0, "tenant {t} got an empty region");
                }
            }
            Ok(())
        });
    }
}
