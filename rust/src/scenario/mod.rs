//! Named scenarios and the parallel sweep runner.
//!
//! A [`Scenario`] bundles the three inputs of a CHIPSIM run — hardware,
//! parameters, and a seeded workload — under a stable name, so fidelity
//! and topology combinations are one-liners instead of hand-assembled
//! preset code duplicated across `main.rs`, `experiments/`, and the
//! examples.  [`Registry::builtin`] names every preset the repository
//! ships; register your own with [`Registry::register`].
//!
//! [`SweepRunner`] executes a batch of scenarios across threads with
//! deterministic per-scenario seeds: because every scenario run is an
//! independent, fully-seeded simulation, the parallel results are
//! byte-identical to a sequential sweep (asserted by
//! `rust/tests/builder_api.rs`).
//!
//! ```no_run
//! use chipsim::scenario::{Registry, SweepRunner};
//!
//! let reg = Registry::builtin();
//! let report = reg.get("mesh-10x10-cnn").unwrap().run(0xC0FFEE).unwrap();
//! println!("{}", report.summary());
//!
//! let outcomes = SweepRunner::new()
//!     .threads(4)
//!     .run(&reg, &["mesh-10x10-cnn", "hetero-mesh", "floret", "vit-pipeline"])
//!     .unwrap();
//! for o in &outcomes {
//!     println!("{}: {:?} models", o.scenario, o.result.as_ref().map(|r| r.outcomes.len()));
//! }
//! ```

use std::sync::Arc;

use crate::config::{HardwareConfig, SimParams, WorkloadConfig};
use crate::dtm::GovernorSpec;
use crate::fault::FaultPlan;
use crate::mapping::PlacementPolicy;
use crate::serving::{
    ArrivalSpec, MixReport, SteadyState, TenantSpec, TraceEvent, TrafficReport, TrafficSpec,
    WorkloadMix,
};
use crate::sim::{SimReport, Simulation, ThermalSpec};
use crate::util::rng::Rng;
use crate::workload::{ModelKind, ALL_CNNS};

type HwFn = Arc<dyn Fn() -> HardwareConfig + Send + Sync>;
type WlFn = Arc<dyn Fn(u64) -> WorkloadConfig + Send + Sync>;
type TrafficFn = Arc<dyn Fn(u64) -> TrafficSpec + Send + Sync>;
type MixFn = Arc<dyn Fn(u64) -> WorkloadMix + Send + Sync>;

/// What a scenario runs: a one-shot batch workload, a sustained
/// open-loop traffic stream (see [`crate::serving`]), or a multi-tenant
/// co-execution mix (see [`crate::serving::mix`]).
#[derive(Clone)]
enum Work {
    Batch(WlFn),
    Traffic(TrafficFn),
    Mix(MixFn),
}

/// Construct one of the named hardware presets.  This is the single
/// source of truth used by `chipsim run --topo ...`, the builtin
/// registry, and the examples (`petals`/`ccds` are ignored by presets
/// that do not need them).
pub fn hardware_preset(
    name: &str,
    rows: usize,
    cols: usize,
    petals: usize,
    ccds: usize,
) -> anyhow::Result<HardwareConfig> {
    Ok(match name {
        "mesh" => HardwareConfig::homogeneous_mesh(rows, cols),
        "hetero" => HardwareConfig::heterogeneous_mesh(rows, cols),
        "floret" => HardwareConfig::floret(rows, cols, petals),
        "vit" => HardwareConfig::vit_mesh(rows, cols),
        "ccd" => HardwareConfig::ccd_star(ccds),
        other => anyhow::bail!(
            "unknown hardware preset '{other}' (expected mesh|hetero|floret|vit|ccd)"
        ),
    })
}

/// Fleet overlay for a traffic scenario: how many replica boards serve
/// the (global) arrival stream, behind which routing and autoscaling
/// policies.  Names resolve through [`crate::fleet::parse_routing`] /
/// [`crate::fleet::parse_autoscaler`]; `chipsim fleet --scenario NAME`
/// applies the overlay, and every knob stays CLI-overridable.
#[derive(Debug, Clone)]
pub struct FleetPreset {
    pub replicas: usize,
    pub max_replicas: usize,
    pub routing: &'static str,
    /// `"none"` fixes the fleet size.
    pub autoscale: &'static str,
    pub epoch_ns: u64,
    pub cold_start_ns: u64,
    pub emergency_c: Option<f64>,
}

/// A named, reproducible co-simulation setup.
#[derive(Clone)]
pub struct Scenario {
    pub name: String,
    /// One-line description shown by `chipsim scenarios`.
    pub about: String,
    hardware: HwFn,
    params: SimParams,
    work: Work,
    /// Thermal coupling applied when the scenario builds its simulation
    /// (Off unless set with [`Scenario::with_thermal`]).
    thermal: ThermalSpec,
    /// Fleet overlay (None for single-board scenarios).
    fleet: Option<FleetPreset>,
    /// Fault-injection plan applied when the scenario builds its
    /// simulation (and, for fleet scenarios, its dispatcher).
    faults: Option<FaultPlan>,
    /// Seed used when the caller does not supply one.
    pub default_seed: u64,
}

impl Scenario {
    pub fn new(
        name: &str,
        about: &str,
        hardware: impl Fn() -> HardwareConfig + Send + Sync + 'static,
        params: SimParams,
        workload: impl Fn(u64) -> WorkloadConfig + Send + Sync + 'static,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            about: about.to_string(),
            hardware: Arc::new(hardware),
            params,
            work: Work::Batch(Arc::new(workload)),
            thermal: ThermalSpec::Off,
            fleet: None,
            faults: None,
            default_seed: 0xC0FFEE,
        }
    }

    /// A sustained-traffic scenario: instead of a one-shot batch, it
    /// streams the [`TrafficSpec`] produced for the run's seed.
    pub fn traffic(
        name: &str,
        about: &str,
        hardware: impl Fn() -> HardwareConfig + Send + Sync + 'static,
        params: SimParams,
        spec: impl Fn(u64) -> TrafficSpec + Send + Sync + 'static,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            about: about.to_string(),
            hardware: Arc::new(hardware),
            params,
            work: Work::Traffic(Arc::new(spec)),
            thermal: ThermalSpec::Off,
            fleet: None,
            faults: None,
            default_seed: 0xC0FFEE,
        }
    }

    /// A multi-tenant co-execution scenario: N tenants share the chiplet
    /// system under a placement policy (see [`crate::serving::mix`]).
    pub fn mix(
        name: &str,
        about: &str,
        hardware: impl Fn() -> HardwareConfig + Send + Sync + 'static,
        params: SimParams,
        spec: impl Fn(u64) -> WorkloadMix + Send + Sync + 'static,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            about: about.to_string(),
            hardware: Arc::new(hardware),
            params,
            work: Work::Mix(Arc::new(spec)),
            thermal: ThermalSpec::Off,
            fleet: None,
            faults: None,
            default_seed: 0xC0FFEE,
        }
    }

    pub fn with_default_seed(mut self, seed: u64) -> Scenario {
        self.default_seed = seed;
        self
    }

    /// Attach thermal coupling (e.g. `ThermalSpec::InLoop` for the
    /// closed-loop DTM presets).
    pub fn with_thermal(mut self, thermal: ThermalSpec) -> Scenario {
        self.thermal = thermal;
        self
    }

    pub fn thermal(&self) -> &ThermalSpec {
        &self.thermal
    }

    /// Whether this scenario runs closed-loop DTM.
    pub fn is_dtm(&self) -> bool {
        self.thermal.is_in_loop()
    }

    /// Attach a fleet overlay (traffic scenarios only): `chipsim fleet
    /// --scenario NAME` serves this scenario's arrival stream from
    /// `preset.replicas` replica boards instead of one.
    pub fn with_fleet(mut self, preset: FleetPreset) -> Scenario {
        self.fleet = Some(preset);
        self
    }

    pub fn fleet_preset(&self) -> Option<&FleetPreset> {
        self.fleet.as_ref()
    }

    /// Attach a fault-injection plan: the scenario's simulation arms it
    /// on build, and `chipsim fleet --scenario NAME` passes it to the
    /// dispatcher so `board:` events crash replicas.
    pub fn with_faults(mut self, plan: FaultPlan) -> Scenario {
        self.faults = Some(plan);
        self
    }

    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Whether this scenario carries a fleet overlay.
    pub fn is_fleet(&self) -> bool {
        self.fleet.is_some()
    }

    /// Instantiate the scenario's hardware configuration.
    pub fn hardware(&self) -> HardwareConfig {
        (self.hardware)()
    }

    pub fn params(&self) -> SimParams {
        self.params.clone()
    }

    pub fn is_traffic(&self) -> bool {
        matches!(self.work, Work::Traffic(_))
    }

    /// Whether this scenario is a multi-tenant co-execution mix.
    pub fn is_mix(&self) -> bool {
        matches!(self.work, Work::Mix(_))
    }

    /// Instantiate the scenario's batch workload for a seed (empty for
    /// traffic and mix scenarios — their requests come from arrival
    /// processes).
    pub fn workload(&self, seed: u64) -> WorkloadConfig {
        match &self.work {
            Work::Batch(f) => f(seed),
            Work::Traffic(_) | Work::Mix(_) => WorkloadConfig::from_kinds(&[]),
        }
    }

    /// Instantiate the traffic spec for a seed (`None` for batch and mix
    /// ones).
    pub fn traffic_spec(&self, seed: u64) -> Option<TrafficSpec> {
        match &self.work {
            Work::Batch(_) | Work::Mix(_) => None,
            Work::Traffic(f) => Some(f(seed)),
        }
    }

    /// Instantiate the workload mix for a seed (`None` for non-mix ones).
    pub fn mix_spec(&self, seed: u64) -> Option<WorkloadMix> {
        match &self.work {
            Work::Mix(f) => Some(f(seed)),
            _ => None,
        }
    }

    /// Assemble a runnable [`Simulation`] for this scenario.
    pub fn build(&self) -> anyhow::Result<Simulation> {
        Simulation::builder()
            .hardware(self.hardware())
            .params(self.params())
            .thermal(self.thermal.clone())
            .faults(self.faults.clone())
            .build()
    }

    /// Build and run to completion with the given workload seed.  Traffic
    /// and mix scenarios run the streaming engine and return its tail
    /// [`SimReport`] (span, power tail, energy); use
    /// [`run_traffic`](Self::run_traffic) / [`run_mix`](Self::run_mix)
    /// for the full serving stats.  Mix scenarios skip their solo
    /// interference baselines on this path (co-located pass only).
    pub fn run(&self, seed: u64) -> anyhow::Result<SimReport> {
        match &self.work {
            Work::Batch(f) => self.build()?.run(f(seed)),
            Work::Traffic(f) => Ok(self.build()?.run_traffic_with(&f(seed), seed)?.sim),
            Work::Mix(f) => {
                let mix = f(seed).interference(false);
                Ok(crate::serving::mix::run_mix(|| self.build(), &mix, seed)?.sim)
            }
        }
    }

    /// Build and run a traffic scenario, returning full serving stats.
    /// Errors for batch and mix scenarios.
    pub fn run_traffic(&self, seed: u64) -> anyhow::Result<TrafficReport> {
        match &self.work {
            Work::Batch(_) => anyhow::bail!(
                "scenario '{}' is a batch scenario; run it with Scenario::run",
                self.name
            ),
            Work::Mix(_) => anyhow::bail!(
                "scenario '{}' is a multi-tenant mix; run it with Scenario::run_mix \
                 (or `chipsim mix --scenario {}`)",
                self.name,
                self.name
            ),
            Work::Traffic(f) => self.build()?.run_traffic_with(&f(seed), seed),
        }
    }

    /// Build and run a mix scenario, returning per-tenant serving stats
    /// (and the interference matrix when the spec enables it).  Errors
    /// for batch and traffic scenarios.
    pub fn run_mix(&self, seed: u64) -> anyhow::Result<MixReport> {
        match &self.work {
            Work::Mix(f) => crate::serving::mix::run_mix(|| self.build(), &f(seed), seed),
            _ => anyhow::bail!(
                "scenario '{}' is not a multi-tenant mix; run it with Scenario::run \
                 or Scenario::run_traffic",
                self.name
            ),
        }
    }
}

/// Ordered, name-addressed collection of scenarios.
pub struct Registry {
    scenarios: Vec<Scenario>,
}

impl Registry {
    /// An empty registry (compose your own scenario set).
    pub fn new() -> Registry {
        Registry { scenarios: Vec::new() }
    }

    /// Every preset the repository ships, replacing the ad-hoc
    /// construction previously duplicated across `main.rs::build_hw`,
    /// `experiments/`, and the examples.
    pub fn builtin() -> Registry {
        let mut reg = Registry::new();
        let pipelined = |inf: u32| SimParams {
            pipelined: true,
            inferences_per_model: inf,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        reg.register(Scenario::new(
            "mesh-10x10-cnn",
            "paper §V-B primary system: 10x10 type-A mesh, pipelined CNN stream",
            || hardware_preset("mesh", 10, 10, 0, 0).expect("builtin preset"),
            pipelined(5),
            |seed| WorkloadConfig::cnn_stream(12, 5, seed),
        ));
        reg.register(Scenario::new(
            "mesh-6x6-quickstart",
            "small homogeneous mesh, 8-model CNN stream (the README quickstart)",
            || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
            pipelined(5),
            |seed| WorkloadConfig::cnn_stream(8, 5, seed),
        ));
        reg.register(Scenario::new(
            "hetero-mesh",
            "paper §V-C1: 8x8 checkerboard of type-A/type-B IMC chiplets",
            || hardware_preset("hetero", 8, 8, 0, 0).expect("builtin preset"),
            pipelined(5),
            |seed| WorkloadConfig::cnn_stream(12, 5, seed),
        ));
        reg.register(Scenario::new(
            "floret",
            "paper §V-C2: 8x8 chiplets on the Floret space-filling NoI",
            || hardware_preset("floret", 8, 8, 8, 0).expect("builtin preset"),
            pipelined(5),
            |seed| WorkloadConfig::cnn_stream(12, 5, seed),
        ));
        reg.register(Scenario::new(
            "vit-pipeline",
            "paper §V-E: ViT-B/16 weight-stationary, corner I/O dies, input pipelining",
            || hardware_preset("vit", 10, 10, 0, 0).expect("builtin preset"),
            pipelined(10),
            |_seed| WorkloadConfig::single(ModelKind::VitB16),
        ));
        reg.register(Scenario::new(
            "ccd-star",
            "paper §V-F: Threadripper-like 8-CCD star, CPU backend validation workload",
            || hardware_preset("ccd", 0, 0, 0, 8).expect("builtin preset"),
            SimParams {
                inferences_per_model: 2,
                warmup_ns: 0,
                cooldown_ns: 0,
                ..SimParams::default()
            },
            |_seed| {
                WorkloadConfig::from_kinds(&[
                    ModelKind::AlexNet,
                    ModelKind::ResNet18,
                    ModelKind::ResNet34,
                    ModelKind::ResNet50,
                ])
            },
        ));
        reg.register(Scenario::new(
            "flit-validation",
            "4x4 mesh at flit-level wormhole fidelity (packet-vs-flit cross-check)",
            || hardware_preset("mesh", 4, 4, 0, 0).expect("builtin preset"),
            SimParams {
                inferences_per_model: 2,
                warmup_ns: 0,
                cooldown_ns: 0,
                noc_fidelity: crate::config::NocFidelity::Flit,
                ..SimParams::default()
            },
            |_seed| WorkloadConfig::single(ModelKind::ResNet18),
        ));
        // ---- sustained-traffic scenarios (open-loop serving) ----
        let serving_params = || SimParams {
            pipelined: true,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        reg.register(Scenario::traffic(
            "traffic-poisson-mesh",
            "8x8 mesh serving a 2 krps Poisson CNN stream to steady state",
            || hardware_preset("mesh", 8, 8, 0, 0).expect("builtin preset"),
            serving_params(),
            |_seed| {
                TrafficSpec::poisson(2_000.0)
                    .horizon_ms(60.0)
                    .warmup_ms(10.0)
                    .window_ms(10.0)
                    .slo_ms(2.0)
                    .steady(Some(SteadyState { windows: 3, rel_tol: 0.15, min_per_window: 10 }))
            },
        ));
        reg.register(Scenario::traffic(
            "traffic-burst-mmpp",
            "8x8 hetero mesh under bursty on-off MMPP traffic (5 ms bursts)",
            || hardware_preset("hetero", 8, 8, 0, 0).expect("builtin preset"),
            serving_params(),
            |_seed| {
                TrafficSpec::new(ArrivalSpec::on_off(4_000.0, 250.0, 5e6, 5e6))
                    .horizon_ms(60.0)
                    .warmup_ms(10.0)
                    .window_ms(10.0)
                    .slo_ms(2.0)
                    .steady(None) // bursty p99 is not expected to converge
            },
        ));
        reg.register(Scenario::traffic(
            "traffic-diurnal",
            "10x10 mesh riding a sinusoidal day/night rate curve (40 ms period)",
            || hardware_preset("mesh", 10, 10, 0, 0).expect("builtin preset"),
            serving_params(),
            |_seed| {
                TrafficSpec::new(ArrivalSpec::diurnal(2_500.0, 0.6, 40_000_000))
                    .horizon_ms(80.0)
                    .warmup_ms(10.0)
                    .window_ms(10.0)
                    .slo_ms(2.0)
                    .steady(None)
            },
        ));
        reg.register(Scenario::traffic(
            "traffic-trace-replay",
            "6x6 mesh replaying a seeded synthetic burst trace (trace-replay path)",
            || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
            serving_params(),
            |seed| {
                // Deterministic synthetic trace: three bursts of uniform
                // CNN requests — exercises the replay path without a file.
                let mut rng = Rng::new(seed);
                let mut events = Vec::new();
                for burst in 0..3u64 {
                    let mut t = burst * 10_000_000 + rng.range_u64(0, 500_000);
                    for _ in 0..40 {
                        t += rng.range_u64(10_000, 150_000);
                        events.push(TraceEvent {
                            at_ns: t,
                            kind: *rng.choice(&ALL_CNNS),
                            inferences: 1,
                        });
                    }
                }
                TrafficSpec::new(ArrivalSpec::trace(events))
                    .horizon_ms(35.0)
                    .warmup_ms(2.0)
                    .window_ms(5.0)
                    .slo_ms(2.0)
                    .steady(None)
            },
        ));
        // ---- flit-fidelity serving (active-set wormhole engine) ----
        // The cycle-skipping flit engine makes per-flit arbitration
        // affordable at serving scale; these presets mirror the packet
        // ones at full wormhole fidelity.
        let flit_serving_params = || SimParams {
            pipelined: true,
            warmup_ns: 0,
            cooldown_ns: 0,
            noc_fidelity: crate::config::NocFidelity::Flit,
            ..SimParams::default()
        };
        reg.register(Scenario::traffic(
            "traffic-poisson-flit",
            "6x6 mesh serving a 1.5 krps Poisson CNN stream at flit-level wormhole fidelity",
            || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
            flit_serving_params(),
            |_seed| {
                TrafficSpec::poisson(1_500.0)
                    .horizon_ms(20.0)
                    .warmup_ms(2.0)
                    .window_ms(5.0)
                    .slo_ms(2.0)
                    .steady(None)
            },
        ));
        reg.register(
            Scenario::traffic(
                "dtm-ceiling-flit",
                "6x6 mesh with threshold DVFS at a 48 °C ceiling, flit-level NoI contention",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                flit_serving_params(),
                |_seed| {
                    TrafficSpec::poisson(2_000.0)
                        .horizon_ms(15.0)
                        .warmup_ms(2.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::threshold_band(47.0, 46.2, 48.0),
            }),
        );
        // ---- closed-loop DTM scenarios (see crate::dtm) ----
        // Control period 100 µs; one implicit-Euler step per window
        // (stride 0).  Temperatures over ms-scale horizons sit a few
        // kelvin over the 45 °C ambient, so the setpoints live there.
        reg.register(
            Scenario::traffic(
                "dtm-thermal-ceiling",
                "6x6 mesh near saturation with threshold-throttle DVFS at a 48 °C ceiling",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::poisson(3_000.0)
                        .horizon_ms(30.0)
                        .warmup_ms(5.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::threshold_band(47.0, 46.2, 48.0),
            }),
        );
        reg.register(
            Scenario::traffic(
                "dtm-throttle-slo",
                "6x6 mesh with PID DVFS toward 46.5 °C — the throttle-vs-SLO tradeoff probe",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::poisson(3_000.0)
                        .horizon_ms(30.0)
                        .warmup_ms(5.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::pid(46.5),
            }),
        );
        // ---- multi-tenant co-execution mixes (see crate::serving::mix) ----
        // Concurrent DNN tenants on one system: contention for the shared
        // NoI, chiplet queues, and weight memory is cross-tenant by
        // construction.  `chipsim mix --scenario NAME [--sweep interference]`.
        reg.register(Scenario::mix(
            "mix-vit-resnet-partitioned",
            "12x12 mesh: ViT-B/16 tenant + ResNet18 tenant on disjoint spatial partitions",
            || hardware_preset("mesh", 12, 12, 0, 0).expect("builtin preset"),
            serving_params(),
            |_seed| {
                WorkloadMix::new(vec![
                    TenantSpec::poisson("vit", ModelKind::VitB16, 80.0).slo_ms(10.0),
                    TenantSpec::poisson("resnet", ModelKind::ResNet18, 1_200.0).slo_ms(2.0),
                ])
                .placement(PlacementPolicy::DisjointPartition)
                .horizon_ms(20.0)
                .warmup_ms(2.0)
                .window_ms(5.0)
            },
        ));
        reg.register(Scenario::mix(
            "mix-contended-interleaved",
            "6x6 mesh with narrow 8 B links: two CNN tenants fully interleaved — the \
             constrained-bandwidth interference probe",
            || {
                let mut hw = hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset");
                // Quarter-width links starve the shared NoI so co-location
                // visibly inflates tails over the solo baselines.
                hw.link.width_bytes = 8;
                hw
            },
            serving_params(),
            |_seed| {
                WorkloadMix::new(vec![
                    TenantSpec::poisson("latency", ModelKind::ResNet18, 1_500.0).slo_ms(2.0),
                    TenantSpec::poisson("batch", ModelKind::ResNet34, 700.0).slo_ms(8.0),
                ])
                .placement(PlacementPolicy::Interleaved)
                .horizon_ms(30.0)
                .warmup_ms(2.0)
                .window_ms(5.0)
                .interference(true)
            },
        ));
        reg.register(Scenario::mix(
            "mix-background-noise-greedy",
            "8x8 mesh: a latency-sensitive ResNet34 tenant vs bursty AlexNet background \
             noise, greedy best-fit placement",
            || hardware_preset("mesh", 8, 8, 0, 0).expect("builtin preset"),
            serving_params(),
            |_seed| {
                WorkloadMix::new(vec![
                    TenantSpec::poisson("serve", ModelKind::ResNet34, 800.0).slo_ms(2.0),
                    TenantSpec::new(
                        "noise",
                        ArrivalSpec::on_off(2_000.0, 0.0, 2e6, 2e6)
                            .kinds(&[ModelKind::AlexNet]),
                    )
                    .slo_ms(8.0),
                ])
                .placement(PlacementPolicy::GreedyBestFit)
                .horizon_ms(20.0)
                .warmup_ms(2.0)
                .window_ms(5.0)
            },
        ));
        reg.register(Scenario::mix(
            "mix-duo-partitioned-flit",
            "6x6 mesh at flit-level wormhole fidelity: ResNet50 + ResNet18 tenants on \
             disjoint partitions",
            || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
            flit_serving_params(),
            |_seed| {
                WorkloadMix::new(vec![
                    TenantSpec::poisson("fifty", ModelKind::ResNet50, 500.0).slo_ms(4.0),
                    TenantSpec::poisson("eighteen", ModelKind::ResNet18, 1_000.0).slo_ms(2.0),
                ])
                .placement(PlacementPolicy::DisjointPartition)
                .horizon_ms(10.0)
                .warmup_ms(1.0)
                .window_ms(2.5)
            },
        ));
        // ---- fleet-scale serving (see crate::fleet) ----
        // N replica boards behind one dispatcher; `chipsim fleet
        // --scenario NAME` applies the overlay (all knobs overridable).
        // Board = 6x6 mesh; one board saturates around 3 krps (the
        // dtm-thermal-ceiling operating point), so the 4-board fleets
        // serve ~3x that comfortably and expose routing differences.
        let fleet_traffic = |rate: f64| {
            move |_seed: u64| {
                TrafficSpec::poisson(rate)
                    .horizon_ms(30.0)
                    .warmup_ms(5.0)
                    .window_ms(5.0)
                    .slo_ms(2.0)
                    .steady(None) // fleets always run the full horizon
            }
        };
        reg.register(
            Scenario::traffic(
                "fleet-round-robin",
                "4x 6x6-mesh boards, round-robin dispatch of a 9 krps Poisson stream",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                fleet_traffic(9_000.0),
            )
            .with_fleet(FleetPreset {
                replicas: 4,
                max_replicas: 4,
                routing: "round-robin",
                autoscale: "none",
                epoch_ns: 200_000,
                cold_start_ns: 5_000_000,
                emergency_c: None,
            }),
        );
        reg.register(
            Scenario::traffic(
                "fleet-least-outstanding",
                "4x 6x6-mesh boards, least-outstanding dispatch — the routing-compare twin",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                fleet_traffic(9_000.0),
            )
            .with_fleet(FleetPreset {
                replicas: 4,
                max_replicas: 4,
                routing: "least-outstanding",
                autoscale: "none",
                epoch_ns: 200_000,
                cold_start_ns: 5_000_000,
                emergency_c: None,
            }),
        );
        reg.register(
            Scenario::traffic(
                "fleet-autoscale-diurnal",
                "2..6 boards riding a day/night rate curve, queue-depth autoscaler with \
                 5 ms cold starts",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::new(ArrivalSpec::diurnal(7_000.0, 0.7, 30_000_000))
                        .horizon_ms(60.0)
                        .warmup_ms(5.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_fleet(FleetPreset {
                replicas: 2,
                max_replicas: 6,
                routing: "least-outstanding",
                autoscale: "queue:24",
                epoch_ns: 200_000,
                cold_start_ns: 5_000_000,
                emergency_c: None,
            }),
        );
        reg.register(
            Scenario::traffic(
                "fleet-thermal-migrate",
                "3 DTM boards under bursty load: thermal-aware routing, queued work \
                 migrates off boards above 47.5 degC",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::new(ArrivalSpec::on_off(9_000.0, 600.0, 5e6, 5e6))
                        .horizon_ms(30.0)
                        .warmup_ms(5.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_thermal(ThermalSpec::InLoop {
                window_ns: 100_000,
                governor: GovernorSpec::threshold_band(47.0, 46.2, 48.0),
            })
            .with_fleet(FleetPreset {
                replicas: 3,
                max_replicas: 3,
                routing: "thermal",
                autoscale: "none",
                epoch_ns: 200_000,
                cold_start_ns: 5_000_000,
                emergency_c: Some(47.5),
            }),
        );
        // ---- fault-injection / graceful-degradation presets ----
        // Deterministic fault schedules over the serving presets above:
        // same seed + same plan => byte-identical FaultReport.
        reg.register(
            Scenario::traffic(
                "fault-link-flap",
                "6x6 mesh under 2 krps Poisson with an intermittent NoI link (down 1 ms \
                 every 4 ms): reroute-vs-fail under repair cycles",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::poisson(2_000.0)
                        .horizon_ms(20.0)
                        .warmup_ms(2.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_faults(
                FaultPlan::parse("link:14-15@4ms+1ms%4ms*3").expect("builtin fault plan"),
            ),
        );
        reg.register(
            Scenario::traffic(
                "fault-chiplet-kill",
                "6x6 mesh under 1.5 krps Poisson; chiplet 7 dies at 3 ms for 6 ms and a \
                 sensor sticks at 95 degC: mapper exclusion + lying-governor probe",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                |_seed| {
                    TrafficSpec::poisson(1_500.0)
                        .horizon_ms(20.0)
                        .warmup_ms(2.0)
                        .window_ms(5.0)
                        .slo_ms(2.0)
                        .steady(None)
                },
            )
            .with_faults(
                FaultPlan::parse("chiplet:7@3ms+6ms, sensor:3:stuck=95@2ms")
                    .expect("builtin fault plan"),
            ),
        );
        reg.register(
            Scenario::traffic(
                "fault-fleet-board-crash",
                "4x 6x6-mesh boards at 8 krps; board 1 crashes at 8 ms — queued work \
                 migrates, in-flight requests retry with capped backoff",
                || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
                serving_params(),
                fleet_traffic(8_000.0),
            )
            .with_fleet(FleetPreset {
                replicas: 4,
                max_replicas: 4,
                routing: "least-outstanding",
                autoscale: "none",
                epoch_ns: 200_000,
                cold_start_ns: 5_000_000,
                emergency_c: None,
            })
            .with_faults(
                FaultPlan::parse("board:1@8ms, retry=3:200us:2ms:20ms")
                    .expect("builtin fault plan"),
            ),
        );
        reg.register(Scenario::new(
            "thermal-hotspot",
            "6x6 mesh with THERMOS-style thermal-aware mapping enabled",
            || hardware_preset("mesh", 6, 6, 0, 0).expect("builtin preset"),
            SimParams {
                pipelined: true,
                inferences_per_model: 3,
                warmup_ns: 0,
                cooldown_ns: 0,
                thermal_aware_hops: 2.0,
                ..SimParams::default()
            },
            |seed| WorkloadConfig::cnn_stream(8, 3, seed),
        ));
        reg
    }

    /// Add (or replace, by name) a scenario.
    pub fn register(&mut self, scenario: Scenario) {
        match self.scenarios.iter_mut().find(|s| s.name == scenario.name) {
            Some(slot) => *slot = scenario,
            None => self.scenarios.push(scenario),
        }
    }

    pub fn get(&self, name: &str) -> Option<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.scenarios.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Scenario> {
        self.scenarios.iter()
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// Result of one scenario inside a sweep.
pub struct SweepOutcome {
    pub scenario: String,
    /// The derived per-scenario workload seed actually used.
    pub seed: u64,
    pub result: anyhow::Result<SimReport>,
}

/// Executes a batch of registry scenarios, optionally across threads.
///
/// Per-scenario seeds derive deterministically from `(base_seed, name)`,
/// and every scenario run owns its whole simulation state, so thread
/// scheduling cannot perturb results: `run` and `run_sequential` return
/// byte-identical reports in the same input order.
///
/// A scenario that *panics* is caught and surfaced as that scenario's
/// `Err` outcome instead of unwinding through the worker thread — one
/// broken preset can no longer poison a whole threaded sweep.
pub struct SweepRunner {
    threads: usize,
    base_seed: u64,
}

impl SweepRunner {
    pub fn new() -> SweepRunner {
        SweepRunner { threads: 0, base_seed: 0xC0FFEE }
    }

    /// Worker thread count; 0 (default) uses the available parallelism.
    pub fn threads(mut self, n: usize) -> SweepRunner {
        self.threads = n;
        self
    }

    pub fn base_seed(mut self, seed: u64) -> SweepRunner {
        self.base_seed = seed;
        self
    }

    /// Deterministic per-scenario seed: FNV-1a of the name mixed into the
    /// base seed through one PRNG round (avalanches nearby names apart).
    pub fn seed_for(&self, name: &str) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        Rng::new(self.base_seed ^ h).next_u64()
    }

    /// Run one scenario with panics converted into `Err` results (the
    /// registry accepts user-registered scenarios whose closures may
    /// panic; a sweep must report that, not die).
    fn run_caught(sc: &Scenario, seed: u64) -> anyhow::Result<SimReport> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sc.run(seed))) {
            Ok(result) => result,
            Err(payload) => Err(anyhow::anyhow!(
                "scenario '{}' panicked: {}",
                sc.name,
                crate::util::pool::panic_message(payload)
            )),
        }
    }

    fn resolve<'a>(
        &self,
        registry: &'a Registry,
        names: &[&str],
    ) -> anyhow::Result<Vec<&'a Scenario>> {
        names
            .iter()
            .map(|&n| {
                registry.get(n).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scenario '{n}' (registered: {})",
                        registry.names().join(", ")
                    )
                })
            })
            .collect()
    }

    /// Run the named scenarios across worker threads (the shared
    /// [`crate::util::pool`] implementation).  Outcomes are returned in
    /// input order regardless of completion order.
    pub fn run(&self, registry: &Registry, names: &[&str]) -> anyhow::Result<Vec<SweepOutcome>> {
        let scenarios = self.resolve(registry, names)?;
        let jobs: Vec<(&Scenario, u64)> =
            scenarios.iter().map(|s| (*s, self.seed_for(&s.name))).collect();
        let pool = crate::util::pool::WorkerPool::new(self.threads);
        let results = pool.map_catching(jobs.len(), |i| {
            let (sc, seed) = jobs[i];
            SweepOutcome {
                scenario: sc.name.clone(),
                seed,
                result: SweepRunner::run_caught(sc, seed),
            }
        });
        // run_caught already converts scenario panics; the pool-level
        // catch only fires if outcome assembly itself panicked.
        Ok(results
            .into_iter()
            .zip(jobs)
            .map(|(out, (sc, seed))| {
                out.unwrap_or_else(|msg| SweepOutcome {
                    scenario: sc.name.clone(),
                    seed,
                    result: Err(anyhow::anyhow!("scenario '{}' panicked: {msg}", sc.name)),
                })
            })
            .collect())
    }

    /// Same batch on the calling thread (reference for determinism tests).
    pub fn run_sequential(
        &self,
        registry: &Registry,
        names: &[&str],
    ) -> anyhow::Result<Vec<SweepOutcome>> {
        let scenarios = self.resolve(registry, names)?;
        Ok(scenarios
            .into_iter()
            .map(|sc| {
                let seed = self.seed_for(&sc.name);
                SweepOutcome {
                    scenario: sc.name.clone(),
                    seed,
                    result: SweepRunner::run_caught(sc, seed),
                }
            })
            .collect())
    }

    /// Run every scenario registered in `registry`.
    pub fn run_all(&self, registry: &Registry) -> anyhow::Result<Vec<SweepOutcome>> {
        let names = registry.names();
        self.run(registry, &names)
    }
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_names_the_paper_presets() {
        let reg = Registry::builtin();
        for name in ["mesh-10x10-cnn", "hetero-mesh", "floret", "vit-pipeline", "ccd-star"] {
            assert!(reg.get(name).is_some(), "missing builtin scenario '{name}'");
        }
        assert!(reg.len() >= 6);
    }

    #[test]
    fn traffic_scenarios_are_registered_and_typed() {
        let reg = Registry::builtin();
        for name in [
            "traffic-poisson-mesh",
            "traffic-burst-mmpp",
            "traffic-diurnal",
            "traffic-trace-replay",
        ] {
            let sc = reg.get(name).unwrap_or_else(|| panic!("missing builtin '{name}'"));
            assert!(sc.is_traffic(), "'{name}' should be a traffic scenario");
            assert!(sc.traffic_spec(1).is_some());
            assert!(sc.workload(1).kinds.is_empty());
        }
        let batch = reg.get("mesh-10x10-cnn").unwrap();
        assert!(!batch.is_traffic());
        assert!(batch.traffic_spec(1).is_none());
        assert!(batch.run_traffic(1).is_err());
    }

    #[test]
    fn flit_fidelity_presets_are_registered() {
        use crate::config::NocFidelity;
        let reg = Registry::builtin();
        let poisson = reg.get("traffic-poisson-flit").expect("flit traffic preset");
        assert!(poisson.is_traffic());
        assert_eq!(poisson.params().noc_fidelity, NocFidelity::Flit);
        let dtm = reg.get("dtm-ceiling-flit").expect("flit dtm preset");
        assert!(dtm.is_dtm());
        assert_eq!(dtm.params().noc_fidelity, NocFidelity::Flit);
    }

    #[test]
    fn dtm_scenarios_are_registered_with_in_loop_thermal() {
        let reg = Registry::builtin();
        for name in ["dtm-thermal-ceiling", "dtm-throttle-slo"] {
            let sc = reg.get(name).unwrap_or_else(|| panic!("missing builtin '{name}'"));
            assert!(sc.is_traffic(), "'{name}' should be a traffic scenario");
            assert!(sc.is_dtm(), "'{name}' should run closed-loop DTM");
            assert!(sc.thermal().is_in_loop());
        }
        assert!(!reg.get("mesh-10x10-cnn").unwrap().is_dtm());
    }

    #[test]
    fn mix_scenarios_are_registered_and_typed() {
        let reg = Registry::builtin();
        for name in [
            "mix-vit-resnet-partitioned",
            "mix-contended-interleaved",
            "mix-background-noise-greedy",
            "mix-duo-partitioned-flit",
        ] {
            let sc = reg.get(name).unwrap_or_else(|| panic!("missing builtin '{name}'"));
            assert!(sc.is_mix(), "'{name}' should be a mix scenario");
            assert!(!sc.is_traffic());
            let mix = sc.mix_spec(1).expect("mix spec");
            assert!(mix.tenants.len() >= 2, "'{name}' should co-run >= 2 tenants");
            assert!(mix.validate().is_ok(), "'{name}' spec must validate");
            assert!(sc.workload(1).kinds.is_empty());
            assert!(sc.traffic_spec(1).is_none());
            assert!(sc.run_traffic(1).is_err());
        }
        let flit = reg.get("mix-duo-partitioned-flit").unwrap();
        assert_eq!(flit.params().noc_fidelity, crate::config::NocFidelity::Flit);
        assert!(reg.get("mesh-10x10-cnn").unwrap().mix_spec(1).is_none());
        assert!(reg.get("mesh-10x10-cnn").unwrap().run_mix(1).is_err());
    }

    #[test]
    fn sweep_surfaces_a_panicking_scenario_as_its_own_failure() {
        let mut reg = Registry::builtin();
        reg.register(Scenario::new(
            "boom",
            "hardware closure panics (sweep must survive)",
            || panic!("intentional test panic"),
            SimParams {
                inferences_per_model: 1,
                warmup_ns: 0,
                cooldown_ns: 0,
                ..SimParams::default()
            },
            |_| WorkloadConfig::single(ModelKind::ResNet18),
        ));
        let outcomes = SweepRunner::new()
            .threads(2)
            .run(&reg, &["boom", "mesh-6x6-quickstart"])
            .expect("the sweep itself must not die");
        assert_eq!(outcomes.len(), 2);
        let boom = &outcomes[0];
        let err = boom.result.as_ref().err().expect("panicking scenario reports Err");
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("intentional test panic"), "{err}");
        assert!(
            outcomes[1].result.is_ok(),
            "healthy scenario must complete despite the neighbour's panic"
        );
        // Sequential path surfaces the same failure.
        let seq = SweepRunner::new().run_sequential(&reg, &["boom"]).unwrap();
        assert!(seq[0].result.is_err());
    }

    #[test]
    fn fault_presets_are_registered_with_plans() {
        let reg = Registry::builtin();
        for name in ["fault-link-flap", "fault-chiplet-kill", "fault-fleet-board-crash"] {
            let sc = reg.get(name).unwrap_or_else(|| panic!("missing builtin '{name}'"));
            assert!(sc.is_traffic(), "'{name}' should be a traffic scenario");
            let plan = sc.fault_plan().expect("fault preset carries a plan");
            assert!(!plan.is_empty());
        }
        let fleet = reg.get("fault-fleet-board-crash").unwrap();
        assert!(fleet.is_fleet(), "board-crash preset is a fleet scenario");
        assert_eq!(
            fleet.fault_plan().unwrap().arm_boards(4).unwrap(),
            vec![(8_000_000, 1)]
        );
        assert!(reg.get("mesh-10x10-cnn").unwrap().fault_plan().is_none());
    }

    #[test]
    fn register_replaces_by_name() {
        let mut reg = Registry::builtin();
        let n = reg.len();
        reg.register(Scenario::new(
            "floret",
            "replacement",
            || HardwareConfig::homogeneous_mesh(2, 2),
            SimParams::default(),
            |_| WorkloadConfig::from_kinds(&[]),
        ));
        assert_eq!(reg.len(), n);
        assert_eq!(reg.get("floret").unwrap().about, "replacement");
    }

    #[test]
    fn seed_derivation_is_stable_and_name_sensitive() {
        let r = SweepRunner::new().base_seed(42);
        assert_eq!(r.seed_for("floret"), r.seed_for("floret"));
        assert_ne!(r.seed_for("floret"), r.seed_for("floret2"));
        let r2 = SweepRunner::new().base_seed(43);
        assert_ne!(r.seed_for("floret"), r2.seed_for("floret"));
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let reg = Registry::builtin();
        let err = SweepRunner::new().run(&reg, &["no-such-scenario"]).err();
        assert!(err.is_some());
        assert!(err.unwrap().to_string().contains("no-such-scenario"));
    }

    #[test]
    fn hardware_preset_rejects_unknown_names() {
        assert!(hardware_preset("torus", 4, 4, 0, 0).is_err());
        assert!(hardware_preset("mesh", 4, 4, 0, 0).is_ok());
    }
}
