//! Multi-fidelity thermal model (our MFIT [49] analog, paper §IV-C).
//!
//! A 2.5D stack is discretized into an RC network with variable spatial
//! granularity: **2×2 nodes per chiplet** in the active layer (to capture
//! intra-chiplet gradients) and coarser uniform grids in the passive
//! layers (interposer, heat spreader).  The spreader couples to ambient
//! through an effective heat-sink convection conductance.
//!
//! Temperatures are solved as ΔT above ambient:
//!
//!   C dT/dt = -G T + P         (transient)
//!           0 = -G T + P       (steady state)
//!
//! The implicit-Euler step matrices A = (I + dt·C⁻¹G)⁻¹ and
//! Bm = A·dt·C⁻¹ are precomputed once per physical configuration (dense
//! LU from `util::linalg`), then the timeline is integrated either by the
//! in-process [`native::NativeSolver`] (oracle) or by the AOT JAX/Pallas
//! artifact through [`pjrt::PjrtThermalSolver`] (hot path).

pub mod native;
pub mod pjrt;
pub mod stepper;

use crate::config::HardwareConfig;
use crate::util::linalg::{Lu, Mat};

/// Material / package constants (SI).
pub mod consts {
    /// Silicon thermal conductivity, W/(m·K).
    pub const K_SI: f64 = 120.0;
    /// Interposer (Si + wiring) effective conductivity, W/(m·K).
    pub const K_INTERPOSER: f64 = 80.0;
    /// Copper heat-spreader conductivity, W/(m·K).
    pub const K_SPREADER: f64 = 390.0;
    /// Volumetric heat capacity of silicon, J/(m³·K).
    pub const CV_SI: f64 = 1.66e6;
    /// Volumetric heat capacity of copper, J/(m³·K).
    pub const CV_CU: f64 = 3.45e6;
    /// Die thickness, m.
    pub const T_DIE: f64 = 0.3e-3;
    /// Interposer thickness, m.
    pub const T_INTERPOSER: f64 = 0.1e-3;
    /// Spreader thickness, m.
    pub const T_SPREADER: f64 = 1.0e-3;
    /// TIM conductance per area between die and spreader, W/(m²·K).
    pub const H_TIM: f64 = 5.0e4;
    /// Heat-sink convection coefficient, W/(m²·K).
    pub const H_SINK: f64 = 2.0e3;
    /// Ambient temperature, °C (paper's setups run warm).
    pub const T_AMBIENT: f64 = 45.0;
}

/// Node indices of one layer of the RC network.
#[derive(Debug, Clone)]
pub struct ThermalLayerIdx {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub first: usize,
}

impl ThermalLayerIdx {
    pub fn node(&self, r: usize, c: usize) -> usize {
        self.first + r * self.cols + c
    }
}

/// The assembled RC network for a hardware configuration.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Total node count.
    pub n: usize,
    /// Conductance matrix (SPD; diagonal includes ambient ties), W/K.
    pub g: Mat,
    /// Heat capacitance per node, J/K.
    pub c: Vec<f64>,
    /// Active-layer node ids per chiplet (2×2 each).
    pub chiplet_nodes: Vec<Vec<usize>>,
    pub layers: Vec<ThermalLayerIdx>,
    pub ambient_c: f64,
}

/// Grid resolution of the passive layers (interposer / spreader).
pub const PASSIVE_GRID: usize = 10;
/// Active-layer sub-grid per chiplet.
pub const CHIPLET_SUBGRID: usize = 2;

impl ThermalModel {
    /// Build the RC network for a chiplet grid.  Chiplets sit on a
    /// rows×cols floorplan; the interposer and spreader span the package.
    pub fn build(hw: &HardwareConfig) -> ThermalModel {
        use consts::*;
        let nch = hw.num_chiplets();
        let sub = CHIPLET_SUBGRID;
        let active_nodes = nch * sub * sub;
        let passive = PASSIVE_GRID * PASSIVE_GRID;
        let n = active_nodes + 2 * passive;
        let mut g = Mat::zeros(n, n);
        let mut c = vec![0.0; n];

        // Package footprint: chiplet pitch grid with 1 mm spacing margin.
        let pitch_x: f64 = hw
            .chiplet_types
            .iter()
            .map(|t| t.width_mm)
            .fold(0.0, f64::max)
            + 1.0;
        let pitch_y: f64 = hw
            .chiplet_types
            .iter()
            .map(|t| t.height_mm)
            .fold(0.0, f64::max)
            + 1.0;
        let pkg_w = pitch_x * hw.cols as f64 * 1e-3;
        let pkg_h = pitch_y * hw.rows as f64 * 1e-3;

        let add = |g: &mut Mat, a: usize, b: usize, cond: f64| {
            g[(a, a)] += cond;
            g[(b, b)] += cond;
            g[(a, b)] -= cond;
            g[(b, a)] -= cond;
        };
        let tie = |g: &mut Mat, a: usize, cond: f64| {
            g[(a, a)] += cond;
        };

        // ----- active layer: 2×2 nodes per chiplet --------------------
        let mut chiplet_nodes = Vec::with_capacity(nch);
        for ch in 0..nch {
            let t = hw.chiplet_type(ch);
            let w = t.width_mm * 1e-3;
            let h = t.height_mm * 1e-3;
            let cell_w = w / sub as f64;
            let cell_h = h / sub as f64;
            let vol = cell_w * cell_h * T_DIE;
            let base = ch * sub * sub;
            let mut nodes = Vec::with_capacity(sub * sub);
            for r in 0..sub {
                for cc in 0..sub {
                    let idx = base + r * sub + cc;
                    c[idx] = CV_SI * vol;
                    nodes.push(idx);
                    // Lateral conduction inside the die.
                    if cc + 1 < sub {
                        let cond = K_SI * (cell_h * T_DIE) / cell_w;
                        add(&mut g, idx, idx + 1, cond);
                    }
                    if r + 1 < sub {
                        let cond = K_SI * (cell_w * T_DIE) / cell_h;
                        add(&mut g, idx, idx + sub, cond);
                    }
                }
            }
            chiplet_nodes.push(nodes);
        }

        // ----- passive layers ----------------------------------------
        let pg = PASSIVE_GRID;
        let interposer = ThermalLayerIdx { name: "interposer", rows: pg, cols: pg, first: active_nodes };
        let spreader =
            ThermalLayerIdx { name: "spreader", rows: pg, cols: pg, first: active_nodes + passive };
        let cell_w = pkg_w / pg as f64;
        let cell_h = pkg_h / pg as f64;
        for (layer, k, thick, cv) in [
            (&interposer, K_INTERPOSER, T_INTERPOSER, CV_SI),
            (&spreader, K_SPREADER, T_SPREADER, CV_CU),
        ] {
            let vol = cell_w * cell_h * thick;
            for r in 0..pg {
                for cc in 0..pg {
                    let idx = layer.node(r, cc);
                    c[idx] = cv * vol;
                    if cc + 1 < pg {
                        add(&mut g, idx, layer.node(r, cc + 1), k * (cell_h * thick) / cell_w);
                    }
                    if r + 1 < pg {
                        add(&mut g, idx, layer.node(r + 1, cc), k * (cell_w * thick) / cell_h);
                    }
                }
            }
        }

        // ----- vertical coupling --------------------------------------
        // Chiplet cell -> nearest interposer cell (below) and spreader
        // cell (above, through TIM).
        let cell_of = |x: f64, y: f64, layer: &ThermalLayerIdx| {
            let cc = ((x / pkg_w) * layer.cols as f64).min(layer.cols as f64 - 1.0) as usize;
            let rr = ((y / pkg_h) * layer.rows as f64).min(layer.rows as f64 - 1.0) as usize;
            layer.node(rr, cc)
        };
        for ch in 0..nch {
            let t = hw.chiplet_type(ch);
            let (crow, ccol) = (ch / hw.cols, ch % hw.cols);
            // Center each die inside its pitch cell so the floorplan is
            // symmetric in the package (corner dies then cool equally).
            let cx0 = (ccol as f64 * pitch_x + (pitch_x - t.width_mm) / 2.0) * 1e-3;
            let cy0 = (crow as f64 * pitch_y + (pitch_y - t.height_mm) / 2.0) * 1e-3;
            let w = t.width_mm * 1e-3;
            let h = t.height_mm * 1e-3;
            let cell_area = (w / sub as f64) * (h / sub as f64);
            for r in 0..sub {
                for cc2 in 0..sub {
                    let idx = chiplet_nodes[ch][r * sub + cc2];
                    let x = cx0 + (cc2 as f64 + 0.5) * w / sub as f64;
                    let y = cy0 + (r as f64 + 0.5) * h / sub as f64;
                    // Die -> interposer (microbumps + underfill ≈ die k).
                    let gi = K_SI * cell_area / (T_DIE / 2.0 + T_INTERPOSER / 2.0);
                    add(&mut g, idx, cell_of(x, y, &interposer), gi);
                    // Die -> spreader through TIM.
                    let gs = H_TIM * cell_area;
                    add(&mut g, idx, cell_of(x, y, &spreader), gs);
                }
            }
        }
        // Interposer <-> spreader around the dies (edge path, weak).
        for r in 0..pg {
            for cc in 0..pg {
                let gi = 0.1 * K_INTERPOSER * (cell_w * cell_h) / (T_INTERPOSER + T_SPREADER);
                add(&mut g, interposer.node(r, cc), spreader.node(r, cc), gi);
            }
        }
        // Spreader -> ambient (heat sink).
        for r in 0..pg {
            for cc in 0..pg {
                tie(&mut g, spreader.node(r, cc), H_SINK * cell_w * cell_h);
            }
        }
        // Interposer underside -> board (weak).
        for r in 0..pg {
            for cc in 0..pg {
                tie(&mut g, interposer.node(r, cc), 0.05 * H_SINK * cell_w * cell_h);
            }
        }

        ThermalModel {
            n,
            g,
            c,
            chiplet_nodes,
            layers: vec![interposer, spreader],
            ambient_c: T_AMBIENT,
        }
    }

    /// Implicit-Euler step matrices for timestep `dt_s` (seconds):
    /// A = (I + dt C⁻¹ G)⁻¹,  Bm = A · diag(dt / C).
    pub fn step_matrices(&self, dt_s: f64) -> anyhow::Result<(Mat, Mat)> {
        let n = self.n;
        let mut m = Mat::identity(n);
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] += dt_s * self.g[(i, j)] / self.c[i];
            }
        }
        let a = Lu::factor(&m)?.inverse();
        let mut bm = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                bm[(i, j)] = a[(i, j)] * dt_s / self.c[j];
            }
        }
        Ok((a, bm))
    }

    /// Expand per-chiplet power (W) to per-node power (W): each chiplet's
    /// power splits equally over its 2×2 active nodes.
    pub fn node_power(&self, chiplet_w: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; self.n];
        for (ch, nodes) in self.chiplet_nodes.iter().enumerate() {
            let share = chiplet_w.get(ch).copied().unwrap_or(0.0) / nodes.len() as f64;
            for &nd in nodes {
                p[nd] = share;
            }
        }
        p
    }

    /// Mean ΔT of a chiplet given a node-temperature vector.
    pub fn chiplet_temp(&self, temps: &[f64], chiplet: usize) -> f64 {
        let nodes = &self.chiplet_nodes[chiplet];
        nodes.iter().map(|&i| temps[i]).sum::<f64>() / nodes.len() as f64
    }

    /// Render an ASCII/art heatmap of chiplet temperatures (°C absolute).
    pub fn heatmap(&self, temps: &[f64], rows: usize, cols: usize) -> String {
        let vals: Vec<f64> =
            (0..rows * cols).map(|ch| self.chiplet_temp(temps, ch) + self.ambient_c).collect();
        let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
        let mut s = format!("thermal heatmap: {lo:.1}°C (' ') .. {hi:.1}°C ('@')\n");
        for r in 0..rows {
            for c in 0..cols {
                let v = vals[r * cols + c];
                let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
                let idx = ((t * (shades.len() - 1) as f64).round()) as usize;
                s.push(shades[idx.min(shades.len() - 1)]);
                s.push(shades[idx.min(shades.len() - 1)]);
            }
            s.push('\n');
        }
        s
    }

    /// CSV of per-chiplet temperatures.
    pub fn temps_csv(&self, temps: &[f64], num_chiplets: usize) -> String {
        let mut s = String::from("chiplet,temp_c\n");
        for ch in 0..num_chiplets {
            s.push_str(&format!("{ch},{:.3}\n", self.chiplet_temp(temps, ch) + self.ambient_c));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_4x4() -> (HardwareConfig, ThermalModel) {
        let hw = HardwareConfig::homogeneous_mesh(4, 4);
        let tm = ThermalModel::build(&hw);
        (hw, tm)
    }

    #[test]
    fn network_dimensions() {
        let (hw, tm) = model_4x4();
        assert_eq!(tm.chiplet_nodes.len(), hw.num_chiplets());
        assert_eq!(tm.n, 16 * 4 + 2 * PASSIVE_GRID * PASSIVE_GRID);
        assert!(tm.c.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn conductance_matrix_is_symmetric_spd_ish() {
        let (_, tm) = model_4x4();
        for i in 0..tm.n {
            for j in (i + 1)..tm.n {
                assert!((tm.g[(i, j)] - tm.g[(j, i)]).abs() < 1e-12);
            }
            // Ambient ties make row sums positive on tied rows, zero or
            // positive elsewhere => weakly diagonally dominant.
            let off: f64 = (0..tm.n).filter(|&j| j != i).map(|j| tm.g[(i, j)].abs()).sum();
            assert!(tm.g[(i, i)] >= off - 1e-9, "row {i}");
        }
    }

    #[test]
    fn steady_state_uniform_power_is_warmer_in_center() {
        let (hw, tm) = model_4x4();
        let p = tm.node_power(&vec![1.0; hw.num_chiplets()]); // 1 W each
        let t = crate::util::linalg::Lu::factor(&tm.g).unwrap().solve(&p);
        // Center chiplets (1,1),(1,2),(2,1),(2,2) warmer than corner 0.
        let corner = tm.chiplet_temp(&t, 0);
        let center = tm.chiplet_temp(&t, 5);
        assert!(center > corner, "center {center} !> corner {corner}");
        assert!(t.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn node_power_conserves_total() {
        let (hw, tm) = model_4x4();
        let chips: Vec<f64> = (0..hw.num_chiplets()).map(|i| i as f64 * 0.1).collect();
        let p = tm.node_power(&chips);
        let total_in: f64 = chips.iter().sum();
        let total_out: f64 = p.iter().sum();
        assert!((total_in - total_out).abs() < 1e-9);
    }

    #[test]
    fn step_matrices_padding_identity_property() {
        let (_, tm) = model_4x4();
        let (a, bm) = tm.step_matrices(1e-6).unwrap();
        assert_eq!(a.n_rows, tm.n);
        assert_eq!(bm.n_rows, tm.n);
        // A rows sum <= 1 (decay), Bm nonnegative-ish.
        for i in 0..tm.n {
            let s: f64 = (0..tm.n).map(|j| a[(i, j)]).sum();
            assert!(s <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn temps_csv_lists_every_chiplet_in_absolute_degrees() {
        let (hw, tm) = model_4x4();
        let p = tm.node_power(&vec![1.5; hw.num_chiplets()]);
        let t = crate::util::linalg::Lu::factor(&tm.g).unwrap().solve(&p);
        let csv = tm.temps_csv(&t, hw.num_chiplets());
        assert!(csv.starts_with("chiplet,temp_c\n"));
        assert_eq!(csv.lines().count(), 1 + hw.num_chiplets());
        // Every reported value is absolute (>= ambient under heating).
        for line in csv.lines().skip(1) {
            let temp: f64 = line.split(',').nth(1).unwrap().parse().unwrap();
            assert!(temp >= consts::T_AMBIENT, "{line}");
        }
    }

    #[test]
    fn heatmap_renders() {
        let (hw, tm) = model_4x4();
        let p = tm.node_power(&vec![2.0; hw.num_chiplets()]);
        let t = crate::util::linalg::Lu::factor(&tm.g).unwrap().solve(&p);
        let map = tm.heatmap(&t, 4, 4);
        assert!(map.lines().count() >= 5);
        assert!(map.contains("°C"));
    }
}
