//! Incremental thermal integration over streamed power windows.
//!
//! The batch flow solves thermal post-mortem from the whole power trace;
//! a streaming run has no whole trace — [`PowerTracker`] bins drain one
//! window behind virtual time.  [`ThermalStepper`] closes that gap: it
//! precomputes the implicit-Euler step matrices once (native solver, or
//! the PJRT AOT artifact when available) and then advances the RC state
//! one drained [`PowerWindow`] at a time, so the thermal trajectory is
//! exact over the *entire* horizon while memory stays constant.
//!
//! Consumers:
//! * `Simulation::run_with` attaches a stepper to the window-drain path
//!   (`sim::PowerPort`) for `ThermalSpec::Native`/`Auto`, so traffic runs
//!   no longer compute thermal on only the undrained tail of the trace;
//! * the closed-loop DTM controller (`crate::dtm`) steps it every control
//!   window and feeds the resulting temperatures to its governor.

use super::{native::NativeSolver, pjrt::PjrtThermalSolver, ThermalModel};
use crate::config::HardwareConfig;
use crate::power::PowerWindow;
use crate::TimeNs;

enum Backend {
    Native(NativeSolver),
    Pjrt(Box<PjrtThermalSolver>),
}

/// Incremental RC-network integrator: feed it power windows as they are
/// drained and read chiplet temperatures between feeds.
///
/// Stride groups are tracked *globally*: a window whose bin count is not
/// a multiple of `stride_bins` leaves its partial group in a carry that
/// the next window continues, so the integration is identical no matter
/// how the same bins were split into windows.  Call [`flush`](Self::flush)
/// once at end of run to integrate the final short group (averaged over
/// its actual bins, stepped at the full dt — matching the last short row
/// of the old whole-trace decimation).
pub struct ThermalStepper {
    model: ThermalModel,
    backend: Backend,
    /// Power bins averaged per integration step.
    stride_bins: usize,
    bin_ns: TimeNs,
    /// Current ΔT above ambient, node space.
    t: Vec<f64>,
    steps: usize,
    solver: &'static str,
    /// Partial stride group carried across windows: accumulated energy
    /// (dynamic + baseline-as-energy), pJ per chiplet.
    carry_pj: Vec<f64>,
    carry_bins: usize,
}

impl ThermalStepper {
    /// Build the RC network for `hw` and precompute step matrices for a
    /// timestep of `stride_bins` power bins.  With `prefer_pjrt` the AOT
    /// artifact is tried first and the native solver is the fallback
    /// (mirroring `ThermalSpec::Auto`).
    pub fn new(
        hw: &HardwareConfig,
        bin_ns: TimeNs,
        stride_bins: usize,
        prefer_pjrt: bool,
    ) -> anyhow::Result<ThermalStepper> {
        anyhow::ensure!(bin_ns > 0, "thermal stepper needs bin_ns > 0");
        let stride_bins = stride_bins.max(1);
        let model = ThermalModel::build(hw);
        let dt_s = stride_bins as f64 * bin_ns as f64 * 1e-9;
        let (backend, solver) = if prefer_pjrt {
            match PjrtThermalSolver::open_default(&model, dt_s) {
                Ok(s) => (Backend::Pjrt(Box::new(s)), "pjrt-aot"),
                Err(e) => {
                    crate::warn_once!("PJRT thermal unavailable ({e}); using native solver");
                    (Backend::Native(NativeSolver::new(&model, dt_s)?), "native")
                }
            }
        } else {
            (Backend::Native(NativeSolver::new(&model, dt_s)?), "native")
        };
        let t = vec![0.0; model.n];
        let carry_pj = vec![0.0; model.chiplet_nodes.len()];
        Ok(ThermalStepper {
            model,
            backend,
            stride_bins,
            bin_ns,
            t,
            steps: 0,
            solver,
            carry_pj,
            carry_bins: 0,
        })
    }

    /// Integrate one power window: bins accumulate into the global
    /// stride group (continuing any carry from earlier windows) and each
    /// completed group is one implicit-Euler step.  Returns the number
    /// of steps taken; an incomplete trailing group stays in the carry.
    pub fn ingest(&mut self, w: &PowerWindow) -> anyhow::Result<usize> {
        let bins = w.bins();
        if bins == 0 {
            return Ok(0);
        }
        debug_assert_eq!(w.bin_ns, self.bin_ns, "window bin width mismatch");
        let nch = self.model.chiplet_nodes.len();
        let mut rows = Vec::with_capacity((self.carry_bins + bins) / self.stride_bins);
        for bin in 0..bins {
            for c in 0..nch {
                let dyn_pj =
                    w.energy_pj.get(c).and_then(|row| row.get(bin)).copied().unwrap_or(0.0);
                let baseline_pj =
                    w.baseline_mw.get(c).copied().unwrap_or(0.0) * w.bin_ns as f64;
                self.carry_pj[c] += dyn_pj + baseline_pj;
            }
            self.carry_bins += 1;
            if self.carry_bins == self.stride_bins {
                let row = self.take_group_row();
                rows.push(row);
            }
        }
        self.advance(rows)
    }

    /// Stream the tracker's live bins into the stepper without
    /// materializing a snapshot (the end-of-run tail of a batch run can
    /// be the whole trace).
    pub fn ingest_live(&mut self, power: &crate::power::PowerTracker) -> anyhow::Result<usize> {
        debug_assert_eq!(power.bin_ns, self.bin_ns, "tracker bin width mismatch");
        let nch = self.model.chiplet_nodes.len();
        let first = power.drained_bins();
        let total = power.num_bins();
        let mut rows = Vec::new();
        for bin in first..total {
            for c in 0..nch {
                // dynamic + baseline power, mW, over one bin -> pJ.
                self.carry_pj[c] += power.power_mw(c, bin) * self.bin_ns as f64;
            }
            self.carry_bins += 1;
            if self.carry_bins == self.stride_bins {
                let row = self.take_group_row();
                rows.push(row);
            }
        }
        self.advance(rows)
    }

    /// Integrate any partial stride group left in the carry (mean power
    /// over its actual bins, one full-dt step).  Call once at end of
    /// run, after the last ingest.
    pub fn flush(&mut self) -> anyhow::Result<usize> {
        if self.carry_bins == 0 {
            return Ok(0);
        }
        let row = self.take_group_row();
        self.advance(vec![row])
    }

    /// Close the current group: mean power in W per chiplet, expanded to
    /// node space; resets the carry.
    fn take_group_row(&mut self) -> Vec<f64> {
        let span_ns = self.carry_bins as f64 * self.bin_ns as f64;
        let chiplet_w: Vec<f64> =
            self.carry_pj.iter().map(|pj| pj / span_ns * 1e-3).collect();
        for pj in self.carry_pj.iter_mut() {
            *pj = 0.0;
        }
        self.carry_bins = 0;
        self.model.node_power(&chiplet_w)
    }

    fn advance(&mut self, rows: Vec<Vec<f64>>) -> anyhow::Result<usize> {
        if rows.is_empty() {
            return Ok(0);
        }
        let traj = match &mut self.backend {
            Backend::Native(s) => s.transient(&self.t, &rows),
            Backend::Pjrt(s) => s.transient(&self.t, &rows)?,
        };
        if let Some(last) = traj.last() {
            self.t = last.clone();
        }
        self.steps += rows.len();
        Ok(rows.len())
    }

    /// Which solver integrates the steps ("native" or "pjrt-aot").
    pub fn solver(&self) -> &'static str {
        self.solver
    }

    /// Implicit-Euler steps integrated so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Current ΔT above ambient, node space.
    pub fn delta_t(&self) -> &[f64] {
        &self.t
    }

    pub fn model(&self) -> &ThermalModel {
        &self.model
    }

    /// Current absolute per-chiplet temperatures, °C.
    pub fn chiplet_temps_c(&self) -> Vec<f64> {
        (0..self.model.chiplet_nodes.len())
            .map(|c| self.model.chiplet_temp(&self.t, c) + self.model.ambient_c)
            .collect()
    }

    /// Current hottest chiplet, °C.
    pub fn hottest_c(&self) -> f64 {
        self.chiplet_temps_c().into_iter().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::PowerTracker;

    fn hw() -> HardwareConfig {
        HardwareConfig::homogeneous_mesh(3, 3)
    }

    /// A window with `watts` of flat per-chiplet power over `bins` bins.
    fn flat_window(
        nch: usize,
        bins: usize,
        bin_ns: TimeNs,
        start_ns: TimeNs,
        watts: f64,
    ) -> PowerWindow {
        // watts -> mW -> pJ per bin (mW * ns).
        let pj_per_bin = watts * 1e3 * bin_ns as f64;
        PowerWindow {
            start_ns,
            bin_ns,
            energy_pj: vec![vec![pj_per_bin; bins]; nch],
            baseline_mw: vec![0.0; nch],
        }
    }

    #[test]
    fn windowed_ingest_matches_one_shot_transient() {
        // Feeding N windows must land on the same state as one batch
        // transient over the concatenated rows (same dt, same powers).
        let hw = hw();
        let mut stepper = ThermalStepper::new(&hw, 1_000, 10, false).unwrap();
        let nch = hw.num_chiplets();
        for k in 0..5u64 {
            let w = flat_window(nch, 20, 1_000, k * 20_000, 2.0);
            stepper.ingest(&w).unwrap();
        }
        assert_eq!(stepper.steps(), 10); // 5 windows x 20 bins / stride 10
        let tm = ThermalModel::build(&hw);
        let solver = NativeSolver::new(&tm, 10.0 * 1_000.0 * 1e-9).unwrap();
        let p = tm.node_power(&vec![2.0; nch]);
        let traj = solver.transient(&vec![0.0; tm.n], &vec![p; 10]);
        let want = traj.last().unwrap();
        for i in 0..tm.n {
            assert!(
                (stepper.delta_t()[i] - want[i]).abs() < 1e-12,
                "node {i}: {} vs {}",
                stepper.delta_t()[i],
                want[i]
            );
        }
    }

    #[test]
    fn constant_power_converges_to_steady_state_via_windows() {
        // Long constant-power feed through windows converges to the
        // direct steady-state solve 0 = -G T + P.
        let hw = hw();
        let nch = hw.num_chiplets();
        // 0.1 s steps: 100 windows x 10 bins of 1 ms, stride 100 -> one
        // step per window, 10 s simulated.
        let bin_ns = 1_000_000; // 1 ms bins
        let mut stepper = ThermalStepper::new(&hw, bin_ns, 100, false).unwrap();
        for k in 0..100u64 {
            let w = flat_window(nch, 100, bin_ns, k * 100_000_000, 3.0);
            stepper.ingest(&w).unwrap();
        }
        let tm = stepper.model();
        let p = tm.node_power(&vec![3.0; nch]);
        let steady = NativeSolver::steady(tm, &p).unwrap();
        for i in 0..tm.n {
            let err = (stepper.delta_t()[i] - steady[i]).abs() / steady[i].abs().max(1e-9);
            assert!(err < 0.05, "node {i}: {} vs steady {}", stepper.delta_t()[i], steady[i]);
        }
        assert!(stepper.hottest_c() > tm.ambient_c);
    }

    #[test]
    fn empty_and_idle_windows_are_safe() {
        let hw = hw();
        let mut stepper = ThermalStepper::new(&hw, 1_000, 10, false).unwrap();
        let mut tracker = PowerTracker::new(hw.num_chiplets(), 1_000);
        // Nothing booked: an empty drain integrates zero steps.
        let w = tracker.drain_window(0);
        assert_eq!(stepper.ingest(&w).unwrap(), 0);
        // An idle (all-zero) 5-bin window is shorter than the 10-bin
        // stride: it stays in the carry until flushed.
        let w = tracker.drain_window(5_000);
        assert_eq!(stepper.ingest(&w).unwrap(), 0);
        assert_eq!(stepper.flush().unwrap(), 1);
        assert_eq!(stepper.flush().unwrap(), 0, "flush is idempotent");
        assert!(stepper.delta_t().iter().all(|&x| x.abs() < 1e-12));
        let temps = stepper.chiplet_temps_c();
        assert_eq!(temps.len(), hw.num_chiplets());
        assert!(temps.iter().all(|&t| (t - stepper.model().ambient_c).abs() < 1e-9));
    }

    #[test]
    fn stride_groups_are_continuous_across_misaligned_windows() {
        // 3 windows of 15 bins with a 10-bin stride must integrate the
        // exact same trajectory as one 45-bin window: the partial group
        // carries over instead of being stepped short at full dt.
        let hw = hw();
        let nch = hw.num_chiplets();
        let run = |splits: &[usize]| {
            let mut stepper = ThermalStepper::new(&hw, 1_000, 10, false).unwrap();
            let mut start = 0u64;
            for &bins in splits {
                let w = flat_window(nch, bins, 1_000, start, 1.5);
                stepper.ingest(&w).unwrap();
                start += bins as u64 * 1_000;
            }
            stepper.flush().unwrap();
            (stepper.steps(), stepper.delta_t().to_vec())
        };
        let (steps_split, t_split) = run(&[15, 15, 15]);
        let (steps_whole, t_whole) = run(&[45]);
        assert_eq!(steps_split, steps_whole);
        assert_eq!(steps_split, 5, "45 bins / stride 10 = 4 full groups + 1 flushed tail");
        for (a, b) in t_split.iter().zip(&t_whole) {
            assert!((a - b).abs() < 1e-15, "{a} vs {b}");
        }
    }

    #[test]
    fn ingest_live_matches_window_ingest() {
        let hw = hw();
        let mut tracker = PowerTracker::new(hw.num_chiplets(), 1_000);
        tracker.set_baseline_mw(0, 2.0);
        tracker.add_energy(0, 500, 7_000, 21_000.0);
        tracker.add_event(3, 9_100, 500.0);
        let mut via_live = ThermalStepper::new(&hw, 1_000, 4, false).unwrap();
        via_live.ingest_live(&tracker).unwrap();
        via_live.flush().unwrap();
        let mut via_window = ThermalStepper::new(&hw, 1_000, 4, false).unwrap();
        via_window.ingest(&tracker.live_window()).unwrap();
        via_window.flush().unwrap();
        assert_eq!(via_live.steps(), via_window.steps());
        for (a, b) in via_live.delta_t().iter().zip(via_window.delta_t()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }
}
