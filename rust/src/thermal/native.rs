//! In-process thermal solver: the correctness oracle for the PJRT path.
//!
//! Same math as the AOT artifact (implicit Euler + CG), run in f64 with
//! `util::linalg`.  Tests cross-check `pjrt::PjrtThermalSolver` against
//! this solver to f32 tolerance.

use super::ThermalModel;
use crate::util::linalg::{Lu, Mat};

/// Transient + steady-state solver over a thermal model.
pub struct NativeSolver {
    a: Mat,
    bm: Mat,
    pub dt_s: f64,
}

impl NativeSolver {
    /// Precompute the implicit-Euler matrices for timestep `dt_s`.
    pub fn new(model: &ThermalModel, dt_s: f64) -> anyhow::Result<NativeSolver> {
        let (a, bm) = model.step_matrices(dt_s)?;
        Ok(NativeSolver { a, bm, dt_s })
    }

    /// One step: T' = A·T + Bm·P  (P in node space, W).
    pub fn step(&self, t: &[f64], p: &[f64]) -> Vec<f64> {
        let at = self.a.matvec(t);
        let bp = self.bm.matvec(p);
        at.iter().zip(&bp).map(|(x, y)| x + y).collect()
    }

    /// Integrate a power timeline (rows = steps, node space).  Returns the
    /// trajectory (ΔT per step).
    pub fn transient(&self, t0: &[f64], p_steps: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let mut t = t0.to_vec();
        let mut traj = Vec::with_capacity(p_steps.len());
        for p in p_steps {
            t = self.step(&t, p);
            traj.push(t.clone());
        }
        traj
    }

    /// Steady state: solve G·T = P directly (LU).
    pub fn steady(model: &ThermalModel, p: &[f64]) -> anyhow::Result<Vec<f64>> {
        Ok(Lu::factor(&model.g)?.solve(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::thermal::ThermalModel;

    fn setup() -> (HardwareConfig, ThermalModel, NativeSolver) {
        let hw = HardwareConfig::homogeneous_mesh(3, 3);
        let tm = ThermalModel::build(&hw);
        let solver = NativeSolver::new(&tm, 1e-6).unwrap();
        (hw, tm, solver)
    }

    #[test]
    fn zero_power_stays_at_ambient() {
        let (_, tm, s) = setup();
        let p = vec![vec![0.0; tm.n]; 10];
        let traj = s.transient(&vec![0.0; tm.n], &p);
        for row in traj {
            assert!(row.iter().all(|&x| x.abs() < 1e-12));
        }
    }

    #[test]
    fn constant_power_converges_to_steady_state() {
        let (hw, tm, _s) = setup();
        let p_node = tm.node_power(&vec![3.0; hw.num_chiplets()]);
        let steady = NativeSolver::steady(&tm, &p_node).unwrap();
        // The spreader-to-ambient time constant is seconds-scale; implicit
        // Euler is unconditionally stable, so integrate 60 s in 0.1 s steps.
        let big = NativeSolver::new(&tm, 0.1).unwrap();
        let steps = vec![p_node.clone(); 600];
        let traj = big.transient(&vec![0.0; tm.n], &steps);
        let last = traj.last().unwrap();
        for i in 0..tm.n {
            let err = (last[i] - steady[i]).abs() / steady[i].abs().max(1e-9);
            assert!(err < 0.05, "node {i}: {} vs steady {}", last[i], steady[i]);
        }
    }

    #[test]
    fn monotone_heating_under_constant_power() {
        let (hw, tm, s) = setup();
        let p_node = tm.node_power(&vec![2.0; hw.num_chiplets()]);
        let steps = vec![p_node; 50];
        let traj = s.transient(&vec![0.0; tm.n], &steps);
        for w in traj.windows(2) {
            for i in 0..tm.n {
                assert!(w[1][i] >= w[0][i] - 1e-12);
            }
        }
    }

    #[test]
    fn cooling_after_power_off() {
        // After power-off, heat keeps diffusing into the passive layers,
        // so individual passive nodes may still warm — but total stored
        // thermal energy (Σ C·T) must decrease monotonically, and the hot
        // die nodes must cool.
        let (hw, tm, s) = setup();
        let hot = tm.node_power(&vec![5.0; hw.num_chiplets()]);
        let mut steps = vec![hot; 100];
        steps.extend(vec![vec![0.0; tm.n]; 100]);
        let traj = s.transient(&vec![0.0; tm.n], &steps);
        let energy = |t: &Vec<f64>| -> f64 { t.iter().zip(&tm.c).map(|(x, c)| x * c).sum() };
        for w in traj[100..].windows(2) {
            assert!(energy(&w[1]) <= energy(&w[0]) + 1e-12);
        }
        let die0 = tm.chiplet_nodes[0][0];
        assert!(traj.last().unwrap()[die0] < traj[99][die0]);
    }

    #[test]
    fn transient_is_the_composition_of_single_steps() {
        // The incremental stepping used by the DTM loop relies on
        // transient(t0, [p1..pk]) == step(..step(step(t0,p1),p2)..,pk).
        let (hw, tm, s) = setup();
        let mut chips = vec![0.0; hw.num_chiplets()];
        chips[0] = 4.0;
        chips[8] = 1.0;
        let p = tm.node_power(&chips);
        let steps = vec![p.clone(); 7];
        let traj = s.transient(&vec![0.0; tm.n], &steps);
        let mut t = vec![0.0; tm.n];
        for _ in 0..7 {
            t = s.step(&t, &p);
        }
        for i in 0..tm.n {
            assert!((traj[6][i] - t[i]).abs() < 1e-15, "node {i}");
        }
    }

    #[test]
    fn superposition_holds() {
        // Linear system: T(p1 + p2) == T(p1) + T(p2).
        let (hw, tm, s) = setup();
        let p1 = tm.node_power(&vec![1.0; hw.num_chiplets()]);
        let mut chips2 = vec![0.0; hw.num_chiplets()];
        chips2[4] = 7.0;
        let p2 = tm.node_power(&chips2);
        let psum: Vec<f64> = p1.iter().zip(&p2).map(|(a, b)| a + b).collect();
        let t1 = s.transient(&vec![0.0; tm.n], &vec![p1; 20]);
        let t2 = s.transient(&vec![0.0; tm.n], &vec![p2; 20]);
        let ts = s.transient(&vec![0.0; tm.n], &vec![psum; 20]);
        for i in 0..tm.n {
            let want = t1[19][i] + t2[19][i];
            assert!((ts[19][i] - want).abs() < 1e-9);
        }
    }
}
