//! PJRT-backed thermal solver: drives the AOT JAX/Pallas artifacts.
//!
//! `thermal_transient_n{N}` integrates [`CHUNK`] implicit-Euler steps per
//! dispatch (the scan lives inside the HLO, not in Rust, so dispatch
//! overhead is amortized 256×); `thermal_steady_n{N}` runs 64 CG
//! iterations per dispatch with warm restart until the residual converges.
//!
//! The RC system is zero-padded to the nearest artifact size variant with
//! the convention tested in `python/tests/test_model.py`: padded rows of A
//! are identity, of Bm zero, padded G rows are identity-diagonal, padded
//! power entries zero — padded nodes stay exactly at ΔT = 0.

use super::ThermalModel;
use crate::runtime::{F32Tensor, Runtime};
use crate::util::linalg::Mat;

/// Artifact-served thermal solver.
pub struct PjrtThermalSolver {
    rt: Runtime,
    /// Real node count.
    n: usize,
    /// Padded artifact variant size.
    n_pad: usize,
    /// Steps per transient dispatch.
    chunk: usize,
    a_pad: F32Tensor,
    bm_pad: F32Tensor,
    g_pad: F32Tensor,
    pub dt_s: f64,
}

impl PjrtThermalSolver {
    /// Build from a thermal model + runtime; precomputes padded matrices.
    pub fn new(model: &ThermalModel, dt_s: f64, rt: Runtime) -> anyhow::Result<Self> {
        let sizes: Vec<usize> = rt
            .manifest
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("thermal_transient_n").and_then(|s| s.parse().ok()))
            .collect();
        anyhow::ensure!(!sizes.is_empty(), "no thermal artifacts in manifest");
        let n = model.n;
        let n_pad = *sizes
            .iter()
            .filter(|&&s| s >= n)
            .min()
            .ok_or_else(|| anyhow::anyhow!("no artifact variant fits {n} nodes (have {sizes:?})"))?;
        let chunk = rt
            .manifest
            .constant_usize("transient_chunk")
            .ok_or_else(|| anyhow::anyhow!("manifest missing transient_chunk"))?;
        let (a, bm) = model.step_matrices(dt_s)?;
        Ok(PjrtThermalSolver {
            n,
            n_pad,
            chunk,
            a_pad: pad_matrix(&a, n_pad, true),
            bm_pad: pad_matrix(&bm, n_pad, false),
            g_pad: pad_matrix(&model.g, n_pad, true),
            rt,
            dt_s,
        })
    }

    pub fn open_default(model: &ThermalModel, dt_s: f64) -> anyhow::Result<Self> {
        Self::new(model, dt_s, Runtime::open_default()?)
    }

    pub fn dispatches(&self) -> u64 {
        self.rt.dispatches
    }

    /// Integrate a node-space power timeline; returns the ΔT trajectory
    /// (one row per step, truncated to the real node count).
    pub fn transient(&mut self, t0: &[f64], p_steps: &[Vec<f64>]) -> anyhow::Result<Vec<Vec<f64>>> {
        assert_eq!(t0.len(), self.n);
        let name = format!("thermal_transient_n{}", self.n_pad);
        let mut t: Vec<f32> = (0..self.n_pad)
            .map(|i| if i < self.n { t0[i] as f32 } else { 0.0 })
            .collect();
        let mut traj = Vec::with_capacity(p_steps.len());
        let mut s = 0;
        while s < p_steps.len() {
            let take = (p_steps.len() - s).min(self.chunk);
            let mut p = vec![0.0f32; self.chunk * self.n_pad];
            for (row, step) in p_steps[s..s + take].iter().enumerate() {
                assert_eq!(step.len(), self.n);
                for (j, &w) in step.iter().enumerate() {
                    p[row * self.n_pad + j] = w as f32;
                }
            }
            let out = self.rt.exec_f32(
                &name,
                &[
                    self.a_pad.clone(),
                    self.bm_pad.clone(),
                    F32Tensor::new(vec![self.n_pad], t.clone()),
                    F32Tensor::new(vec![self.chunk, self.n_pad], p),
                ],
            )?;
            // out[0] = trajectory [chunk, n_pad]; out[1] = final state.
            for row in 0..take {
                traj.push(
                    out[0][row * self.n_pad..row * self.n_pad + self.n]
                        .iter()
                        .map(|&x| x as f64)
                        .collect(),
                );
            }
            // Carry the state at the end of the *taken* rows (if the chunk
            // was partial, the remaining rows ran with zero power — padded
            // nodes unaffected, but real nodes would decay; so restart from
            // the last taken row instead of out[1]).
            if take == self.chunk {
                t = out[1].clone();
            } else {
                let row = take - 1;
                let mut nt = vec![0.0f32; self.n_pad];
                nt[..self.n_pad]
                    .copy_from_slice(&out[0][row * self.n_pad..(row + 1) * self.n_pad]);
                t = nt;
            }
            s += take;
        }
        Ok(traj)
    }

    /// Steady state via warm-restarted CG dispatches.
    pub fn steady(&mut self, p: &[f64], tol: f64, max_dispatches: usize) -> anyhow::Result<Vec<f64>> {
        assert_eq!(p.len(), self.n);
        let name = format!("thermal_steady_n{}", self.n_pad);
        let mut pp = vec![0.0f32; self.n_pad];
        for (i, &x) in p.iter().enumerate() {
            pp[i] = x as f32;
        }
        let mut t = vec![0.0f32; self.n_pad];
        for _ in 0..max_dispatches {
            let out = self.rt.exec_f32(
                &name,
                &[
                    self.g_pad.clone(),
                    F32Tensor::new(vec![self.n_pad], pp.clone()),
                    F32Tensor::new(vec![self.n_pad], t.clone()),
                ],
            )?;
            t = out[0].clone();
            let rs = out[1][0] as f64;
            if rs < tol {
                break;
            }
        }
        Ok(t[..self.n].iter().map(|&x| x as f64).collect())
    }
}

/// Zero-pad a square matrix to `n_pad`; `identity_diag` puts 1.0 on the
/// padded diagonal (required for A and G so padded nodes are inert and G
/// stays non-singular).
fn pad_matrix(m: &Mat, n_pad: usize, identity_diag: bool) -> F32Tensor {
    let n = m.n_rows;
    let mut data = vec![0.0f32; n_pad * n_pad];
    for i in 0..n {
        for j in 0..n {
            data[i * n_pad + j] = m[(i, j)] as f32;
        }
    }
    if identity_diag {
        for i in n..n_pad {
            data[i * n_pad + i] = 1.0;
        }
    }
    F32Tensor::new(vec![n_pad, n_pad], data)
}

// Integration tests that execute artifacts live in
// rust/tests/runtime_artifacts.rs (they need `make artifacts` to have run).
