//! PJRT-served IMC compute backend.
//!
//! Runs the batched IMC estimator that `python/compile/model.py::imc_batch`
//! lowered to `artifacts/imc_batch_b128.hlo.txt`.  Feature/parameter/output
//! layouts must stay in sync with `python/compile/kernels/ref.py`:
//!
//! features[b] = [macs, weight_bytes, in_act_bytes, out_act_elems,
//!                rows_used, cols_used]
//! params      = [mac_rate_gops, e_mac_pj, e_adc_pj, t_adc_ns_per_elem,
//!                base_latency_ns, leak_mw]
//! outputs[b]  = [latency_ns, energy_pj, avg_power_mw]
//!
//! Segments are grouped by chiplet type (params are per-dispatch) and
//! padded to the artifact batch size.  This backend exists to prove the
//! "compute simulator is swappable, even out-of-process" property of the
//! paper (§III-C); it matches [`super::AnalyticalImc`] to f32 precision —
//! see `rust/tests/runtime_artifacts.rs`.

use super::{ComputeBackend, ComputeResult, SegmentWork};
use crate::config::{ChipletClass, ChipletTypeParams};
use crate::runtime::{F32Tensor, Runtime};

/// Batched PJRT backend (falls back to CPU-analytical for non-IMC types).
pub struct PjrtImcBackend {
    rt: Runtime,
    batch: usize,
    artifact: String,
    cpu_fallback: super::AnalyticalCpu,
}

impl PjrtImcBackend {
    pub fn new(rt: Runtime) -> anyhow::Result<Self> {
        let batch = rt
            .manifest
            .constant_usize("imc_batch")
            .ok_or_else(|| anyhow::anyhow!("manifest missing imc_batch constant"))?;
        let artifact = format!("imc_batch_b{batch}");
        anyhow::ensure!(
            rt.manifest.entries.contains_key(&artifact),
            "artifact '{artifact}' not found — run `make artifacts`"
        );
        Ok(PjrtImcBackend { rt, batch, artifact, cpu_fallback: super::AnalyticalCpu })
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::new(Runtime::open_default()?)
    }

    fn params_of(c: &ChipletTypeParams) -> [f32; 6] {
        [
            c.mac_rate_gops as f32,
            c.e_mac_pj as f32,
            c.e_adc_pj as f32,
            c.t_adc_ns_per_elem as f32,
            c.base_latency_ns as f32,
            c.leak_mw as f32,
        ]
    }

    fn features_of(w: &SegmentWork) -> [f32; 6] {
        [
            w.macs as f32,
            w.weight_bytes as f32,
            w.in_bytes as f32,
            w.out_elems as f32,
            w.rows_used as f32,
            w.cols_used as f32,
        ]
    }

    /// Dispatch one padded batch for a single chiplet-type parameter set.
    fn dispatch(
        &mut self,
        params: [f32; 6],
        works: &[SegmentWork],
    ) -> anyhow::Result<Vec<ComputeResult>> {
        let mut results = Vec::with_capacity(works.len());
        for chunk in works.chunks(self.batch) {
            let mut feat = vec![0.0f32; self.batch * 6];
            for (i, w) in chunk.iter().enumerate() {
                feat[i * 6..(i + 1) * 6].copy_from_slice(&Self::features_of(w));
            }
            // Padding rows are all-zero -> harmless outputs, discarded.
            let out = self.rt.exec_f32(
                &self.artifact,
                &[
                    F32Tensor::new(vec![self.batch, 6], feat),
                    F32Tensor::new(vec![6], params.to_vec()),
                ],
            )?;
            let flat = &out[0]; // [batch, 3]
            for i in 0..chunk.len() {
                results.push(ComputeResult {
                    latency_ns: flat[i * 3] as f64,
                    energy_pj: flat[i * 3 + 1] as f64,
                    avg_power_mw: flat[i * 3 + 2] as f64,
                });
            }
        }
        Ok(results)
    }
}

impl ComputeBackend for PjrtImcBackend {
    fn name(&self) -> &'static str {
        "pjrt-imc"
    }

    fn evaluate(&mut self, chiplet: &ChipletTypeParams, work: &SegmentWork) -> ComputeResult {
        if chiplet.class != ChipletClass::Imc {
            return self.cpu_fallback.evaluate(chiplet, work);
        }
        self.dispatch(Self::params_of(chiplet), std::slice::from_ref(work))
            .expect("pjrt imc dispatch")[0]
    }

    fn evaluate_batch(
        &mut self,
        items: &[(&ChipletTypeParams, SegmentWork)],
    ) -> Vec<ComputeResult> {
        // Group contiguous-by-parameter-set so mapped models (usually one
        // or two chiplet types) need only a few dispatches.
        let mut out = vec![
            ComputeResult { latency_ns: 0.0, energy_pj: 0.0, avg_power_mw: 0.0 };
            items.len()
        ];
        let mut groups: Vec<([f32; 6], Vec<usize>)> = Vec::new();
        for (idx, (c, w)) in items.iter().enumerate() {
            if c.class != ChipletClass::Imc {
                out[idx] = self.cpu_fallback.evaluate(c, w);
                continue;
            }
            let p = Self::params_of(c);
            match groups.iter_mut().find(|(gp, _)| *gp == p) {
                Some((_, idxs)) => idxs.push(idx),
                None => groups.push((p, vec![idx])),
            }
        }
        for (p, idxs) in groups {
            let works: Vec<SegmentWork> = idxs.iter().map(|&i| items[i].1).collect();
            let res = self.dispatch(p, &works).expect("pjrt imc batch dispatch");
            for (slot, r) in idxs.into_iter().zip(res) {
                out[slot] = r;
            }
        }
        out
    }
}
