//! Compute-simulation backends (the CiMLoop-analog layer of CHIPSIM).
//!
//! Compute within a chiplet is independent of other chiplets, so CHIPSIM
//! evaluates each mapped layer segment with an event-based backend and
//! schedules the completion on the global timeline (paper §III-C).  The
//! backend interface is deliberately narrow — "standardized input/output
//! format" — so backends are swappable without touching the coordinator:
//!
//! * [`AnalyticalImc`] — analytical in-memory-compute model calibrated to
//!   the paper's cited chips (NeuRRAM [34] / RAELLA [33]); identical
//!   formulas to the python oracle `kernels/ref.py::imc_estimate_ref`.
//! * [`AnalyticalCpu`] — MACs-per-second CPU model used by the §V-F
//!   hardware-validation study (the paper swapped CiMLoop for exactly
//!   such a model to show backend modularity).
//! * [`pjrt::PjrtImcBackend`] — the same IMC estimator served from the
//!   AOT-compiled JAX/Pallas artifact through the PJRT runtime
//!   (`--compute pjrt`), demonstrating an out-of-process backend.

pub mod pjrt;

use crate::config::{ChipletClass, ChipletTypeParams};
use crate::workload::LayerDesc;

/// Work descriptor for one mapped layer segment (a fraction of a layer).
#[derive(Debug, Clone, Copy)]
pub struct SegmentWork {
    pub macs: u64,
    pub weight_bytes: u64,
    pub in_bytes: u64,
    pub out_elems: u64,
    /// Crossbar rows/cols activated (informational for IMC models).
    pub rows_used: u64,
    pub cols_used: u64,
}

impl SegmentWork {
    /// Slice `frac` of a layer's work (layer split across segments).
    pub fn from_layer(layer: &LayerDesc, frac: f64) -> SegmentWork {
        let f = |x: u64| ((x as f64) * frac).ceil() as u64;
        SegmentWork {
            macs: f(layer.macs),
            weight_bytes: f(layer.weight_bytes),
            // Input activations are broadcast to every segment in full.
            in_bytes: layer.in_bytes,
            out_elems: f(layer.out_elems),
            rows_used: 256,
            cols_used: 256,
        }
    }
}

/// Result of simulating one segment on one chiplet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeResult {
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub avg_power_mw: f64,
}

/// A compute simulator backend.
///
/// `Send` (not `Sync`): each simulation owns its backend exclusively, and
/// the fleet layer moves whole replica boards across worker-pool threads
/// between epochs.  Compute *parallelism* within one board is still
/// event-level, not thread-level — a backend is never called from two
/// threads at once.
pub trait ComputeBackend: Send {
    fn name(&self) -> &'static str;

    /// Evaluate one segment on one chiplet type.
    fn evaluate(&mut self, chiplet: &ChipletTypeParams, work: &SegmentWork) -> ComputeResult;

    /// Batched evaluation — the Global Manager calls this once per mapped
    /// model with every (chiplet, segment) pair, which lets artifact-based
    /// backends amortize dispatch.  Default: loop over `evaluate`.
    fn evaluate_batch(
        &mut self,
        items: &[(&ChipletTypeParams, SegmentWork)],
    ) -> Vec<ComputeResult> {
        items.iter().map(|(c, w)| self.evaluate(c, w)).collect()
    }
}

// ----------------------------------------------------------------- IMC

/// Analytical IMC model (CiMLoop analog).  Keep in sync with
/// `python/compile/kernels/ref.py::imc_estimate_ref` — the PJRT backend
/// runs that exact formula and tests assert agreement.
pub struct AnalyticalImc;

impl ComputeBackend for AnalyticalImc {
    fn name(&self) -> &'static str {
        "analytical-imc"
    }

    fn evaluate(&mut self, chiplet: &ChipletTypeParams, w: &SegmentWork) -> ComputeResult {
        debug_assert!(matches!(chiplet.class, ChipletClass::Imc));
        let t_mac = w.macs as f64 / chiplet.mac_rate_gops.max(1e-9);
        let t_adc = w.out_elems as f64 * chiplet.t_adc_ns_per_elem;
        let latency = chiplet.base_latency_ns + t_mac.max(t_adc);
        let e_dyn = w.macs as f64 * chiplet.e_mac_pj + w.out_elems as f64 * chiplet.e_adc_pj;
        let e_leak = chiplet.leak_mw * latency * 1e-3; // mW * ns -> pJ
        let energy = e_dyn + e_leak;
        ComputeResult {
            latency_ns: latency,
            energy_pj: energy,
            avg_power_mw: energy / latency.max(1e-9) * 1e3,
        }
    }
}

// ----------------------------------------------------------------- CPU

/// Analytical CPU model: latency = MACs / sustained MAC rate (measured on
/// the emulated platform by micro-kernels, see `hwemu::`).
pub struct AnalyticalCpu;

impl ComputeBackend for AnalyticalCpu {
    fn name(&self) -> &'static str {
        "analytical-cpu"
    }

    fn evaluate(&mut self, chiplet: &ChipletTypeParams, w: &SegmentWork) -> ComputeResult {
        let t_mac = w.macs as f64 / chiplet.mac_rate_gops.max(1e-9);
        let latency = chiplet.base_latency_ns + t_mac;
        let e_dyn = w.macs as f64 * chiplet.e_mac_pj;
        let e_static = chiplet.leak_mw * latency * 1e-3;
        let energy = e_dyn + e_static;
        ComputeResult {
            latency_ns: latency,
            energy_pj: energy,
            avg_power_mw: energy / latency.max(1e-9) * 1e3,
        }
    }
}

/// Dispatch on chiplet class: IMC chiplets -> IMC model, CPU -> CPU model.
/// I/O dies never compute (the mapper excludes them); evaluating one is a
/// coordinator bug and panics in debug builds.
pub struct ClassDispatchBackend {
    imc: AnalyticalImc,
    cpu: AnalyticalCpu,
}

impl ClassDispatchBackend {
    pub fn new() -> Self {
        ClassDispatchBackend { imc: AnalyticalImc, cpu: AnalyticalCpu }
    }
}

impl Default for ClassDispatchBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeBackend for ClassDispatchBackend {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn evaluate(&mut self, chiplet: &ChipletTypeParams, w: &SegmentWork) -> ComputeResult {
        match chiplet.class {
            ChipletClass::Imc => self.imc.evaluate(chiplet, w),
            ChipletClass::Cpu => self.cpu.evaluate(chiplet, w),
            ChipletClass::Io => {
                debug_assert!(false, "compute scheduled on an I/O die");
                ComputeResult { latency_ns: 0.0, energy_pj: 0.0, avg_power_mw: 0.0 }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ModelKind, NeuralModel};

    fn seg(macs: u64, out_elems: u64) -> SegmentWork {
        SegmentWork { macs, weight_bytes: 0, in_bytes: 0, out_elems, rows_used: 256, cols_used: 256 }
    }

    #[test]
    fn imc_latency_is_max_of_mac_and_adc() {
        let mut b = AnalyticalImc;
        let c = ChipletTypeParams::imc_type_a();
        // MAC-bound case.
        let r1 = b.evaluate(&c, &seg(1_000_000_000, 10));
        let t_mac = 1e9 / c.mac_rate_gops;
        assert!((r1.latency_ns - (c.base_latency_ns + t_mac)).abs() < 1e-6);
        // ADC-bound case.
        let r2 = b.evaluate(&c, &seg(10, 100_000_000));
        let t_adc = 1e8 * c.t_adc_ns_per_elem;
        assert!((r2.latency_ns - (c.base_latency_ns + t_adc)).abs() < 1e-3);
    }

    #[test]
    fn type_b_is_slower_but_lower_energy_per_mac() {
        let mut b = AnalyticalImc;
        let a = ChipletTypeParams::imc_type_a();
        let bb = ChipletTypeParams::imc_type_b();
        let w = seg(100_000_000, 1000);
        let ra = b.evaluate(&a, &w);
        let rb = b.evaluate(&bb, &w);
        assert!(rb.latency_ns > ra.latency_ns);
        assert!(bb.e_mac_pj < a.e_mac_pj);
    }

    #[test]
    fn power_consistency() {
        let mut b = ClassDispatchBackend::new();
        let c = ChipletTypeParams::imc_type_a();
        let r = b.evaluate(&c, &seg(50_000_000, 20_000));
        assert!((r.avg_power_mw - r.energy_pj / r.latency_ns * 1e3).abs() < 1e-6);
        assert!(r.avg_power_mw > 0.0);
    }

    #[test]
    fn segment_fraction_scales_work() {
        let m = NeuralModel::build(ModelKind::ResNet18);
        let l = &m.layers[2];
        let whole = SegmentWork::from_layer(l, 1.0);
        let half = SegmentWork::from_layer(l, 0.5);
        assert!(half.macs >= whole.macs / 2 && half.macs <= whole.macs / 2 + 1);
        assert_eq!(half.in_bytes, whole.in_bytes); // broadcast input
    }

    #[test]
    fn batch_matches_singles() {
        let mut b = ClassDispatchBackend::new();
        let c = ChipletTypeParams::imc_type_a();
        let works = [seg(1_000_000, 100), seg(2_000_000, 5_000), seg(123, 45)];
        let items: Vec<(&ChipletTypeParams, SegmentWork)> =
            works.iter().map(|w| (&c, *w)).collect();
        let batched = b.evaluate_batch(&items);
        for (w, r) in works.iter().zip(&batched) {
            assert_eq!(*r, b.evaluate(&c, w));
        }
    }

    #[test]
    fn cnn_layers_have_positive_latency_on_type_a() {
        let mut b = AnalyticalImc;
        let c = ChipletTypeParams::imc_type_a();
        for kind in crate::workload::ALL_CNNS {
            let m = NeuralModel::build(kind);
            for l in &m.layers {
                let r = b.evaluate(&c, &SegmentWork::from_layer(l, 1.0));
                assert!(r.latency_ns > 0.0 && r.energy_pj > 0.0, "{}", l.name);
            }
        }
    }
}
