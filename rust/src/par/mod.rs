//! Conservative parallel discrete-event core for the flit-level NoI.
//!
//! Domain-decomposes the interposer mesh into `K` contiguous node
//! stripes ("regions") and advances each region on the shared
//! [`WorkerPool`](crate::util::pool::WorkerPool) workers in lock-step
//! **synchronization windows** of at most `E` cycles, where the
//! lookahead `E` is bounded by the minimum inter-region link latency
//! (`Topology::hop_latency_cycles`): a flit sent across a region
//! boundary during a window cannot arrive — and therefore cannot be
//! observed by the neighbour — before the window ends, so regions may
//! step the window's cycles concurrently without speculation or
//! rollback.  Boundary flits, credits, energy, traces, and completions
//! are exchanged/merged by the coordinator between windows in the
//! sequential engine's exact `(cycle, link)` order, which makes the
//! parallel engine **byte-identical** to [`FlitEngine`]: same completion
//! sequences, same `FlowStats`, bit-equal `f64` energy totals, same
//! link-busy accounting, same traces — asserted by the differential
//! harness in `par::engine`'s tests and by
//! `rust/tests/parallel_determinism.rs` across `--threads 1/2/8`,
//! including with a PR 9 fault plan armed.
//!
//! Select it per run with [`ExecSpec`] on the
//! [`SimulationBuilder`](crate::sim::SimulationBuilder) (or
//! `--threads N` on any CLI subcommand).  Packet fidelity keeps the
//! single sequential event heap — it is thread-count-invariant by
//! construction, and `ExecSpec` simply leaves it untouched.
//!
//! [`FlitEngine`]: crate::noc::flit::FlitEngine

mod engine;

pub use engine::ShardedFlitEngine;

/// How the NoI node set is split into regions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Partitioner {
    /// One contiguous node stripe per worker thread (row-major node
    /// order, so mesh stripes are bands of whole rows and boundary
    /// links exist only between adjacent stripes).
    #[default]
    Auto,
    /// Exactly `k` contiguous stripes regardless of thread count
    /// (clamped to the node count).  Useful to decouple decomposition
    /// granularity from the pool size in tests and sweeps.
    Stripes(usize),
}

/// Execution specification: how a single simulation run is executed,
/// orthogonal to *what* is simulated.  Defaults reproduce the
/// sequential engines exactly (`threads == 1`).
///
/// ```
/// use chipsim::par::ExecSpec;
/// let exec = ExecSpec::threads(8);
/// assert_eq!(exec.threads, 8);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecSpec {
    /// Worker threads for one run: `1` = sequential engines (the
    /// default), `0` = available parallelism, `N > 1` = the sharded
    /// flit engine on an `N`-worker pool.
    pub threads: usize,
    /// Region decomposition policy.
    pub partitioner: Partitioner,
    /// Synchronization-window length in cycles.  `None` (default) uses
    /// the maximum safe lookahead — the inter-region hop latency.
    /// Values are clamped to `1..=hop_latency_cycles`; a larger value
    /// would let a boundary flit arrive mid-window (unsound), so it is
    /// never honoured.
    pub lookahead: Option<u64>,
}

impl Default for ExecSpec {
    fn default() -> Self {
        ExecSpec { threads: 1, partitioner: Partitioner::Auto, lookahead: None }
    }
}

impl ExecSpec {
    /// Sequential execution (the default; identical to not setting an
    /// `ExecSpec` at all).
    pub fn sequential() -> Self {
        ExecSpec::default()
    }

    /// Parallel execution on `threads` workers (`0` = available
    /// parallelism) with the default partitioner and lookahead.
    pub fn threads(threads: usize) -> Self {
        ExecSpec { threads, ..ExecSpec::default() }
    }

    /// Override the partitioner.
    pub fn with_partitioner(mut self, p: Partitioner) -> Self {
        self.partitioner = p;
        self
    }

    /// Override the lookahead (clamped to the safe range at run time).
    pub fn with_lookahead(mut self, cycles: u64) -> Self {
        self.lookahead = Some(cycles);
        self
    }

    /// Does this spec ask for the parallel engine at all?
    pub fn is_parallel(&self) -> bool {
        self.threads != 1
    }
}
