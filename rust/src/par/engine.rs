//! The sharded wormhole flit engine: K regions, conservative windows.
//!
//! # How byte-identity is preserved
//!
//! The sequential [`FlitEngine`](crate::noc::flit::FlitEngine)'s
//! observable semantics (proven by PR 4's differential harness) are a
//! dense per-cycle scan: deliver in-flight flits in `(arrival, link)`
//! order, then allocate/traverse output links in ascending link index.
//! Within one cycle the only *cross-region* observables are:
//!
//! 1. **Arrivals** over a boundary link — but a flit sent during a
//!    window of `E <= hop_latency` cycles arrives strictly after the
//!    window, so window-local stepping never misses one.
//! 2. **Credits** of a boundary link's input port (owned by the
//!    downstream region, decremented by the upstream sender's
//!    traversals, incremented by the downstream router's pops).  The
//!    coordinator snapshots each boundary port's credits at the window
//!    start and *caps the window length to the smallest snapshot among
//!    links that could send*: the upstream gate (`will_eject || credits
//!    > 0`) then sees `snapshot - k >= 1` before its `k+1`-th send
//!    (`k < window <= snapshot`) while the sequential engine sees a
//!    value at least as large (pops only add) — both gates pass, so
//!    every traversal decision is identical, and reconciling the real
//!    counters at the merge can never underflow.  When a live boundary
//!    port has fewer credits than even a one-cycle window needs, the
//!    coordinator steps that cycle itself with a dense cross-region
//!    scan (`step_cycle_dense`) — sequential semantics by construction.
//!
//! Everything else (energy `f64` accumulation order, trace coalescing,
//! completion order, RR pointers) is region-local or replayed by the
//! coordinator from the merged `(cycle, link)` traversal stream, which
//! equals the sequential processing order because at most one flit
//! crosses a given link per cycle.
//!
//! Windows additionally never overshoot a flow completion: all in-window
//! ejections come from flits already in flight at the window start, so
//! the coordinator pre-scans the heaps for the earliest tail that
//! finishes a flow and caps the window there.  `advance_until` therefore
//! returns with the clock parked on the completion cycle, exactly like
//! the sequential engine — the outer `Simulation` may inject dependent
//! flows at that instant and both engines see the same network.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap, VecDeque};
use std::sync::Mutex;

use crate::noc::flit::{
    Flit, FlowProgress, InPort, InputRef, LinkTraceLog, BUF_FLITS, PACKET_FLITS,
};
use crate::noc::topology::Topology;
use crate::noc::{
    EnergyLog, FlowCompletion, FlowId, FlowSpec, FlowStats, LinkTraceEvent, NetworkSim,
};
use crate::util::pool::WorkerPool;
use crate::TimeNs;

use super::{ExecSpec, Partitioner};

/// A flit in flight toward a region, min-ordered by `(arrival, link)` —
/// the sequential delivery order.  Constant hop latency means at most
/// one flit per `(cycle, link)`, so the pair is a total order and the
/// carried flit never participates in comparisons.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    arr: u64,
    link: usize,
    flit: Flit,
}

impl PartialEq for InFlight {
    fn eq(&self, other: &Self) -> bool {
        (self.arr, self.link) == (other.arr, other.link)
    }
}
impl Eq for InFlight {}
impl PartialOrd for InFlight {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for InFlight {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arr, self.link).cmp(&(other.arr, other.link))
    }
}

/// One domain-decomposed stripe of the NoI, with all the router state
/// the sequential engine keeps for its nodes.  Per-link vectors are
/// global-length (indexed by global link id) for simplicity; a region
/// only ever touches the indices it owns: input ports of links whose
/// *destination* it owns, output bindings / RR pointers / busy counters
/// of links whose *source* it owns.
struct Region {
    /// Owned nodes: `lo..hi` (contiguous, row-major).
    lo: usize,
    hi: usize,
    /// Input port of link `l` (used iff `dst(l)` is owned).
    ports: Vec<InPort>,
    /// Output binding of link `l` (used iff `src(l)` is owned).
    bound: Vec<Option<(InputRef, FlowId, u64)>>,
    rr: Vec<usize>,
    link_busy_cycles: Vec<u64>,
    /// Owned output links in ascending global index (the sequential
    /// scan order restricted to this region).
    own_out_links: Vec<usize>,
    /// `own_out_links[i]` crosses into another region.
    is_boundary_out: Vec<bool>,
    /// Candidate input list per owned node (in-links ascending, then
    /// the local injection queue) — the sequential allocation order.
    inputs: Vec<Vec<InputRef>>,
    inject_q: Vec<VecDeque<Flit>>,
    /// Flits in flight toward owned nodes.
    in_flight: BinaryHeap<Reverse<InFlight>>,
    /// Flits buffered in owned ports + injection queues.
    occupancy: u64,
    /// Boundary-link credit mirror for the current window: snapshot of
    /// the downstream port's credits at window start, decremented by
    /// own sends (reconciled against the real counter at the merge).
    ext_credit: Vec<usize>,
    // ---- per-window outputs, drained by the coordinator ----
    /// Traversals `(cycle, link, flow)`, sorted by construction.
    travs: Vec<(u64, usize, FlowId)>,
    /// Tail-flit ejections `(cycle, link, flow)`, sorted by construction.
    tails: Vec<(u64, usize, FlowId)>,
    /// Sends over boundary links, to be routed to the owner's heap.
    boundary_out: Vec<InFlight>,
    /// Did anything move (delivery or traversal) this window?
    moved: bool,
}

impl Region {
    fn owns(&self, node: usize) -> bool {
        (self.lo..self.hi).contains(&node)
    }

    fn front(&self, input: InputRef) -> Option<&Flit> {
        match input {
            InputRef::Link(l) => self.ports[l].buf.front(),
            InputRef::Local(n) => self.inject_q[n].front(),
        }
    }

    fn pop(&mut self, input: InputRef) -> Flit {
        self.occupancy -= 1;
        match input {
            InputRef::Link(l) => {
                let f = self.ports[l].buf.pop_front().unwrap();
                self.ports[l].credits += 1;
                f
            }
            InputRef::Local(n) => self.inject_q[n].pop_front().unwrap(),
        }
    }

    /// Advance this region through cycles `s+1 ..= w` with no outside
    /// interaction: deliveries from the own heap, then a dense
    /// ascending scan over owned output links — the sequential
    /// semantics restricted to the region.  Runs on a pool worker.
    fn step_window(&mut self, topo: &Topology, s: u64, w: u64) {
        let _prof = crate::prof::scope(crate::prof::Subsystem::RegionAdvance);
        self.travs.clear();
        self.tails.clear();
        self.boundary_out.clear();
        self.moved = false;
        let hop = topo.hop_latency_cycles.max(1);
        for c in s + 1..=w {
            // 1. Deliveries due this cycle, in (arrival, link) order.
            while let Some(&Reverse(e)) = self.in_flight.peek() {
                if e.arr > c {
                    break;
                }
                let e = self.in_flight.pop().unwrap().0;
                let node = topo.links[e.link].dst;
                debug_assert!(self.owns(node));
                if e.flit.dst == node {
                    // Ejection: leaves the network; return the credit.
                    self.ports[e.link].credits += 1;
                    if e.flit.is_tail {
                        self.tails.push((c, e.link, e.flit.flow));
                    }
                } else {
                    self.ports[e.link].buf.push_back(e.flit);
                    self.occupancy += 1;
                }
                self.moved = true;
            }
            if self.occupancy == 0 {
                // No buffered flit anywhere in the region: allocation
                // scans empty fronts and traversal has no front — a
                // provable no-op, as in the sequential active set.
                continue;
            }
            // 2. Switch allocation + traversal, ascending link index.
            #[allow(clippy::needless_range_loop)] // parallel is_boundary_out lookup
            for i in 0..self.own_out_links.len() {
                let link = self.own_out_links[i];
                if self.bound[link].is_none() {
                    let node = topo.links[link].src;
                    let ninputs = self.inputs[node].len();
                    let start = self.rr[link] % ninputs;
                    for k in 0..ninputs {
                        let input = self.inputs[node][(start + k) % ninputs];
                        if let Some(f) = self.front(input) {
                            if f.is_head && route_out(topo, node, f.dst) == Some(link) {
                                self.bound[link] = Some((input, f.flow, f.pkt));
                                self.rr[link] = (start + k + 1) % ninputs;
                                break;
                            }
                        }
                    }
                }
                if let Some((input, flow, pkt)) = self.bound[link] {
                    let ready =
                        matches!(self.front(input), Some(f) if f.flow == flow && f.pkt == pkt);
                    if !ready {
                        continue;
                    }
                    let downstream = topo.links[link].dst;
                    let f = *self.front(input).unwrap();
                    let will_eject = f.dst == downstream;
                    let have_credit = if self.is_boundary_out[i] {
                        self.ext_credit[link] > 0
                    } else {
                        self.ports[link].credits > 0
                    };
                    if will_eject || have_credit {
                        let f = self.pop(input);
                        if !will_eject {
                            if self.is_boundary_out[i] {
                                self.ext_credit[link] -= 1;
                            } else {
                                self.ports[link].credits -= 1;
                            }
                        }
                        let e = InFlight { arr: c + hop, link, flit: f };
                        if self.is_boundary_out[i] {
                            self.boundary_out.push(e);
                        } else {
                            self.in_flight.push(Reverse(e));
                        }
                        self.travs.push((c, link, f.flow));
                        self.link_busy_cycles[link] += 1;
                        if f.is_tail {
                            self.bound[link] = None;
                        }
                        self.moved = true;
                    }
                }
            }
        }
    }
}

/// The output link a flit wants at router `node` (shared routing rule).
fn route_out(topo: &Topology, node: usize, dst: usize) -> Option<usize> {
    if node == dst {
        None
    } else {
        let l = topo.route[node][dst];
        debug_assert_ne!(l, usize::MAX, "stranded flit survived apply_fault: {node} -> {dst}");
        Some(l)
    }
}

/// The parallel sharded wormhole engine.  Byte-identical to
/// [`FlitEngine`](crate::noc::flit::FlitEngine) for any thread count,
/// partitioning, and lookahead (see the module docs for the argument).
pub struct ShardedFlitEngine {
    topo: Topology,
    regions: Vec<Mutex<Region>>,
    /// Node -> owning region.
    region_of: Vec<usize>,
    /// Links whose src and dst regions differ (ascending).
    boundary_links: Vec<usize>,
    pool: WorkerPool,
    /// Maximum synchronization-window length in cycles (`<= hop
    /// latency`, the conservative lookahead bound).
    lookahead: u64,
    // ---- coordinator-owned flow/report state (mirrors FlitEngine) ----
    flows: Vec<Option<FlowProgress>>,
    active_flows: usize,
    finished: HashMap<FlowId, FlowStats>,
    completions: VecDeque<(TimeNs, FlowId)>,
    next_flow_id: FlowId,
    cycle: u64,
    energy: EnergyLog,
    work: u64,
    link_trace: Option<LinkTraceLog>,
    /// Merge scratch, reused across windows.
    merge_travs: Vec<(u64, usize, FlowId)>,
    merge_tails: Vec<(u64, usize, FlowId)>,
}

impl ShardedFlitEngine {
    pub fn new(topo: Topology, exec: ExecSpec) -> Self {
        Self::with_buffer_depth(topo, exec, BUF_FLITS)
    }

    /// Construct with an explicit per-port buffer depth (flits); the
    /// differential tests sweep this exactly like the sequential
    /// harness does.
    pub fn with_buffer_depth(topo: Topology, exec: ExecSpec, buf_flits: usize) -> Self {
        for l in &topo.links {
            assert_eq!(l.clock_div, 1, "flit engine requires homogeneous clocks");
        }
        let depth = buf_flits.max(1);
        let nnodes = topo.num_nodes;
        let nlinks = topo.links.len();
        let pool = WorkerPool::new(exec.threads);
        let k = match exec.partitioner {
            Partitioner::Auto => pool.threads(),
            Partitioner::Stripes(k) => k,
        }
        .clamp(1, nnodes.max(1));
        let hop = topo.hop_latency_cycles.max(1);
        let lookahead = exec.lookahead.unwrap_or(hop).clamp(1, hop);
        // Contiguous row-major stripes: node n belongs to the region
        // whose [lo, hi) range contains it.
        let bounds: Vec<(usize, usize)> =
            (0..k).map(|r| (r * nnodes / k, (r + 1) * nnodes / k)).collect();
        let mut region_of = vec![0usize; nnodes];
        for (r, &(lo, hi)) in bounds.iter().enumerate() {
            for slot in region_of.iter_mut().take(hi).skip(lo) {
                *slot = r;
            }
        }
        let boundary_links: Vec<usize> = (0..nlinks)
            .filter(|&l| region_of[topo.links[l].src] != region_of[topo.links[l].dst])
            .collect();
        let regions: Vec<Mutex<Region>> = bounds
            .iter()
            .map(|&(lo, hi)| {
                let own_out_links: Vec<usize> = (0..nlinks)
                    .filter(|&l| (lo..hi).contains(&topo.links[l].src))
                    .collect();
                let is_boundary_out: Vec<bool> = own_out_links
                    .iter()
                    .map(|&l| !(lo..hi).contains(&topo.links[l].dst))
                    .collect();
                let inputs: Vec<Vec<InputRef>> = (0..nnodes)
                    .map(|n| {
                        if (lo..hi).contains(&n) {
                            let mut v: Vec<InputRef> =
                                topo.in_links[n].iter().map(|&l| InputRef::Link(l)).collect();
                            v.push(InputRef::Local(n));
                            v
                        } else {
                            Vec::new()
                        }
                    })
                    .collect();
                Mutex::new(Region {
                    lo,
                    hi,
                    ports: (0..nlinks).map(|_| InPort::new(depth)).collect(),
                    bound: vec![None; nlinks],
                    rr: vec![0; nlinks],
                    link_busy_cycles: vec![0; nlinks],
                    own_out_links,
                    is_boundary_out,
                    inputs,
                    inject_q: vec![VecDeque::new(); nnodes],
                    in_flight: BinaryHeap::new(),
                    occupancy: 0,
                    ext_credit: vec![0; nlinks],
                    travs: Vec::new(),
                    tails: Vec::new(),
                    boundary_out: Vec::new(),
                    moved: false,
                })
            })
            .collect();
        ShardedFlitEngine {
            regions,
            region_of,
            boundary_links,
            pool,
            lookahead,
            flows: Vec::new(),
            active_flows: 0,
            finished: HashMap::new(),
            completions: VecDeque::new(),
            next_flow_id: 0,
            cycle: 0,
            energy: EnergyLog::new(nnodes),
            work: 0,
            link_trace: None,
            merge_travs: Vec::new(),
            merge_tails: Vec::new(),
            topo,
        }
    }

    /// Number of regions the mesh was decomposed into.
    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }

    fn ns(&self, cycle: u64) -> TimeNs {
        (cycle as f64 * self.topo.cycle_ns).round() as TimeNs
    }

    /// Smallest cycle whose [`ns`](Self::ns) stamp is `>= t` (same
    /// rounding-anchored search as the sequential engines).
    fn cycle_of(&self, t: TimeNs) -> u64 {
        let mut c = (t as f64 / self.topo.cycle_ns).ceil() as u64;
        while c > 0 && self.ns(c - 1) >= t {
            c -= 1;
        }
        while c < u64::MAX && self.ns(c) < t {
            c += 1;
        }
        c
    }

    fn network_busy(&mut self) -> bool {
        self.regions.iter_mut().any(|r| {
            let g = r.get_mut().expect("region lock");
            g.occupancy > 0 || !g.in_flight.is_empty()
        })
    }

    /// Earliest in-flight arrival cycle anywhere, if any.
    fn next_arrival(&mut self) -> Option<u64> {
        self.regions
            .iter_mut()
            .filter_map(|r| {
                r.get_mut().expect("region lock").in_flight.peek().map(|Reverse(e)| e.arr)
            })
            .min()
    }

    /// Production cycle-skip: nothing moved, so the switch state is
    /// frozen until the next in-flight arrival — jump over the gap
    /// (bounded by where the per-cycle loop would rest for this `t`).
    /// With nothing in flight at all the network is hard-blocked until
    /// new injections: consume the horizon.
    fn skip_frozen(&mut self, c_lim: u64) {
        match self.next_arrival() {
            Some(arr) if arr > self.cycle + 1 => self.cycle = (arr - 1).min(c_lim),
            Some(_) => {}
            None => self.cycle = c_lim,
        }
    }

    /// Decrement a flow's outstanding-tails count; on the last tail,
    /// finish the flow (identical to the sequential `finish_packet`).
    fn finish_tail(&mut self, flow: FlowId, now_ns: TimeNs) {
        let slot = &mut self.flows[flow as usize];
        let fp = slot.as_mut().expect("tail for unknown flow");
        fp.tails_left -= 1;
        if fp.tails_left == 0 {
            let fp = slot.take().unwrap();
            self.active_flows -= 1;
            let stats = FlowStats {
                spec: fp.spec,
                injected_ns: fp.injected_ns,
                completed_ns: now_ns,
                hops: fp.hops,
            };
            self.finished.insert(flow, stats);
            self.completions.push_back((now_ns, flow));
        }
    }

    /// Replay one traversal on the coordinator: energy (bit-exact f64
    /// accumulation order), work, trace, in the merged global order.
    fn commit_traversal(&mut self, cycle: u64, link: usize, flow: FlowId) {
        let now_ns = self.ns(cycle);
        let l = &self.topo.links[link];
        let pj = l.width_bytes as f64 * l.e_per_byte_pj;
        self.energy.push(l.src, now_ns, pj);
        self.work += l.width_bytes;
        if let Some(log) = &mut self.link_trace {
            log.on_traverse(link, flow, cycle, self.topo.cycle_ns);
        }
    }

    /// Run one synchronization window toward `t`.  On return the clock
    /// has advanced (or the horizon was consumed when hard-blocked).
    fn run_window(&mut self, t: TimeNs) {
        let c_lim = self.cycle_of(t);
        let s = self.cycle;
        debug_assert!(s < c_lim, "run_window called at/after the horizon");
        let len_raw = self.lookahead.min(c_lim - s);
        let mut len = len_raw;

        // --- coordinator: boundary credit snapshots + window sizing ---
        {
            let _sb = crate::prof::scope(crate::prof::Subsystem::SyncBarrier);
            for &l in &self.boundary_links {
                let (src, dst) = (self.topo.links[l].src, self.topo.links[l].dst);
                let credits = {
                    let owner = self.regions[self.region_of[dst]].get_mut().expect("region lock");
                    owner.ports[l].credits
                };
                let sender = self.regions[self.region_of[src]].get_mut().expect("region lock");
                sender.ext_credit[l] = credits;
                // Only a region that holds (or will receive) flits can
                // send this window; an idle sender never consults the
                // gate, so its starved downstream port must not stall
                // everyone else.
                let could_send = sender.occupancy > 0
                    || matches!(sender.in_flight.peek(), Some(&Reverse(e)) if e.arr <= s + len_raw);
                if could_send {
                    len = len.min(credits as u64);
                }
            }
            if len > 0 {
                // Completion pre-scan: every in-window ejection is
                // already in some heap (in-window sends arrive after the
                // window), so the earliest flow-finishing tail is known
                // now.  Cap the window there so the clock parks on the
                // completion cycle exactly like the sequential engine.
                let mut tails: Vec<(FlowId, u64, usize)> = Vec::new();
                for r in self.regions.iter_mut() {
                    let g = r.get_mut().expect("region lock");
                    for &Reverse(e) in g.in_flight.iter() {
                        if e.arr <= s + len
                            && e.flit.is_tail
                            && e.flit.dst == self.topo.links[e.link].dst
                        {
                            tails.push((e.flit.flow, e.arr, e.link));
                        }
                    }
                }
                tails.sort_unstable();
                let mut i = 0;
                while i < tails.len() {
                    let flow = tails[i].0;
                    let mut j = i;
                    while j < tails.len() && tails[j].0 == flow {
                        j += 1;
                    }
                    let left = self.flows[flow as usize]
                        .as_ref()
                        .expect("in-flight tail for unknown flow")
                        .tails_left as usize;
                    if j - i >= left {
                        // The flow's last tail ejects at this cycle.
                        len = len.min(tails[i + left - 1].1 - s);
                    }
                    i = j;
                }
            }
        }

        if len == 0 {
            // A live boundary port has no credit to guarantee even a
            // one-cycle window: the upstream gate outcome depends on
            // same-cycle pops downstream, so step this cycle with the
            // dense cross-region scan (sequential semantics).
            if !self.step_cycle_dense() {
                self.skip_frozen(c_lim);
            }
            return;
        }
        let w = s + len;

        // --- parallel: each region steps the window on a pool worker ---
        {
            let regions = &self.regions;
            let topo = &self.topo;
            let results = self.pool.map_catching(regions.len(), |r| {
                let mut g = regions[r].lock().expect("region lock");
                g.step_window(topo, s, w);
            });
            for res in results {
                if let Err(msg) = res {
                    panic!("region worker panicked: {msg}");
                }
            }
        }

        // --- coordinator: merge in the sequential (cycle, link) order ---
        let _sb = crate::prof::scope(crate::prof::Subsystem::SyncBarrier);
        let mut moved_any = false;
        let mut travs = std::mem::take(&mut self.merge_travs);
        let mut tails = std::mem::take(&mut self.merge_tails);
        travs.clear();
        tails.clear();
        #[allow(clippy::needless_range_loop)] // two indices borrow self.regions
        for i in 0..self.regions.len() {
            let g = self.regions[i].get_mut().expect("region lock");
            moved_any |= g.moved;
            travs.extend(g.travs.drain(..));
            tails.extend(g.tails.drain(..));
            let outs: Vec<InFlight> = g.boundary_out.drain(..).collect();
            for e in outs {
                let owner = self.region_of[self.topo.links[e.link].dst];
                let og = self.regions[owner].get_mut().expect("region lock");
                if e.flit.dst != self.topo.links[e.link].dst {
                    // Reconcile the sender's mirrored credit decrement
                    // against the real downstream counter (an ejecting
                    // flit reserved no slot).
                    og.ports[e.link].credits -= 1;
                }
                og.in_flight.push(Reverse(e));
            }
        }
        // At most one flit per (cycle, link): sorting reproduces the
        // dense scan's global processing order.
        travs.sort_unstable();
        tails.sort_unstable();
        crate::prof::count(crate::prof::Counter::FlitHops, travs.len() as u64);
        for &(cycle, link, flow) in &travs {
            self.commit_traversal(cycle, link, flow);
        }
        for &(cycle, _link, flow) in &tails {
            let now_ns = self.ns(cycle);
            self.finish_tail(flow, now_ns);
        }
        self.merge_travs = travs;
        self.merge_tails = tails;
        self.cycle = w;
        if !moved_any {
            self.skip_frozen(c_lim);
        }
    }

    /// One cycle of the dense cross-region scan — the literal
    /// sequential semantics over the partitioned storage, used when a
    /// starved boundary port makes even a one-cycle window unsound.
    /// Returns true if any flit moved.
    fn step_cycle_dense(&mut self) -> bool {
        let mut moved = false;
        self.cycle += 1;
        let c = self.cycle;
        let now_ns = self.ns(c);
        let hop = self.topo.hop_latency_cycles.max(1);

        // 1. Deliveries due this cycle in global (arrival, link) order:
        // a K-way min-merge over the region heaps.
        loop {
            let mut best: Option<(u64, usize, usize)> = None; // (arr, link, region)
            for (ri, r) in self.regions.iter_mut().enumerate() {
                let g = r.get_mut().expect("region lock");
                if let Some(&Reverse(e)) = g.in_flight.peek() {
                    let better = match best {
                        None => true,
                        Some((a, l, _)) => (e.arr, e.link) < (a, l),
                    };
                    if e.arr <= c && better {
                        best = Some((e.arr, e.link, ri));
                    }
                }
            }
            let Some((_, _, ri)) = best else { break };
            let e = {
                let g = self.regions[ri].get_mut().expect("region lock");
                g.in_flight.pop().unwrap().0
            };
            let node = self.topo.links[e.link].dst;
            if e.flit.dst == node {
                self.regions[ri].get_mut().expect("region lock").ports[e.link].credits += 1;
                if e.flit.is_tail {
                    self.finish_tail(e.flit.flow, now_ns);
                }
            } else {
                let g = self.regions[ri].get_mut().expect("region lock");
                g.ports[e.link].buf.push_back(e.flit);
                g.occupancy += 1;
            }
            moved = true;
        }

        // 2. Allocation + traversal over every link, ascending — state
        // for link `l` lives in region(src) except the input port,
        // which lives in region(dst).
        for link in 0..self.topo.links.len() {
            let (src, dst) = (self.topo.links[link].src, self.topo.links[link].dst);
            let (rs, rd) = (self.region_of[src], self.region_of[dst]);
            if self.regions[rs].get_mut().expect("region lock").occupancy == 0 {
                // No buffered flit in the source region: provable no-op.
                continue;
            }
            {
                let g = self.regions[rs].get_mut().expect("region lock");
                if g.bound[link].is_none() {
                    let ninputs = g.inputs[src].len();
                    let start = g.rr[link] % ninputs;
                    for k in 0..ninputs {
                        let input = g.inputs[src][(start + k) % ninputs];
                        if let Some(f) = g.front(input) {
                            if f.is_head && route_out(&self.topo, src, f.dst) == Some(link) {
                                g.bound[link] = Some((input, f.flow, f.pkt));
                                g.rr[link] = (start + k + 1) % ninputs;
                                break;
                            }
                        }
                    }
                }
            }
            let Some((input, flow, pkt)) =
                self.regions[rs].get_mut().expect("region lock").bound[link]
            else {
                continue;
            };
            let f = {
                let g = self.regions[rs].get_mut().expect("region lock");
                match g.front(input) {
                    Some(f) if f.flow == flow && f.pkt == pkt => *f,
                    _ => continue,
                }
            };
            let will_eject = f.dst == dst;
            let have_credit =
                self.regions[rd].get_mut().expect("region lock").ports[link].credits > 0;
            if !(will_eject || have_credit) {
                continue;
            }
            let f = self.regions[rs].get_mut().expect("region lock").pop(input);
            if !will_eject {
                self.regions[rd].get_mut().expect("region lock").ports[link].credits -= 1;
            }
            self.regions[rd]
                .get_mut()
                .expect("region lock")
                .in_flight
                .push(Reverse(InFlight { arr: c + hop, link, flit: f }));
            self.commit_traversal(c, link, f.flow);
            crate::prof::count(crate::prof::Counter::FlitHops, 1);
            self.regions[rs].get_mut().expect("region lock").link_busy_cycles[link] += 1;
            if f.is_tail {
                self.regions[rs].get_mut().expect("region lock").bound[link] = None;
            }
            moved = true;
        }
        moved
    }
}

impl NetworkSim for ShardedFlitEngine {
    fn inject(&mut self, spec: FlowSpec, now: TimeNs) -> FlowId {
        let id = self.next_flow_id;
        self.next_flow_id += 1;
        debug_assert_eq!(self.flows.len(), id as usize);
        // Catch the engine's clock up to the injection time without
        // simulating idle cycles one by one (sequential fast-forward).
        let inj_cycle = self.cycle_of(now);
        if inj_cycle > self.cycle && !self.network_busy() {
            self.cycle = inj_cycle;
        }
        let path = self
            .topo
            .path(spec.src, spec.dst)
            .expect("inject: unreachable destination (check Topology::reachable first)");
        if path.is_empty() {
            let stats = FlowStats { spec, injected_ns: now, completed_ns: now, hops: 0 };
            self.flows.push(None);
            self.finished.insert(id, stats);
            self.completions.push_back((now, id));
            return id;
        }
        let width = self.topo.links[path[0]].width_bytes;
        let payload_flits = spec.bytes.max(1).div_ceil(width);
        let npackets = payload_flits.div_ceil(PACKET_FLITS);
        self.flows.push(Some(FlowProgress {
            spec,
            injected_ns: now,
            hops: path.len() as u32,
            tails_left: npackets,
        }));
        self.active_flows += 1;
        let g = self.regions[self.region_of[spec.src]].get_mut().expect("region lock");
        g.occupancy += payload_flits;
        let mut remaining = payload_flits;
        for pkt in 0..npackets {
            let in_this = remaining.min(PACKET_FLITS);
            remaining -= in_this;
            for k in 0..in_this {
                g.inject_q[spec.src].push_back(Flit {
                    flow: id,
                    pkt,
                    is_head: k == 0,
                    is_tail: k == in_this - 1,
                    dst: spec.dst,
                });
            }
        }
        id
    }

    fn advance_until(&mut self, t: TimeNs) -> Option<FlowCompletion> {
        let _prof = crate::prof::scope(crate::prof::Subsystem::FlitEngine);
        loop {
            if let Some(&(ct, _)) = self.completions.front() {
                if ct <= t {
                    let (time, id) = self.completions.pop_front().unwrap();
                    return Some(FlowCompletion { id, time });
                }
                return None;
            }
            if !self.network_busy() || self.ns(self.cycle) >= t || self.cycle == u64::MAX {
                return None;
            }
            self.run_window(t);
        }
    }

    fn has_active(&self) -> bool {
        self.active_flows > 0 || !self.completions.is_empty()
    }

    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id).copied()
    }

    fn comm_energy_pj(&self) -> f64 {
        self.energy.total_pj()
    }

    fn drain_energy_events(&mut self) -> Vec<(usize, TimeNs, f64)> {
        self.energy.drain()
    }

    fn set_energy_bin_ns(&mut self, bin_ns: TimeNs) {
        self.energy.set_bin_ns(bin_ns);
    }

    fn work_done(&self) -> u64 {
        self.work
    }

    fn link_busy_ns(&self) -> Vec<TimeNs> {
        // Each link's busy counter is owned by exactly one region (the
        // source's); summing across regions reassembles the global view.
        let mut cycles = vec![0u64; self.topo.links.len()];
        for r in &self.regions {
            let g = r.lock().expect("region lock");
            for (i, &c) in g.link_busy_cycles.iter().enumerate() {
                cycles[i] += c;
            }
        }
        cycles.iter().map(|&c| (c as f64 * self.topo.cycle_ns).round() as TimeNs).collect()
    }

    fn set_link_trace(&mut self, enabled: bool) {
        self.link_trace =
            if enabled { Some(LinkTraceLog::new(self.topo.links.len())) } else { None };
    }

    fn drain_link_trace(&mut self) -> Vec<LinkTraceEvent> {
        match &mut self.link_trace {
            Some(log) => log.drain(self.topo.cycle_ns),
            None => Vec::new(),
        }
    }

    /// Mirrors the sequential engine's fault handling over the
    /// partitioned storage: adopt the rerouted tables, collect every
    /// flow with a flit on a dead link or stranded by the new routes,
    /// purge their flits (restoring held credits), and report them.
    fn apply_fault(&mut self, topo: &Topology, link_down: &[bool]) -> Vec<(FlowId, FlowSpec)> {
        debug_assert_eq!(topo.links.len(), self.topo.links.len(), "same link universe");
        self.topo.route = topo.route.clone();
        self.topo.hop_table = topo.hop_table.clone();

        let topo = &self.topo;
        let route = &topo.route;
        let stranded = |node: usize, dst: usize| node != dst && route[node][dst] == usize::MAX;
        let mut affected: BTreeSet<FlowId> = BTreeSet::new();
        for r in self.regions.iter_mut() {
            let g = r.get_mut().expect("region lock");
            for (l, port) in g.ports.iter().enumerate() {
                for f in &port.buf {
                    if link_down[l] || stranded(topo.links[l].dst, f.dst) {
                        affected.insert(f.flow);
                    }
                }
            }
            for (n, q) in g.inject_q.iter().enumerate() {
                for f in q {
                    if stranded(n, f.dst) {
                        affected.insert(f.flow);
                    }
                }
            }
            for &Reverse(e) in g.in_flight.iter() {
                if link_down[e.link] || stranded(topo.links[e.link].dst, e.flit.dst) {
                    affected.insert(e.flit.flow);
                }
            }
            for (l, b) in g.bound.iter().enumerate() {
                if link_down[l] {
                    if let Some((_, flow, _)) = b {
                        affected.insert(*flow);
                    }
                }
            }
        }
        if affected.is_empty() {
            return Vec::new();
        }

        // Purge every flit of every affected flow, restoring the
        // credits they hold: a buffered flit returns its own port slot;
        // an in-flight flit returns the downstream slot reserved at
        // send time (none was reserved for a flit about to eject).
        // Each restoration is region-local: a heap entry's input port
        // belongs to the same (destination) region.
        for r in self.regions.iter_mut() {
            let g = r.get_mut().expect("region lock");
            let mut removed_total = 0u64;
            for port in g.ports.iter_mut() {
                let before = port.buf.len();
                port.buf.retain(|f| !affected.contains(&f.flow));
                let removed = before - port.buf.len();
                port.credits += removed;
                removed_total += removed as u64;
            }
            for q in g.inject_q.iter_mut() {
                let before = q.len();
                q.retain(|f| !affected.contains(&f.flow));
                removed_total += (before - q.len()) as u64;
            }
            g.occupancy -= removed_total;
            let entries: Vec<InFlight> =
                std::mem::take(&mut g.in_flight).into_iter().map(|Reverse(e)| e).collect();
            for e in entries {
                if affected.contains(&e.flit.flow) {
                    if e.flit.dst != topo.links[e.link].dst {
                        g.ports[e.link].credits += 1;
                    }
                } else {
                    g.in_flight.push(Reverse(e));
                }
            }
            for b in g.bound.iter_mut() {
                if matches!(b, Some((_, flow, _)) if affected.contains(flow)) {
                    *b = None;
                }
            }
        }
        let mut dropped = Vec::new();
        for id in affected {
            let fp = self.flows[id as usize].take().expect("affected flow exists");
            self.active_flows -= 1;
            dropped.push((id, fp.spec));
        }
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LinkParams;
    use crate::noc::flit::FlitEngine;
    use crate::noc::topology::mesh;
    use crate::util::rng::Rng;

    /// A pre-generated drive schedule, replayed identically on both
    /// engines (PR 4's differential-harness pattern).
    #[derive(Debug, Clone)]
    enum Op {
        Inject(FlowSpec, TimeNs),
        Advance(TimeNs),
    }

    fn run_script(e: &mut dyn NetworkSim, script: &[Op]) -> Vec<(FlowId, TimeNs)> {
        let mut out = Vec::new();
        for op in script {
            match *op {
                Op::Inject(spec, at) => {
                    e.inject(spec, at);
                }
                Op::Advance(t) => {
                    while let Some(c) = e.advance_until(t) {
                        out.push((c.id, c.time));
                    }
                }
            }
        }
        while let Some(c) = e.advance_until(TimeNs::MAX) {
            out.push((c.id, c.time));
        }
        out
    }

    fn random_script(rng: &mut Rng, nodes: usize, nflows: usize) -> Vec<Op> {
        let mut script = Vec::new();
        let mut t = 0u64;
        for _ in 0..nflows {
            t += rng.below(30_000);
            let src = rng.below_usize(nodes);
            // dst may equal src (empty-path flows complete instantly).
            let dst = rng.below_usize(nodes);
            let bytes = 1 + rng.below(16_384);
            script.push(Op::Inject(FlowSpec { src, dst, bytes }, t));
            if rng.below(3) == 0 {
                script.push(Op::Advance(t + rng.below(5_000)));
            }
        }
        script
    }

    /// Byte-identity assertion: completion sequences, per-flow stats,
    /// bit-equal energy totals, work, link-busy accounting, traces.
    fn assert_matches(
        mut par: ShardedFlitEngine,
        mut seq: FlitEngine,
        script: &[Op],
        label: &str,
    ) {
        par.set_link_trace(true);
        seq.set_link_trace(true);
        let got = run_script(&mut par, script);
        let want = run_script(&mut seq, script);
        assert_eq!(got, want, "{label}: completion sequences diverge");
        for &(id, _) in &want {
            assert_eq!(par.stats(id), seq.stats(id), "{label}: FlowStats diverge for {id}");
        }
        assert_eq!(
            par.comm_energy_pj().to_bits(),
            seq.comm_energy_pj().to_bits(),
            "{label}: energy totals diverge ({} vs {})",
            par.comm_energy_pj(),
            seq.comm_energy_pj()
        );
        assert_eq!(par.work_done(), seq.work_done(), "{label}: work diverges");
        assert_eq!(par.link_busy_ns(), seq.link_busy_ns(), "{label}: link busy diverges");
        let ta = par.drain_link_trace();
        let tb = seq.drain_link_trace();
        assert_eq!(ta, tb, "{label}: link traces diverge");
        let ea = par.drain_energy_events();
        let eb = seq.drain_energy_events();
        assert_eq!(ea, eb, "{label}: energy events diverge");
    }

    fn exec(threads: usize) -> ExecSpec {
        ExecSpec::threads(threads)
    }

    #[test]
    fn differential_randomized_meshes_across_threads() {
        for seed in 0..4u64 {
            for threads in [2usize, 3, 8] {
                let mut rng = Rng::new(0x9A7 + seed * 31 + threads as u64);
                let rows = 2 + rng.below_usize(3);
                let cols = 2 + rng.below_usize(3);
                let depth = [1, 2, 4, 8, 16][rng.below_usize(5)];
                let nflows = 2 + rng.below_usize(9);
                let topo = mesh(rows, cols, &LinkParams::default());
                let script = random_script(&mut rng, rows * cols, nflows);
                assert_matches(
                    ShardedFlitEngine::with_buffer_depth(topo.clone(), exec(threads), depth),
                    FlitEngine::with_buffer_depth(topo, depth),
                    &script,
                    &format!("mesh {rows}x{cols} depth={depth} threads={threads} seed={seed}"),
                );
            }
        }
    }

    #[test]
    fn differential_partitioner_and_lookahead_variants() {
        let topo = mesh(4, 4, &LinkParams::default());
        let mut rng = Rng::new(0x5712);
        let script = random_script(&mut rng, 16, 10);
        for (p, la) in [
            (Partitioner::Stripes(5), None),
            (Partitioner::Stripes(16), None),
            (Partitioner::Auto, Some(1)),
            (Partitioner::Auto, Some(999)), // clamped to hop latency
        ] {
            let mut e = exec(4).with_partitioner(p);
            if let Some(la) = la {
                e = e.with_lookahead(la);
            }
            assert_matches(
                ShardedFlitEngine::new(topo.clone(), e),
                FlitEngine::new(topo.clone()),
                &script,
                &format!("partitioner={p:?} lookahead={la:?}"),
            );
        }
    }

    #[test]
    fn differential_hot_spot_exercises_starved_boundary_fallback() {
        // Everything converges on one corner with depth-1 buffers:
        // boundary ports starve, forcing the dense single-cycle path.
        let topo = mesh(3, 3, &LinkParams::default());
        let mut script = Vec::new();
        for i in 0..8usize {
            script.push(Op::Inject(
                FlowSpec { src: i, dst: 8, bytes: 2_048 + 512 * i as u64 },
                (i as u64) * 7,
            ));
        }
        script.push(Op::Advance(100));
        script.push(Op::Advance(1_000));
        for depth in [1usize, 2] {
            assert_matches(
                ShardedFlitEngine::with_buffer_depth(topo.clone(), exec(3), depth),
                FlitEngine::with_buffer_depth(topo.clone(), depth),
                &script,
                &format!("hot-spot 3x3 depth={depth}"),
            );
        }
    }

    #[test]
    fn differential_non_integer_clock() {
        for (seed, ghz) in [(0u64, 1.6f64), (1, 3.0), (2, 0.8)] {
            let mut rng = Rng::new(0xC10C + seed);
            let p = LinkParams { clock_ghz: ghz, ..LinkParams::default() };
            let topo = mesh(2, 3, &p);
            let script = random_script(&mut rng, 6, 8);
            assert_matches(
                ShardedFlitEngine::new(topo.clone(), exec(2)),
                FlitEngine::new(topo),
                &script,
                &format!("clock {ghz} GHz seed={seed}"),
            );
        }
    }

    #[test]
    fn differential_with_fault_mid_run() {
        let p = LinkParams::default();
        let pristine = mesh(3, 3, &p);
        let dead: Vec<bool> = pristine
            .links
            .iter()
            .map(|l| (l.src == 1 && l.dst == 2) || (l.src == 2 && l.dst == 1))
            .collect();
        let mut masked = pristine.clone();
        masked.apply_link_mask(&dead);
        for threads in [2usize, 8] {
            let mut par = ShardedFlitEngine::new(pristine.clone(), exec(threads));
            let mut seq = FlitEngine::new(pristine.clone());
            let mut rng = Rng::new(0xFA17);
            let script = random_script(&mut rng, 9, 8);
            for e in [&mut par as &mut dyn NetworkSim, &mut seq as &mut dyn NetworkSim] {
                for op in &script {
                    match *op {
                        Op::Inject(spec, at) => {
                            e.inject(spec, at);
                        }
                        Op::Advance(t) => while e.advance_until(t).is_some() {},
                    }
                }
                e.advance_until(40);
            }
            let dp = par.apply_fault(&masked, &dead);
            let ds = seq.apply_fault(&masked, &dead);
            assert_eq!(dp, ds, "threads={threads}: dropped flows diverge");
            // Retransmit the dropped flows on both, then drain.
            for (_, spec) in &dp {
                par.inject(*spec, 50_000);
                seq.inject(*spec, 50_000);
            }
            let mut tail = Vec::new();
            let ga = {
                let mut v = Vec::new();
                while let Some(c) = par.advance_until(TimeNs::MAX) {
                    v.push((c.id, c.time));
                }
                v
            };
            while let Some(c) = seq.advance_until(TimeNs::MAX) {
                tail.push((c.id, c.time));
            }
            assert_eq!(ga, tail, "threads={threads}: post-fault completions diverge");
            assert_eq!(
                par.comm_energy_pj().to_bits(),
                seq.comm_energy_pj().to_bits(),
                "threads={threads}: post-fault energy diverges"
            );
            assert_eq!(par.work_done(), seq.work_done());
        }
    }

    #[test]
    fn idle_fast_forward_and_empty_paths() {
        let topo = mesh(2, 2, &LinkParams::default());
        let script = vec![
            Op::Inject(FlowSpec { src: 0, dst: 0, bytes: 64 }, 5),
            Op::Inject(FlowSpec { src: 0, dst: 3, bytes: 512 }, 1_000_000),
            Op::Advance(1_000_500),
            Op::Inject(FlowSpec { src: 3, dst: 0, bytes: 512 }, 90_000_000_000),
        ];
        assert_matches(
            ShardedFlitEngine::new(topo.clone(), exec(4)),
            FlitEngine::new(topo),
            &script,
            "idle gaps + empty paths",
        );
    }

    #[test]
    fn region_count_clamps_to_nodes_and_threads() {
        let topo = mesh(2, 2, &LinkParams::default());
        assert_eq!(ShardedFlitEngine::new(topo.clone(), exec(16)).num_regions(), 4);
        assert_eq!(
            ShardedFlitEngine::new(
                topo.clone(),
                exec(2).with_partitioner(Partitioner::Stripes(3))
            )
            .num_regions(),
            3
        );
        assert_eq!(ShardedFlitEngine::new(topo, exec(2)).num_regions(), 2);
    }
}

