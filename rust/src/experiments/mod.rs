//! Experiment harness: one function per table/figure of the paper's
//! evaluation (§V), shared by the `chipsim` CLI and `rust/benches/*`.
//!
//! Every function returns a [`Table`] shaped like the paper's artifact and
//! writes CSV/JSON into the results directory (see `metrics::results_dir`).
//! `quick = true` shrinks workloads for CI/tests; benches run full size.
//!
//! Absolute numbers differ from the paper (our substrate is an analytical
//! IMC model + from-scratch NoI instead of CiMLoop + HeteroGarnet); the
//! experiments reproduce the paper's *shape*: who wins, direction and
//! growth of the inaccuracy, crossovers (see EXPERIMENTS.md).

use crate::baselines::BaselineEstimator;
use crate::config::{HardwareConfig, SimParams, WorkloadConfig};
use crate::hwemu;
use crate::metrics::{self, inaccuracy_pct, Csv};
use crate::sim::{SimReport, Simulation};
use crate::thermal::{native::NativeSolver, ThermalModel};
use crate::util::benchkit::{fmt_ns, Table};
use crate::workload::{ModelKind, ALL_CNNS};

/// Shared workload constants (paper §V-A).
pub const STREAM_MODELS: usize = 50;
pub const STREAM_SEED: u64 = 0xC0FFEE;
pub const MESH: (usize, usize) = (10, 10);
/// Inference counts swept by the pipelined studies (paper Table III).
pub const INF_SWEEP: [u32; 5] = [1, 3, 5, 10, 20];

fn stream_size(quick: bool) -> usize {
    if quick {
        12
    } else {
        STREAM_MODELS
    }
}

fn params(pipelined: bool, inferences: u32) -> SimParams {
    SimParams {
        pipelined,
        inferences_per_model: inferences,
        warmup_ns: 0,
        cooldown_ns: 0,
        ..SimParams::default()
    }
}

fn run_stream(hw: &HardwareConfig, pipelined: bool, inferences: u32, n_models: usize) -> SimReport {
    Simulation::builder()
        .hardware(hw.clone())
        .params(params(pipelined, inferences))
        .build()
        .expect("experiment configuration")
        .run(WorkloadConfig::cnn_stream(n_models, inferences, STREAM_SEED))
        .expect("co-simulation")
}

// ------------------------------------------------------------- Table IV

/// Table IV: percent inaccuracy of both baselines vs CHIPSIM,
/// non-pipelined operation, homogeneous mesh, 10 inferences/model.
pub fn table4(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let report = run_stream(&hw, false, 10, stream_size(quick));
    let mut base = BaselineEstimator::new(hw);
    let mut t = Table::new(
        "Table IV: baseline inaccuracy, non-pipelined (10 inf/model)",
        &["DNN Model", "Comm. Only", "Comm. + Compute"],
    );
    let mut csv = Csv::new(&["model", "chipsim_ns", "comm_only_ns", "comm_compute_ns", "err_comm_only_pct", "err_comm_compute_pct"]);
    for kind in ALL_CNNS {
        let Some(cs) = report.mean_latency_of(kind) else { continue };
        let co = base.comm_only(kind).unwrap().inference_latency_ns;
        let cc = base.comm_compute(kind).unwrap().inference_latency_ns;
        t.row(vec![
            kind.name().into(),
            format!("{:.0}%", inaccuracy_pct(cs, co)),
            format!("{:.0}%", inaccuracy_pct(cs, cc)),
        ]);
        csv.row(vec![
            kind.name().into(),
            format!("{cs:.0}"),
            format!("{co:.0}"),
            format!("{cc:.0}"),
            format!("{:.1}", inaccuracy_pct(cs, co)),
            format!("{:.1}", inaccuracy_pct(cs, cc)),
        ]);
    }
    let _ = csv.save("table4.csv");
    t
}

// --------------------------------------------------------------- Fig. 6

/// Fig. 6: pipelined inaccuracy of both baselines vs inferences/model.
pub fn fig6(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let sweep: &[u32] = if quick { &[1, 5] } else { &INF_SWEEP };
    let mut base = BaselineEstimator::new(hw.clone());
    let mut t = Table::new(
        "Fig. 6: pipelined baseline inaccuracy vs inferences per model",
        &["Model", "Inf.", "CHIPSIM", "Comm.Only err", "Comm.+Comp err"],
    );
    let mut csv = Csv::new(&["model", "inferences", "chipsim_ns", "err_comm_only_pct", "err_comm_compute_pct"]);
    for &inf in sweep {
        let report = run_stream(&hw, true, inf, stream_size(quick));
        for kind in ALL_CNNS {
            let Some(cs) = report.mean_latency_of(kind) else { continue };
            let co = base.comm_only(kind).unwrap().inference_latency_ns;
            let cc = base.comm_compute(kind).unwrap().inference_latency_ns;
            t.row(vec![
                kind.name().into(),
                inf.to_string(),
                fmt_ns(cs),
                format!("{:.0}%", inaccuracy_pct(cs, co)),
                format!("{:.0}%", inaccuracy_pct(cs, cc)),
            ]);
            csv.row(vec![
                kind.name().into(),
                inf.to_string(),
                format!("{cs:.0}"),
                format!("{:.1}", inaccuracy_pct(cs, co)),
                format!("{:.1}", inaccuracy_pct(cs, cc)),
            ]);
        }
    }
    let _ = csv.save("fig6.csv");
    t
}

// --------------------------------------------------------------- Fig. 7

/// Fig. 7: average compute vs communication time per model (pipelined,
/// 10 inferences/model).
pub fn fig7(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let report = run_stream(&hw, true, 10, stream_size(quick));
    let mut t = Table::new(
        "Fig. 7: avg compute vs communication time per inference (pipelined, 10 inf)",
        &["Model", "Compute", "Communication", "Comm share"],
    );
    let mut csv = Csv::new(&["model", "compute_ns", "comm_ns", "comm_share_pct"]);
    for kind in ALL_CNNS {
        let Some((comp, comm)) = report.mean_compute_comm_of(kind) else { continue };
        let share = comm / (comp + comm) * 100.0;
        t.row(vec![
            kind.name().into(),
            fmt_ns(comp),
            fmt_ns(comm),
            format!("{share:.0}%"),
        ]);
        csv.row(vec![
            kind.name().into(),
            format!("{comp:.0}"),
            format!("{comm:.0}"),
            format!("{share:.1}"),
        ]);
    }
    let _ = csv.save("fig7.csv");
    t
}

// -------------------------------------------------------------- Table V

/// Table V: heterogeneous (50/50 checkerboard A/B) system — Comm.+Compute
/// baseline inaccuracy across inference counts.  Also reports the compute
/// share (the paper: 42–54 %).
pub fn table5(quick: bool) -> Table {
    let hw = HardwareConfig::heterogeneous_mesh(MESH.0, MESH.1);
    let sweep: &[u32] = if quick { &[1, 5] } else { &INF_SWEEP };
    let mut base = BaselineEstimator::new(hw.clone());
    let mut t = Table::new(
        "Table V: Comm.+Compute inaccuracy on the heterogeneous system",
        &["Inf.", "ResNet18", "ResNet34", "ResNet50", "AlexNet", "compute share"],
    );
    let mut csv = Csv::new(&["inferences", "model", "chipsim_ns", "err_pct", "compute_share_pct"]);
    for &inf in sweep {
        let report = run_stream(&hw, true, inf, stream_size(quick));
        let mut cells = vec![inf.to_string()];
        let mut shares = Vec::new();
        for kind in [ModelKind::ResNet18, ModelKind::ResNet34, ModelKind::ResNet50, ModelKind::AlexNet] {
            let cell = match report.mean_latency_of(kind) {
                Some(cs) => {
                    let cc = base.comm_compute(kind).unwrap().inference_latency_ns;
                    let (comp, comm) = report.mean_compute_comm_of(kind).unwrap();
                    let share = comp / (comp + comm) * 100.0;
                    shares.push(share);
                    csv.row(vec![
                        inf.to_string(),
                        kind.name().into(),
                        format!("{cs:.0}"),
                        format!("{:.1}", inaccuracy_pct(cs, cc)),
                        format!("{share:.1}"),
                    ]);
                    format!("{:.0}%", inaccuracy_pct(cs, cc))
                }
                None => "-".to_string(),
            };
            cells.push(cell);
        }
        let mean_share = shares.iter().sum::<f64>() / shares.len().max(1) as f64;
        cells.push(format!("{mean_share:.0}%"));
        t.row(cells);
    }
    let _ = csv.save("table5.csv");
    t
}

// ------------------------------------------------------------- Table VI

/// Table VI: Floret NoI — Comm.+Compute inaccuracy across inference counts.
pub fn table6(quick: bool) -> Table {
    let hw = HardwareConfig::floret(MESH.0, MESH.1, 10);
    let sweep: &[u32] = if quick { &[1, 5] } else { &INF_SWEEP };
    let mut base = BaselineEstimator::new(hw.clone());
    let mut t = Table::new(
        "Table VI: Comm.+Compute inaccuracy with the Floret NoI",
        &["Inf.", "ResNet18", "ResNet34", "ResNet50", "AlexNet"],
    );
    let mut csv = Csv::new(&["inferences", "model", "chipsim_ns", "err_pct"]);
    for &inf in sweep {
        let report = run_stream(&hw, true, inf, stream_size(quick));
        let mut cells = vec![inf.to_string()];
        for kind in [ModelKind::ResNet18, ModelKind::ResNet34, ModelKind::ResNet50, ModelKind::AlexNet] {
            let cell = match report.mean_latency_of(kind) {
                Some(cs) => {
                    let cc = base.comm_compute(kind).unwrap().inference_latency_ns;
                    csv.row(vec![
                        inf.to_string(),
                        kind.name().into(),
                        format!("{cs:.0}"),
                        format!("{:.1}", inaccuracy_pct(cs, cc)),
                    ]);
                    format!("{:.0}%", inaccuracy_pct(cs, cc))
                }
                None => "-".to_string(),
            };
            cells.push(cell);
        }
        t.row(cells);
    }
    let _ = csv.save("table6.csv");
    t
}

// --------------------------------------------------------------- Fig. 8

/// Fig. 8: per-chiplet + total power profiles at 1 µs granularity.
/// Returns summary rows; the full traces land in results/fig8_*.csv.
pub fn fig8(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let report = run_stream(&hw, true, 10, stream_size(quick));
    // The paper plots chiplets 1 and 51 (+1 more) — pick the same spread.
    let picks = [1usize, 51, 88];
    let _ = metrics::write_result("fig8_per_chiplet.csv", &report.power.to_csv(&picks));
    let total = report.power.total_series_w();
    let mut csv = Csv::new(&["time_us", "total_w"]);
    for (b, w) in total.iter().enumerate() {
        csv.row(vec![b.to_string(), format!("{w:.4}")]);
    }
    let _ = csv.save("fig8_total.csv");
    let mut t = Table::new(
        "Fig. 8: power profile summary (full traces in results/fig8_*.csv)",
        &["Metric", "Value"],
    );
    let peak = total.iter().cloned().fold(0.0, f64::max);
    let avg = total.iter().sum::<f64>() / total.len().max(1) as f64;
    t.row(vec!["bins (1 µs)".into(), total.len().to_string()]);
    t.row(vec!["avg system power".into(), format!("{avg:.2} W")]);
    t.row(vec!["peak system power".into(), format!("{peak:.2} W")]);
    for &c in &picks {
        t.row(vec![
            format!("chiplet {c} avg"),
            format!("{:.1} mW", report.power.avg_power_mw(c)),
        ]);
    }
    t
}

// --------------------------------------------------------------- Fig. 9

/// Fig. 9: end-of-simulation thermal heatmap.  Uses the PJRT AOT solver
/// when artifacts are present, otherwise the native oracle.
pub fn fig9(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let report = run_stream(&hw, true, 10, stream_size(quick));
    let tm = ThermalModel::build(&hw);
    // Decimate 1 µs power bins to 10 µs thermal steps.
    let stride = 10usize;
    let dt_s = stride as f64 * report.power.bin_ns as f64 * 1e-9;
    let rows = report.power.matrix_w(stride);
    let node_steps: Vec<Vec<f64>> = rows.iter().map(|r| tm.node_power(r)).collect();
    let (final_t, solver_name) = match crate::thermal::pjrt::PjrtThermalSolver::open_default(&tm, dt_s) {
        Ok(mut s) => {
            let traj = s.transient(&vec![0.0; tm.n], &node_steps).expect("pjrt transient");
            (traj.last().cloned().unwrap_or_else(|| vec![0.0; tm.n]), "pjrt-aot")
        }
        Err(e) => {
            crate::warn_once!("PJRT thermal unavailable ({e}); using native solver");
            let s = NativeSolver::new(&tm, dt_s).expect("native solver");
            let traj = s.transient(&vec![0.0; tm.n], &node_steps);
            (traj.last().cloned().unwrap_or_else(|| vec![0.0; tm.n]), "native")
        }
    };
    let _ = metrics::write_result("fig9_heatmap.txt", &tm.heatmap(&final_t, MESH.0, MESH.1));
    let _ = metrics::write_result("fig9_temps.csv", &tm.temps_csv(&final_t, hw.num_chiplets()));
    println!("{}", tm.heatmap(&final_t, MESH.0, MESH.1));
    let temps: Vec<f64> =
        (0..hw.num_chiplets()).map(|c| tm.chiplet_temp(&final_t, c) + tm.ambient_c).collect();
    let hottest = temps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let coolest = temps.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut t = Table::new("Fig. 9: end-of-simulation thermal summary", &["Metric", "Value"]);
    t.row(vec!["solver".into(), solver_name.into()]);
    t.row(vec!["thermal steps".into(), node_steps.len().to_string()]);
    t.row(vec!["hottest chiplet".into(), format!("{hottest:.2} °C")]);
    t.row(vec!["coolest chiplet".into(), format!("{coolest:.2} °C")]);
    t.row(vec!["spread".into(), format!("{:.2} K", hottest - coolest)]);
    t
}

// -------------------------------------------------------------- Fig. 10

/// Fig. 10: ViT-B/16 single-model input-pipelined execution — difference
/// between CHIPSIM and each baseline vs inference count.
pub fn fig10(quick: bool) -> Table {
    let hw = HardwareConfig::vit_mesh(MESH.0, MESH.1);
    let sweep: &[u32] = if quick { &[1, 5] } else { &[1, 2, 5, 10, 20] };
    let mut base = BaselineEstimator::new(hw.clone());
    let mut t = Table::new(
        "Fig. 10: ViT-B/16 — baseline difference vs CHIPSIM",
        &["Inf.", "CHIPSIM (amortized)", "Comm.Only diff", "Comm.+Comp diff"],
    );
    let mut csv = Csv::new(&["inferences", "chipsim_ns", "diff_comm_only_pct", "diff_comm_compute_pct"]);
    for &inf in sweep {
        let report = Simulation::builder()
            .hardware(hw.clone())
            .params(params(true, inf))
            .build()
            .expect("vit configuration")
            .run(WorkloadConfig::single(ModelKind::VitB16))
            .expect("vit run");
        // Total run time (weight load + pipelined inferences) compared to
        // the decoupled ideal-pipeline extrapolation: at 1 inference the
        // two coincide (no pipelined-input contention yet), and the gap
        // grows with input pipelining — the paper's Fig. 10 behaviour.
        let o = &report.outcomes[0];
        let cs = (o.finished_ns - o.mapped_ns) as f64 / inf as f64;
        let co =
            base.pipelined_total_with_weight_load(ModelKind::VitB16, inf, false).unwrap()
                / inf as f64;
        let cc =
            base.pipelined_total_with_weight_load(ModelKind::VitB16, inf, true).unwrap()
                / inf as f64;
        t.row(vec![
            inf.to_string(),
            fmt_ns(cs),
            format!("{:.0}%", inaccuracy_pct(cs, co)),
            format!("{:.0}%", inaccuracy_pct(cs, cc)),
        ]);
        csv.row(vec![
            inf.to_string(),
            format!("{cs:.0}"),
            format!("{:.1}", inaccuracy_pct(cs, co)),
            format!("{:.1}", inaccuracy_pct(cs, cc)),
        ]);
    }
    let _ = csv.save("fig10.csv");
    t
}

// -------------------------------------------------------------- Fig. 11

/// Fig. 11: bandwidth scaling curves of the emulated Threadripper platform.
pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig. 11: CCD/DDR bandwidth envelope (golden-model emulator)",
        &["Sweep", "x", "read GB/s", "write GB/s"],
    );
    let mut csv = Csv::new(&["sweep", "x", "read_gbs", "write_gbs"]);
    for threads in 1..=8 {
        t.row(vec![
            "single-CCD threads".into(),
            threads.to_string(),
            format!("{:.1}", hwemu::ccd_read_bw_gbs(threads)),
            format!("{:.1}", hwemu::ccd_write_bw_gbs(threads)),
        ]);
        csv.row(vec![
            "threads".into(),
            threads.to_string(),
            format!("{:.2}", hwemu::ccd_read_bw_gbs(threads)),
            format!("{:.2}", hwemu::ccd_write_bw_gbs(threads)),
        ]);
    }
    for ccds in 1..=8 {
        t.row(vec![
            "active CCDs (8 thr each)".into(),
            ccds.to_string(),
            format!("{:.0}", hwemu::aggregate_read_bw_gbs(ccds)),
            format!("{:.0}", hwemu::aggregate_write_bw_gbs(ccds)),
        ]);
        csv.row(vec![
            "ccds".into(),
            ccds.to_string(),
            format!("{:.2}", hwemu::aggregate_read_bw_gbs(ccds)),
            format!("{:.2}", hwemu::aggregate_write_bw_gbs(ccds)),
        ]);
    }
    let _ = csv.save("fig11.csv");
    t
}

// ------------------------------------------------------------- Table VII

/// Table VII: CHIPSIM (CCD-star + packet engine + CPU backend) vs the
/// golden-model emulator on the three CNN scenarios.
pub fn table7() -> Table {
    let scenarios: Vec<(&str, Vec<ModelKind>)> = vec![
        ("One Chiplet", vec![ModelKind::AlexNet]),
        ("Two Chiplets", vec![ModelKind::AlexNet, ModelKind::AlexNet]),
        (
            "Four Chiplets",
            vec![ModelKind::AlexNet, ModelKind::ResNet18, ModelKind::ResNet34, ModelKind::ResNet50],
        ),
    ];
    let mut t = Table::new(
        "Table VII: CHIPSIM vs hardware-emulator execution time",
        &["Scenario", "Model", "% Diff from HW", "Avg % Diff"],
    );
    let mut csv = Csv::new(&["scenario", "model", "sim_ns", "hw_ns", "diff_pct"]);
    for (name, kinds) in scenarios {
        let traces: Vec<Vec<hwemu::Phase>> =
            kinds.iter().map(|&k| hwemu::model_trace(k)).collect();
        let hw_times = hwemu::emulate(&traces);
        let sim_times = hwemu::chipsim_ccd_run(&traces);
        let diffs: Vec<f64> = sim_times
            .iter()
            .zip(&hw_times)
            .map(|(&s, &h)| hwemu::percent_diff(s, h))
            .collect();
        let avg = diffs.iter().sum::<f64>() / diffs.len() as f64;
        for (i, kind) in kinds.iter().enumerate() {
            t.row(vec![
                if i == 0 { name.into() } else { String::new() },
                format!("{} ({})", kind.name(), i + 1),
                format!("{:.2}%", diffs[i]),
                if i == 0 { format!("{avg:.2}%") } else { String::new() },
            ]);
            csv.row(vec![
                name.into(),
                kind.name().into(),
                format!("{:.0}", sim_times[i]),
                format!("{:.0}", hw_times[i]),
                format!("{:.3}", diffs[i]),
            ]);
        }
    }
    let _ = csv.save("table7.csv");
    t
}

// ------------------------------------------------------------ Table VIII

/// Table VIII: simulation wall-clock per model for each method.
pub fn table8(quick: bool) -> Table {
    let hw = HardwareConfig::homogeneous_mesh(MESH.0, MESH.1);
    let n = stream_size(quick);
    let wall0 = std::time::Instant::now();
    let report = run_stream(&hw, true, 10, n);
    let chipsim_per_model = wall0.elapsed().as_secs_f64() / report.outcomes.len().max(1) as f64;

    // Baseline: decoupled per-model estimation (the Comm.+Compute method).
    let wall1 = std::time::Instant::now();
    let mut base = BaselineEstimator::new(hw);
    for kind in ALL_CNNS {
        let _ = base.comm_compute(kind);
    }
    let baseline_per_model = wall1.elapsed().as_secs_f64() / 4.0;

    let mut t = Table::new(
        "Table VIII: simulation runtime per model",
        &["Simulation Method", "Avg. Execution Time per Model"],
    );
    t.row(vec!["CHIPSIM (this work)".into(), format!("{:.2} s", chipsim_per_model)]);
    t.row(vec!["Comm. + Compute baseline".into(), format!("{:.3} s", baseline_per_model)]);
    t.row(vec!["Cycle-accurate (gem5)".into(), "weeks [56] (cited, not run)".into()]);
    let mut csv = Csv::new(&["method", "seconds_per_model"]);
    csv.row(vec!["chipsim".into(), format!("{chipsim_per_model:.3}")]);
    csv.row(vec!["comm_compute".into(), format!("{baseline_per_model:.4}")]);
    let _ = csv.save("table8.csv");
    t
}
