//! Deterministic fault injection and graceful-degradation accounting.
//!
//! Chiplets exist because of faults — reduced yields made monolithic
//! dies untenable — so a chiplet simulator must be able to answer
//! "what does p99 look like with a dead link and a thermal-runaway
//! board?"  This module provides the schedule side of that question: a
//! seeded [`FaultPlan`] parsed from a compact spec string, expanded
//! ("armed") into a deterministic [`FaultToggle`] timeline that the
//! simulation ([`crate::sim`]) and fleet ([`crate::fleet`]) layers
//! execute, and a [`FaultReport`] that rides on
//! `SimReport`/`FleetReport` with availability, goodput-under-fault,
//! retry/abort counters, and the repair/recovery timeline.
//!
//! # Fault model
//!
//! | kind      | target            | effect                                            |
//! |-----------|-------------------|---------------------------------------------------|
//! | `link`    | `A-B` node pair   | both directed links down; flows reroute or fail   |
//! | `router`  | node index        | every link touching the node down (partitioned)   |
//! | `chiplet` | chiplet index     | mapper excludes it; in-flight segments abort      |
//! | `sensor`  | chiplet index     | stuck-at or drifting readings feed the governor   |
//! | `board`   | replica index     | fleet board crash: migrate queue, retry in-flight |
//!
//! Every event is scheduled: permanent (`@T`), transient with repair
//! (`@T+D`), or intermittent (`@T+D%P[*K]` — down for `D` every `P`,
//! `K` occurrences).  Arming never touches the run RNG (the plan has
//! its own seed for `?` random-target selection), so an armed-but-empty
//! plan is fingerprint-identical to a faultless run — the repo's
//! zero-perturbation rule.
//!
//! # Spec grammar
//!
//! Comma/semicolon-separated tokens:
//!
//! ```text
//! link:2-3@1ms            permanent link failure at 1 ms
//! link:?@500us+200us      random link, down 500 µs..700 µs
//! router:5@2ms            node 5 partitioned at 2 ms
//! chiplet:7@1ms+4ms       chiplet 7 dead for 4 ms
//! sensor:3:stuck=95@1ms   sensor 3 reads 95 °C from 1 ms on
//! sensor:0:drift=0.5@0    sensor 0 drifts +0.5 °C per ms
//! board:1@5ms             fleet replica 1 crashes at 5 ms
//! seed=42                 plan seed (random-target selection)
//! retry=3:200us:2ms:20ms  max:backoff:cap:deadline retry policy
//! ```
//!
//! Times accept `ns` (default), `us`, and `ms` suffixes.

use crate::util::rng::Rng;
use crate::TimeNs;

/// Cap on intermittent repeats (`*K` clamps to this).
pub const MAX_REPEATS: u32 = 256;

// ------------------------------------------------------------------- kinds

/// The resource class a fault targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// An undirected NoI link (both directed halves fail together).
    Link,
    /// An NoI router: every link touching the node fails.
    Router,
    /// A compute chiplet dies (mapper exclusion + in-flight aborts).
    Chiplet,
    /// A thermal sensor lies (stuck-at or drift).
    Sensor,
    /// A whole fleet replica board crashes.
    Board,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Link => "link",
            FaultKind::Router => "router",
            FaultKind::Chiplet => "chiplet",
            FaultKind::Sensor => "sensor",
            FaultKind::Board => "board",
        }
    }
}

/// How a faulty sensor lies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SensorMode {
    /// Reads a constant temperature regardless of the truth.
    StuckAt(f64),
    /// Reading error grows by this many °C per millisecond of fault age.
    DriftPerMs(f64),
}

// -------------------------------------------------------------------- plan

/// Target of one fault event, before arming resolves it to an index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    /// `link:A-B` — the undirected pair; other kinds use `Index`.
    NodePair(usize, usize),
    Index(usize),
    /// `?` — resolved from the plan seed at arm time.
    Random,
}

/// One scheduled fault in a plan (pre-expansion).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub target: FaultTarget,
    /// First failure instant.
    pub at_ns: TimeNs,
    /// Down duration until repair; `None` = permanent.
    pub repair_ns: Option<TimeNs>,
    /// Intermittent period between failure onsets; `None` = one-shot.
    pub period_ns: Option<TimeNs>,
    /// Occurrences when intermittent (clamped to [`MAX_REPEATS`]).
    pub repeats: u32,
    /// Sensor lie mode (sensor faults only).
    pub sensor: Option<SensorMode>,
}

/// Fleet-level retry policy for requests aborted by a fault: capped
/// exponential backoff with a per-request deadline measured from the
/// request's original arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Attempts after the first dispatch (0 = never retry).
    pub max_attempts: u32,
    /// Backoff before attempt k: `backoff_ns << (k-1)`, capped.
    pub backoff_ns: TimeNs,
    pub backoff_cap_ns: TimeNs,
    /// Give up (count dropped) past `arrival + deadline_ns`.
    pub deadline_ns: TimeNs,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            backoff_ns: 200_000,       // 200 µs
            backoff_cap_ns: 2_000_000, // 2 ms
            deadline_ns: 20_000_000,   // 20 ms
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry attempt `attempt` (1-based), capped.
    pub fn backoff_for(&self, attempt: u32) -> TimeNs {
        let shift = attempt.saturating_sub(1).min(62);
        self.backoff_ns
            .saturating_mul(1u64 << shift)
            .min(self.backoff_cap_ns)
    }
}

/// A seeded, schedulable fault-injection plan.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// Seed for `?` random-target selection only; never mixes with the
    /// run RNG (zero-perturbation rule).
    pub seed: u64,
    pub retry: RetryPolicy,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan { events: Vec::new(), seed: 0xFA017, retry: RetryPolicy::default() }
    }
}

/// Parse a `NUMBER[ns|us|ms]` duration into nanoseconds.
fn parse_time_ns(s: &str) -> anyhow::Result<TimeNs> {
    let s = s.trim();
    let (num, mult) = if let Some(v) = s.strip_suffix("ms") {
        (v, 1e6)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e3)
    } else if let Some(v) = s.strip_suffix("ns") {
        (v, 1.0)
    } else {
        (s, 1.0)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration '{s}' (expected NUMBER[ns|us|ms])"))?;
    anyhow::ensure!(v >= 0.0 && v.is_finite(), "duration '{s}' must be finite and >= 0");
    Ok((v * mult) as TimeNs)
}

impl FaultPlan {
    /// Parse the spec grammar (module docs).  An empty string is a valid
    /// empty plan.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for tok in spec.split([',', ';']) {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some(v) = tok.strip_prefix("seed=") {
                plan.seed = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad plan seed '{v}'"))?;
                continue;
            }
            if let Some(v) = tok.strip_prefix("retry=") {
                let parts: Vec<&str> = v.split(':').collect();
                anyhow::ensure!(
                    parts.len() == 4,
                    "bad retry policy '{v}' (expected max:backoff:cap:deadline)"
                );
                plan.retry = RetryPolicy {
                    max_attempts: parts[0]
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad retry count '{}'", parts[0]))?,
                    backoff_ns: parse_time_ns(parts[1])?,
                    backoff_cap_ns: parse_time_ns(parts[2])?,
                    deadline_ns: parse_time_ns(parts[3])?,
                };
                continue;
            }
            plan.events.push(Self::parse_event(tok)?);
        }
        Ok(plan)
    }

    fn parse_event(tok: &str) -> anyhow::Result<FaultEvent> {
        // KIND:TARGET[:MODE]@T[+D][%P[*K]]
        let (head, sched) = tok
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault '{tok}' is missing '@START'"))?;
        let mut head_parts = head.split(':');
        let kind = match head_parts.next().unwrap_or("") {
            "link" => FaultKind::Link,
            "router" => FaultKind::Router,
            "chiplet" => FaultKind::Chiplet,
            "sensor" => FaultKind::Sensor,
            "board" => FaultKind::Board,
            other => anyhow::bail!(
                "unknown fault kind '{other}' (expected link, router, chiplet, sensor, board)"
            ),
        };
        let target_s = head_parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("fault '{tok}' is missing a target"))?;
        let target = if target_s == "?" {
            FaultTarget::Random
        } else if kind == FaultKind::Link {
            let (a, b) = target_s
                .split_once('-')
                .ok_or_else(|| anyhow::anyhow!("link target '{target_s}' must be 'A-B'"))?;
            FaultTarget::NodePair(
                a.parse().map_err(|_| anyhow::anyhow!("bad link endpoint '{a}'"))?,
                b.parse().map_err(|_| anyhow::anyhow!("bad link endpoint '{b}'"))?,
            )
        } else {
            FaultTarget::Index(
                target_s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault target '{target_s}'"))?,
            )
        };
        let sensor = match (kind, head_parts.next()) {
            (FaultKind::Sensor, Some(mode)) => {
                let (m, v) = mode
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("sensor mode '{mode}' must be NAME=VALUE"))?;
                let val: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad sensor value '{v}'"))?;
                match m {
                    "stuck" => Some(SensorMode::StuckAt(val)),
                    "drift" => Some(SensorMode::DriftPerMs(val)),
                    other => anyhow::bail!("unknown sensor mode '{other}' (stuck or drift)"),
                }
            }
            (FaultKind::Sensor, None) => {
                anyhow::bail!("sensor fault '{tok}' needs a mode (stuck=C or drift=C_PER_MS)")
            }
            (_, Some(extra)) => anyhow::bail!("unexpected ':{extra}' in fault '{tok}'"),
            (_, None) => None,
        };
        // Schedule: T[+D][%P[*K]]
        let (t_s, tail) = match sched.find(['+', '%']) {
            Some(i) => (&sched[..i], Some(&sched[i..])),
            None => (sched, None),
        };
        let at_ns = parse_time_ns(t_s)?;
        let (mut repair_ns, mut period_ns, mut repeats) = (None, None, 1u32);
        if let Some(tail) = tail {
            let (d_s, p_s) = if let Some(rest) = tail.strip_prefix('+') {
                match rest.split_once('%') {
                    Some((d, p)) => (Some(d), Some(p)),
                    None => (Some(rest), None),
                }
            } else {
                (None, tail.strip_prefix('%'))
            };
            if let Some(d_s) = d_s {
                repair_ns = Some(parse_time_ns(d_s)?);
            }
            if let Some(p_s) = p_s {
                let (p, k) = match p_s.split_once('*') {
                    Some((p, k)) => (
                        p,
                        k.parse::<u32>()
                            .map_err(|_| anyhow::anyhow!("bad repeat count '{k}'"))?,
                    ),
                    None => (p_s, 16),
                };
                let p = parse_time_ns(p)?;
                anyhow::ensure!(p > 0, "intermittent period must be > 0 in '{tok}'");
                let d = repair_ns.unwrap_or(p / 2);
                anyhow::ensure!(
                    d < p,
                    "intermittent down time {d} ns must be shorter than period {p} ns in '{tok}'"
                );
                repair_ns = Some(d);
                period_ns = Some(p);
                repeats = k.clamp(1, MAX_REPEATS);
            }
        }
        Ok(FaultEvent { kind, target, at_ns, repair_ns, period_ns, repeats, sensor })
    }

    /// No events at all — arming is guaranteed to be a no-op.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Board-crash events only (executed by the fleet dispatcher).
    pub fn board_events(&self) -> Vec<&FaultEvent> {
        self.events.iter().filter(|e| e.kind == FaultKind::Board).collect()
    }

    /// Expand the plan into a sorted toggle timeline for one board-level
    /// simulation.  Board events are skipped (the fleet executes those);
    /// `?` targets resolve from the plan seed.  Never touches any run
    /// RNG.  Targets are validated against `dims`.
    pub fn arm(&self, dims: &FaultDims) -> anyhow::Result<Vec<FaultToggle>> {
        let mut toggles = Vec::new();
        let mut rng = Rng::new(self.seed ^ 0xFA17_70C6_1E5C_0DE5);
        for (idx, ev) in self.events.iter().enumerate() {
            if ev.kind == FaultKind::Board {
                continue;
            }
            let domain = match ev.kind {
                FaultKind::Link => dims.links,
                FaultKind::Router => dims.nodes,
                FaultKind::Chiplet | FaultKind::Sensor => dims.chiplets,
                FaultKind::Board => unreachable!(),
            };
            anyhow::ensure!(domain > 0, "no {} targets exist in this system", ev.kind.name());
            let target = match ev.target {
                FaultTarget::Index(i) => {
                    anyhow::ensure!(
                        i < domain,
                        "{} target {i} out of range (have {domain})",
                        ev.kind.name()
                    );
                    FaultTarget::Index(i)
                }
                FaultTarget::NodePair(a, b) => {
                    anyhow::ensure!(
                        a < dims.nodes && b < dims.nodes && a != b,
                        "link target {a}-{b} out of range (have {} nodes)",
                        dims.nodes
                    );
                    FaultTarget::NodePair(a, b)
                }
                FaultTarget::Random => {
                    // Deterministic in (plan seed, event index) only; a
                    // random link resolves to a directed link index and
                    // the executor fails its reverse half too.
                    FaultTarget::Index((rng.next_u64() as usize) % domain)
                }
            };
            for k in 0..ev.repeats.max(1) {
                let start = ev.at_ns + ev.period_ns.unwrap_or(0) * k as u64;
                toggles.push(FaultToggle {
                    at_ns: start,
                    kind: ev.kind,
                    target,
                    up: false,
                    sensor: ev.sensor,
                    event: idx,
                });
                if let Some(d) = ev.repair_ns {
                    toggles.push(FaultToggle {
                        at_ns: start + d,
                        kind: ev.kind,
                        target,
                        up: true,
                        sensor: ev.sensor,
                        event: idx,
                    });
                }
                if ev.period_ns.is_none() {
                    break;
                }
            }
        }
        // Stable order: time, then declaration order, then down-before-up
        // is impossible at equal times within one event (repair > 0 or
        // equal, where up applies after down anyway via `up` ordering).
        toggles.sort_by_key(|t| (t.at_ns, t.event, t.up));
        Ok(toggles)
    }

    /// Expand the board-crash timeline (fleet side): `(at_ns, replica)`,
    /// sorted.  Repair/intermittent schedules are rejected for boards —
    /// a crashed board stays down (the autoscaler replaces capacity).
    pub fn arm_boards(&self, replicas: usize) -> anyhow::Result<Vec<(TimeNs, usize)>> {
        let mut rng = Rng::new(self.seed ^ 0xB0A2_DC2A_54C2_0DE5);
        let mut out = Vec::new();
        for ev in &self.events {
            if ev.kind != FaultKind::Board {
                continue;
            }
            anyhow::ensure!(
                ev.repair_ns.is_none() && ev.period_ns.is_none(),
                "board crashes are permanent (no '+D'/'%P' schedule)"
            );
            anyhow::ensure!(replicas > 0, "no board targets exist");
            let id = match ev.target {
                FaultTarget::Index(i) => {
                    anyhow::ensure!(i < replicas, "board target {i} out of range ({replicas})");
                    i
                }
                FaultTarget::Random => (rng.next_u64() as usize) % replicas,
                FaultTarget::NodePair(..) => anyhow::bail!("board target must be an index"),
            };
            out.push((ev.at_ns, id));
        }
        out.sort_unstable();
        Ok(out)
    }
}

/// Target-domain sizes a plan is armed against.
#[derive(Debug, Clone, Copy)]
pub struct FaultDims {
    /// Directed NoI links.
    pub links: usize,
    /// NoI nodes (routers).
    pub nodes: usize,
    /// Compute chiplets (also the sensor count).
    pub chiplets: usize,
}

/// One expanded state change: resource `target` goes down (`up ==
/// false`) or is repaired (`up == true`) at `at_ns`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultToggle {
    pub at_ns: TimeNs,
    pub kind: FaultKind,
    pub target: FaultTarget,
    pub up: bool,
    pub sensor: Option<SensorMode>,
    /// Index of the originating [`FaultEvent`] (stable tie-break).
    pub event: usize,
}

// --------------------------------------------------------------- downtime

/// Per-resource downtime integrator feeding the availability metric.
#[derive(Debug, Clone, Default)]
pub struct DowntimeTracker {
    /// Open outages: (kind, target) -> down-since.
    open: Vec<((FaultKind, usize), TimeNs)>,
    /// Closed outage time, summed.
    accrued_ns: u64,
}

impl DowntimeTracker {
    pub fn down(&mut self, kind: FaultKind, target: usize, now: TimeNs) {
        if !self.open.iter().any(|(k, _)| *k == (kind, target)) {
            self.open.push(((kind, target), now));
        }
    }

    pub fn up(&mut self, kind: FaultKind, target: usize, now: TimeNs) {
        if let Some(i) = self.open.iter().position(|(k, _)| *k == (kind, target)) {
            let (_, since) = self.open.swap_remove(i);
            self.accrued_ns += now.saturating_sub(since);
        }
    }

    pub fn any_down(&self) -> bool {
        !self.open.is_empty()
    }

    /// Total resource-downtime with open outages closed at `end_ns`.
    pub fn total_ns(&self, end_ns: TimeNs) -> u64 {
        self.accrued_ns
            + self
                .open
                .iter()
                .map(|(_, since)| end_ns.saturating_sub(*since))
                .sum::<u64>()
    }
}

// ------------------------------------------------------------------ report

/// One executed state change in the report timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultTimelineEntry {
    pub at_ns: TimeNs,
    pub kind: &'static str,
    pub target: usize,
    /// `true` = repair/recovery, `false` = failure.
    pub up: bool,
}

/// What the fault schedule did to a run.  Rides on
/// `SimReport::fault`/`FleetReport::fault` only when the armed plan
/// resolved to at least one toggle (zero-perturbation rule); excluded
/// fields never reach a fingerprint unless the report itself is
/// attached.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultReport {
    /// Failure toggles executed.
    pub injected: u64,
    /// Repair toggles executed.
    pub repairs: u64,
    /// Flows re-injected over a rerouted path after a link/router loss.
    pub reroutes: u64,
    /// Flows that could not be rerouted (destination partitioned).
    pub flow_fails: u64,
    /// Requests aborted mid-flight (partition, chiplet kill, board crash).
    pub aborts: u64,
    /// Fleet-level retry dispatches of aborted requests.
    pub retries: u64,
    /// Aborted requests that later completed via retry.
    pub recovered: u64,
    /// Aborted requests dropped after retries/deadline were exhausted.
    pub fault_dropped: u64,
    /// Sensor overlays applied (stuck-at/drift arm events).
    pub sensor_faults: u64,
    /// Requests completed while at least one fault was active.
    pub goodput_under_fault: u64,
    /// `1 - Σ per-resource downtime / (faulted-resource count × span)`;
    /// 1.0 when nothing was ever down.
    pub availability: f64,
    /// Executed failure/repair instants, time-ordered.
    pub timeline: Vec<FaultTimelineEntry>,
}

impl FaultReport {
    /// Fold availability from a downtime tracker over `span_ns`.
    pub fn finish(&mut self, downtime: &DowntimeTracker, span_ns: TimeNs) {
        let resources: std::collections::BTreeSet<(&'static str, usize)> = self
            .timeline
            .iter()
            .filter(|e| !e.up)
            .map(|e| (e.kind, e.target))
            .collect();
        self.availability = if resources.is_empty() || span_ns == 0 {
            1.0
        } else {
            let cap = resources.len() as u64 * span_ns;
            1.0 - (downtime.total_ns(span_ns).min(cap) as f64 / cap as f64)
        };
    }

    /// Merge another report in (fleet aggregation over replicas).
    pub fn merge(&mut self, other: &FaultReport) {
        self.injected += other.injected;
        self.repairs += other.repairs;
        self.reroutes += other.reroutes;
        self.flow_fails += other.flow_fails;
        self.aborts += other.aborts;
        self.retries += other.retries;
        self.recovered += other.recovered;
        self.fault_dropped += other.fault_dropped;
        self.sensor_faults += other.sensor_faults;
        self.goodput_under_fault += other.goodput_under_fault;
        self.timeline.extend(other.timeline.iter().copied());
        self.timeline.sort_by_key(|e| (e.at_ns, e.kind, e.target, e.up));
        // Availability does not merge linearly; the caller re-derives it
        // (fleet keeps the min across replicas as the honest headline).
        self.availability = self.availability.min(other.availability);
    }

    /// Stable digest: every counter plus an FNV fold of the timeline;
    /// floats by bit pattern.
    pub fn fingerprint(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut fold = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for e in &self.timeline {
            fold(e.at_ns);
            fold(e.kind.len() as u64 ^ ((e.kind.as_bytes()[0] as u64) << 8));
            fold(e.target as u64);
            fold(e.up as u64);
        }
        format!(
            "inj={};rep={};rr={};ff={};ab={};rt={};rec={};fd={};sf={};guf={};avail={:016x};tl={:016x}",
            self.injected,
            self.repairs,
            self.reroutes,
            self.flow_fails,
            self.aborts,
            self.retries,
            self.recovered,
            self.fault_dropped,
            self.sensor_faults,
            self.goodput_under_fault,
            self.availability.to_bits(),
            h,
        )
    }

    /// Human-readable roll-up.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "faults: {} injected, {} repaired | availability {:.4} | \
             {} rerouted, {} flow-failed, {} aborted | \
             {} retries, {} recovered, {} dropped-by-fault | {} served under fault\n",
            self.injected,
            self.repairs,
            self.availability,
            self.reroutes,
            self.flow_fails,
            self.aborts,
            self.retries,
            self.recovered,
            self.fault_dropped,
            self.goodput_under_fault,
        );
        for e in &self.timeline {
            let _ = writeln!(
                s,
                "  {} @{:.3} ms: {} {}",
                if e.up { "repair" } else { "fail  " },
                e.at_ns as f64 / 1e6,
                e.kind,
                e.target,
            );
        }
        s
    }

    /// JSON document (`schema: chipsim-fault-v1`) gated by
    /// `python/fault_check.py` in CI.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let timeline: Vec<Value> = self
            .timeline
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("at_ns", Value::from(e.at_ns)),
                    ("kind", Value::from(e.kind)),
                    ("target", Value::from(e.target as u64)),
                    ("up", Value::from(e.up)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("schema", Value::from("chipsim-fault-v1")),
            ("injected", Value::from(self.injected)),
            ("repairs", Value::from(self.repairs)),
            ("reroutes", Value::from(self.reroutes)),
            ("flow_fails", Value::from(self.flow_fails)),
            ("aborts", Value::from(self.aborts)),
            ("retries", Value::from(self.retries)),
            ("recovered", Value::from(self.recovered)),
            ("fault_dropped", Value::from(self.fault_dropped)),
            ("sensor_faults", Value::from(self.sensor_faults)),
            ("goodput_under_fault", Value::from(self.goodput_under_fault)),
            ("availability", Value::from(self.availability)),
            ("timeline", Value::Arr(timeline)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "link:2-3@1ms, router:5@2ms+500us, chiplet:7@1ms+4ms, \
             sensor:3:stuck=95@1ms; sensor:0:drift=0.5@0, board:1@5ms, \
             seed=42, retry=5:100us:1ms:10ms, link:?@250us%500us*4",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.retry.max_attempts, 5);
        assert_eq!(p.retry.backoff_ns, 100_000);
        assert_eq!(p.retry.deadline_ns, 10_000_000);
        assert_eq!(p.events.len(), 7);
        assert_eq!(p.events[0].kind, FaultKind::Link);
        assert_eq!(p.events[0].target, FaultTarget::NodePair(2, 3));
        assert_eq!(p.events[0].at_ns, 1_000_000);
        assert_eq!(p.events[0].repair_ns, None);
        assert_eq!(p.events[1].repair_ns, Some(500_000));
        assert_eq!(p.events[3].sensor, Some(SensorMode::StuckAt(95.0)));
        assert_eq!(p.events[4].sensor, Some(SensorMode::DriftPerMs(0.5)));
        assert_eq!(p.events[5].kind, FaultKind::Board);
        let flap = &p.events[6];
        assert_eq!(flap.target, FaultTarget::Random);
        assert_eq!(flap.period_ns, Some(500_000));
        assert_eq!(flap.repair_ns, Some(250_000), "default down time is period / 2");
        assert_eq!(flap.repeats, 4);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "link:2-3",            // no schedule
            "link:23@1ms",         // not a pair
            "warp:1@1ms",          // unknown kind
            "sensor:3@1ms",        // missing mode
            "sensor:3:wobble=1@0", // unknown mode
            "chiplet:x@1ms",       // bad index
            "link:0-1@1ms%0",      // zero period
            "link:0-1@0+2ms%1ms",  // down >= period
            "board:0@1ms+2ms",     // board repair unsupported
            "retry=1:2:3",         // short retry tuple
        ] {
            let r = FaultPlan::parse(bad).and_then(|p| {
                p.arm_boards(4)?;
                p.arm(&FaultDims { links: 10, nodes: 5, chiplets: 5 })
            });
            assert!(r.is_err(), "'{bad}' should not parse/arm");
        }
    }

    #[test]
    fn arming_is_deterministic_and_sorted() {
        let dims = FaultDims { links: 24, nodes: 9, chiplets: 9 };
        let p = FaultPlan::parse("link:?@1ms+200us%1ms*3, chiplet:?@500us, seed=7").unwrap();
        let a = p.arm(&dims).unwrap();
        let b = p.arm(&dims).unwrap();
        assert_eq!(a, b, "same plan, same toggles");
        assert_eq!(a.len(), 3 * 2 + 1);
        assert!(a.windows(2).all(|w| w[0].at_ns <= w[1].at_ns), "sorted by time");
        let q = FaultPlan::parse("link:?@1ms+200us%1ms*3, chiplet:?@500us, seed=8").unwrap();
        assert_ne!(p.arm(&dims).unwrap(), q.arm(&dims).unwrap(), "seed moves random targets");
    }

    #[test]
    fn arm_validates_targets() {
        let dims = FaultDims { links: 4, nodes: 4, chiplets: 4 };
        for bad in ["link:0-9@1ms", "router:4@0", "chiplet:17@0", "sensor:5:stuck=9@0"] {
            assert!(FaultPlan::parse(bad).unwrap().arm(&dims).is_err(), "{bad}");
        }
        assert!(FaultPlan::parse("board:9@0").unwrap().arm_boards(4).is_err());
        // Board events are invisible to board-level arming.
        let p = FaultPlan::parse("board:1@5ms").unwrap();
        assert!(p.arm(&dims).unwrap().is_empty());
        assert_eq!(p.arm_boards(4).unwrap(), vec![(5_000_000, 1)]);
    }

    #[test]
    fn retry_backoff_caps() {
        let r = RetryPolicy { max_attempts: 9, backoff_ns: 100, backoff_cap_ns: 450, deadline_ns: 1 << 40 };
        assert_eq!(r.backoff_for(1), 100);
        assert_eq!(r.backoff_for(2), 200);
        assert_eq!(r.backoff_for(3), 400);
        assert_eq!(r.backoff_for(4), 450);
        assert_eq!(r.backoff_for(63), 450);
    }

    #[test]
    fn downtime_tracker_integrates_open_and_closed_outages() {
        let mut d = DowntimeTracker::default();
        d.down(FaultKind::Link, 3, 100);
        d.down(FaultKind::Link, 3, 150); // re-down of an open outage: no-op
        d.up(FaultKind::Link, 3, 300);
        assert_eq!(d.total_ns(1_000), 200);
        d.down(FaultKind::Chiplet, 0, 600);
        assert!(d.any_down());
        assert_eq!(d.total_ns(1_000), 200 + 400);
        let mut r = FaultReport {
            timeline: vec![
                FaultTimelineEntry { at_ns: 100, kind: "link", target: 3, up: false },
                FaultTimelineEntry { at_ns: 300, kind: "link", target: 3, up: true },
                FaultTimelineEntry { at_ns: 600, kind: "chiplet", target: 0, up: false },
            ],
            ..FaultReport::default()
        };
        r.finish(&d, 1_000);
        // Two faulted resources over a 1000 ns span, 600 ns down total.
        assert!((r.availability - (1.0 - 600.0 / 2_000.0)).abs() < 1e-12);
        let empty = FaultReport::default();
        let mut e2 = empty.clone();
        e2.finish(&DowntimeTracker::default(), 1_000);
        assert_eq!(e2.availability, 1.0);
    }

    #[test]
    fn fingerprint_tracks_content() {
        let mut a = FaultReport::default();
        a.injected = 2;
        a.timeline.push(FaultTimelineEntry { at_ns: 5, kind: "link", target: 1, up: false });
        let mut b = a.clone();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.timeline[0].up = true;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
