//! Hardware-validation substrate (paper §V-F).
//!
//! The paper validates CHIPSIM against an AMD Ryzen Threadripper PRO
//! 7985WX (8 CCDs + IOD, GMI3 links, DDR5).  That silicon is unavailable
//! here, so per DESIGN.md §3 we build a **golden-model emulator**: a fluid
//! (fair-share bandwidth) executor of the paper's macro-kernel workload
//! with the measured saturation behaviour of Fig. 11 baked in:
//!
//! * single-CCD read bandwidth saturates at ~49 GB/s (≈90 % of the GMI3
//!   32 B/cy × 1.733 GHz peak), write at ~27 GB/s (≈98 % of 16 B/cy);
//! * aggregate read saturates at ~270 GB/s and write at ~115 GB/s as DDR
//!   congestion kicks in (≈83 % of the 330 GB/s DDR5 peak).
//!
//! Table VII then compares this golden model against "CHIPSIM": the same
//! load→compute→store traces driven through CHIPSIM's own components
//! (CCD-star topology + packet engine + analytical CPU backend), which is
//! exactly the modular-backend swap the paper performs.

use crate::config::{ChipletTypeParams, HardwareConfig};
use crate::noc::engine::PacketEngine;
use crate::noc::topology::Topology;
use crate::noc::{FlowSpec, NetworkSim};
use crate::workload::{ModelKind, NeuralModel};
use crate::TimeNs;

// Measured bandwidth envelope (GB/s) — Fig. 11 ground truth.
pub const CCD_READ_PEAK_GBS: f64 = 49.0;
pub const CCD_WRITE_PEAK_GBS: f64 = 27.0;
pub const DDR_READ_PEAK_GBS: f64 = 270.0;
pub const DDR_WRITE_PEAK_GBS: f64 = 115.0;
/// Per-thread bandwidth before the link saturates (GB/s).
pub const READ_PER_THREAD_GBS: f64 = 8.5;
pub const WRITE_PER_THREAD_GBS: f64 = 5.2;
/// Sustained int8 MAC throughput per CCD (GMAC/s), micro-kernel measured.
pub const CCD_MAC_RATE_GOPS: f64 = 280.0;

// ------------------------------------------------------- Fig. 11 curves

/// Single-CCD read bandwidth as a function of active threads (Fig. 11a).
pub fn ccd_read_bw_gbs(threads: usize) -> f64 {
    (threads as f64 * READ_PER_THREAD_GBS).min(CCD_READ_PEAK_GBS)
}

/// Single-CCD write bandwidth vs threads (Fig. 11b).
pub fn ccd_write_bw_gbs(threads: usize) -> f64 {
    (threads as f64 * WRITE_PER_THREAD_GBS).min(CCD_WRITE_PEAK_GBS)
}

/// Aggregate read bandwidth vs active CCDs, 8 threads each (Fig. 11c).
pub fn aggregate_read_bw_gbs(ccds: usize) -> f64 {
    (ccds as f64 * CCD_READ_PEAK_GBS).min(DDR_READ_PEAK_GBS)
}

/// Aggregate write bandwidth vs active CCDs (Fig. 11d).
pub fn aggregate_write_bw_gbs(ccds: usize) -> f64 {
    (ccds as f64 * CCD_WRITE_PEAK_GBS).min(DDR_WRITE_PEAK_GBS)
}

// -------------------------------------------------------- macro kernels

/// One phase of the macro-kernel workload (paper §V-F: configurable
/// load / compute / store loop).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// Read `bytes` from DDR.
    Load(u64),
    /// Execute `macs` multiply-accumulates.
    Compute(u64),
    /// Write `bytes` to DDR.
    Store(u64),
}

/// Convert a DNN model into its layer-wise macro-kernel trace:
/// per layer, load weights+activations, compute, store activations.
pub fn model_trace(kind: ModelKind) -> Vec<Phase> {
    let model = NeuralModel::build(kind);
    let mut trace = Vec::with_capacity(model.layers.len() * 3);
    for l in &model.layers {
        trace.push(Phase::Load(l.weight_bytes + l.in_bytes));
        trace.push(Phase::Compute(l.macs));
        trace.push(Phase::Store(l.out_bytes));
    }
    trace
}

// ----------------------------------------------------- golden emulator

/// Fluid-model execution of per-CCD traces with fair-share DDR bandwidth.
///
/// At every instant, each CCD in a Load (Store) phase receives
/// `min(ccd_peak, ddr_peak / n_active_loaders)` GB/s; Compute phases run
/// at the fixed MAC rate.  The simulation advances from phase-completion
/// to phase-completion (piecewise-constant rates => exact integration).
/// Returns the completion time of each CCD's trace, in ns.
pub fn emulate(traces: &[Vec<Phase>]) -> Vec<f64> {
    #[derive(Clone)]
    struct St {
        idx: usize,
        /// Remaining work in the current phase (bytes or MACs).
        rem: f64,
        done_at: f64,
    }
    let mut st: Vec<St> = traces
        .iter()
        .map(|t| St {
            idx: 0,
            rem: t.first().map(phase_amount).unwrap_or(0.0),
            done_at: 0.0,
        })
        .collect();
    let mut now = 0.0f64; // ns
    loop {
        // Active phase sets.
        let active = |pred: fn(&Phase) -> bool| -> Vec<usize> {
            (0..traces.len())
                .filter(|&i| st[i].idx < traces[i].len() && pred(&traces[i][st[i].idx]))
                .collect()
        };
        let loaders: Vec<usize> = active(|p| matches!(p, Phase::Load(_)));
        let storers: Vec<usize> = active(|p| matches!(p, Phase::Store(_)));
        let computers: Vec<usize> = active(|p| matches!(p, Phase::Compute(_)));
        if loaders.is_empty() && storers.is_empty() && computers.is_empty() {
            break;
        }
        // Rates (per ns): GB/s == bytes/ns; GMAC/s == MACs/ns... careful:
        // 1 GB/s = 1e9 B / 1e9 ns = 1 B/ns.  1 GMAC/s = 1 MAC/ns? No:
        // 1 GOPS = 1e9 ops/s = 1 op/ns.  Both are unit/ns at Giga scale.
        let rd_share = (DDR_READ_PEAK_GBS / loaders.len().max(1) as f64).min(CCD_READ_PEAK_GBS);
        let wr_share = (DDR_WRITE_PEAK_GBS / storers.len().max(1) as f64).min(CCD_WRITE_PEAK_GBS);
        let rates: Vec<f64> = (0..traces.len())
            .map(|i| {
                if st[i].idx >= traces[i].len() {
                    return 0.0;
                }
                match traces[i][st[i].idx] {
                    Phase::Load(_) => rd_share,
                    Phase::Store(_) => wr_share,
                    Phase::Compute(_) => CCD_MAC_RATE_GOPS,
                }
            })
            .collect();
        // Time until the earliest phase completion at current rates.
        let mut dt = f64::INFINITY;
        for &i in loaders.iter().chain(&storers).chain(&computers) {
            dt = dt.min(st[i].rem / rates[i]);
        }
        now += dt;
        // Progress everyone; advance finished phases.
        for &i in loaders.iter().chain(&storers).chain(&computers) {
            st[i].rem -= dt * rates[i];
            if st[i].rem <= 1e-9 {
                st[i].idx += 1;
                if st[i].idx < traces[i].len() {
                    st[i].rem = phase_amount(&traces[i][st[i].idx]);
                } else {
                    st[i].done_at = now;
                }
            }
        }
    }
    st.iter()
        .map(|s| if s.done_at > 0.0 { s.done_at } else { now })
        .collect()
}

fn phase_amount(p: &Phase) -> f64 {
    match p {
        Phase::Load(b) | Phase::Store(b) => *b as f64,
        Phase::Compute(m) => *m as f64,
    }
}

// ------------------------------------------------- CHIPSIM-components run

/// The same traces driven through CHIPSIM's own substrate: the CCD-star
/// topology, the packet-level network engine (bandwidth-calibrated links)
/// and the analytical CPU compute model.  This is the "simulated" column
/// of Table VII.
pub fn chipsim_ccd_run(traces: &[Vec<Phase>]) -> Vec<f64> {
    let hw = HardwareConfig::ccd_star(8);
    let topo = Topology::build(&hw);
    let ddr = 9usize;
    let mut net = PacketEngine::new(topo);
    let cpu = ChipletTypeParams::cpu_ccd();

    #[derive(Debug)]
    struct St {
        idx: usize,
        done_at: TimeNs,
        waiting_flow: Option<crate::noc::FlowId>,
    }
    let mut st: Vec<St> = traces
        .iter()
        .map(|_| St { idx: 0, done_at: 0, waiting_flow: None })
        .collect();
    let mut events: std::collections::BinaryHeap<std::cmp::Reverse<(TimeNs, usize)>> =
        std::collections::BinaryHeap::new();

    // Kick off phase 0 of every CCD at t=0.
    let start_phase = |i: usize,
                           t: TimeNs,
                           st: &mut Vec<St>,
                           net: &mut PacketEngine,
                           events: &mut std::collections::BinaryHeap<
        std::cmp::Reverse<(TimeNs, usize)>,
    >| {
        if st[i].idx >= traces[i].len() {
            st[i].done_at = t;
            return;
        }
        match traces[i][st[i].idx] {
            Phase::Load(bytes) => {
                let id = net.inject(FlowSpec { src: ddr, dst: i, bytes }, t);
                st[i].waiting_flow = Some(id);
            }
            Phase::Store(bytes) => {
                let id = net.inject(FlowSpec { src: i, dst: ddr, bytes }, t);
                st[i].waiting_flow = Some(id);
            }
            Phase::Compute(macs) => {
                let lat = (cpu.base_latency_ns + macs as f64 / CCD_MAC_RATE_GOPS).round() as TimeNs;
                events.push(std::cmp::Reverse((t + lat, i)));
            }
        }
    };
    for i in 0..traces.len() {
        start_phase(i, 0, &mut st, &mut net, &mut events);
    }

    loop {
        let t_next = events.peek().map(|&std::cmp::Reverse((t, _))| t).unwrap_or(TimeNs::MAX);
        if net.has_active() {
            if let Some(c) = net.advance_until(t_next) {
                // Which CCD was waiting on this flow?
                if let Some(i) = st.iter().position(|s| s.waiting_flow == Some(c.id)) {
                    st[i].waiting_flow = None;
                    st[i].idx += 1;
                    start_phase(i, c.time, &mut st, &mut net, &mut events);
                }
                continue;
            }
        }
        let Some(std::cmp::Reverse((t, i))) = events.pop() else {
            break;
        };
        st[i].idx += 1;
        start_phase(i, t, &mut st, &mut net, &mut events);
    }
    st.iter().map(|s| s.done_at as f64).collect()
}

/// Percent difference between CHIPSIM-run and golden-emulator times.
pub fn percent_diff(sim: f64, hw: f64) -> f64 {
    (sim - hw).abs() / hw * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_curves_saturate_at_measured_peaks() {
        assert!(ccd_read_bw_gbs(1) < ccd_read_bw_gbs(4));
        assert_eq!(ccd_read_bw_gbs(8), CCD_READ_PEAK_GBS);
        assert_eq!(ccd_write_bw_gbs(8), CCD_WRITE_PEAK_GBS);
        assert_eq!(aggregate_read_bw_gbs(8), DDR_READ_PEAK_GBS);
        assert_eq!(aggregate_write_bw_gbs(8), DDR_WRITE_PEAK_GBS);
        // Below saturation the aggregate scales linearly.
        assert_eq!(aggregate_read_bw_gbs(2), 2.0 * CCD_READ_PEAK_GBS);
    }

    #[test]
    fn emulator_single_ccd_hand_calc() {
        // 49 GB load at 49 GB/s = 1 s; 280 GMACs at 280 GMAC/s = 1 s;
        // 27 GB store at 27 GB/s = 1 s.  Total 3e9 ns.
        let t = vec![vec![
            Phase::Load(49_000_000_000),
            Phase::Compute(280_000_000_000),
            Phase::Store(27_000_000_000),
        ]];
        let done = emulate(&t);
        assert!((done[0] - 3e9).abs() / 3e9 < 1e-6, "{}", done[0]);
    }

    #[test]
    fn emulator_ddr_contention_slows_many_ccds() {
        let one = vec![vec![Phase::Load(10_000_000_000)]];
        let solo = emulate(&one)[0];
        let eight: Vec<Vec<Phase>> = (0..8).map(|_| vec![Phase::Load(10_000_000_000)]).collect();
        let crowd = emulate(&eight);
        // 8 loaders share 270 GB/s => 33.75 GB/s each < 49 solo.
        assert!(crowd[0] > solo * 1.3, "crowd {} solo {solo}", crowd[0]);
        // All finish simultaneously (symmetric).
        for d in &crowd {
            assert!((d - crowd[0]).abs() < 1.0);
        }
    }

    #[test]
    fn emulator_compute_is_uncontended() {
        let one = vec![vec![Phase::Compute(1_000_000_000)]];
        let eight: Vec<Vec<Phase>> = (0..8).map(|_| vec![Phase::Compute(1_000_000_000)]).collect();
        let solo = emulate(&one)[0];
        let crowd = emulate(&eight);
        assert!((crowd[0] - solo).abs() / solo < 1e-9);
    }

    #[test]
    fn chipsim_run_close_to_emulator_single_alexnet() {
        // Table VII row 1: one CCD, AlexNet.  The two models use different
        // mechanisms (fluid vs packet queues) so we accept < 15% here; the
        // bench reports the real number.
        let traces = vec![model_trace(ModelKind::AlexNet)];
        let hw = emulate(&traces)[0];
        let sim = chipsim_ccd_run(&traces)[0];
        let diff = percent_diff(sim, hw);
        assert!(diff < 15.0, "sim {sim} vs hw {hw}: {diff}%");
    }

    #[test]
    fn traces_cover_all_layers() {
        let t = model_trace(ModelKind::ResNet18);
        let m = NeuralModel::build(ModelKind::ResNet18);
        assert_eq!(t.len(), m.layers.len() * 3);
    }
}
