//! The paper's two baseline estimation approaches (§V-A "Baseline
//! Comparisons"), reusing CHIPSIM's own mapper, topology, network engine
//! and compute backend — but **decoupled** and **single-model**:
//!
//! * **Comm. Only** — the NoI-exploration style [17, 18]: only network
//!   transfers are simulated, layer by layer, alone on an empty network;
//!   compute time is ignored.
//! * **Comm. + Compute** — the SIAM/HISIM style [23, 24]: per-layer
//!   compute latency plus per-boundary solo communication latency, summed.
//!   No contention between models, no pipelining overlap.
//!
//! Both therefore *underestimate* end-to-end inference latency whenever
//! the system is shared or pipelined; quantifying that gap versus the
//! co-simulation is exactly the paper's Tables IV–VI and Figs. 6/10.

use crate::compute::{ClassDispatchBackend, ComputeBackend};
use crate::config::HardwareConfig;
use crate::mapping::{MemoryLedger, ModelMapping, NearestNeighborMapper};
use crate::noc::engine::PacketEngine;
use crate::noc::topology::Topology;
use crate::noc::{FlowSpec, NetworkSim};
use crate::workload::{ModelKind, NeuralModel};
use crate::TimeNs;

/// Decoupled baseline estimator.
pub struct BaselineEstimator {
    hw: HardwareConfig,
    topo: Topology,
    backend: Box<dyn ComputeBackend>,
}

/// Per-model baseline estimate.
#[derive(Debug, Clone, Copy)]
pub struct BaselineEstimate {
    /// End-to-end latency of one inference, ns.
    pub inference_latency_ns: f64,
    /// Compute portion, ns.
    pub compute_ns: f64,
    /// Communication portion, ns.
    pub comm_ns: f64,
}

impl BaselineEstimator {
    pub fn new(hw: HardwareConfig) -> Self {
        let topo = Topology::build(&hw);
        BaselineEstimator { hw, topo, backend: Box::new(ClassDispatchBackend::new()) }
    }

    pub fn with_backend(mut self, backend: Box<dyn ComputeBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Map the model alone on an empty system (single model in the system
    /// at a time — the baselines' core assumption).
    fn solo_mapping(&self, model: &NeuralModel) -> Option<ModelMapping> {
        let mut ledger = MemoryLedger::new(&self.hw);
        NearestNeighborMapper::new(&self.hw, &self.topo).try_map(model, &mut ledger)
    }

    /// Simulate one layer boundary's flows alone on an empty network and
    /// return the end-to-end completion time.
    fn solo_comm_ns(&self, model: &NeuralModel, mapping: &ModelMapping, layer: usize) -> f64 {
        let mut net = PacketEngine::new(self.topo.clone());
        let out_bytes = model.layers[layer].out_bytes;
        for s in &mapping.layers[layer] {
            let bytes = ((out_bytes as f64) * s.frac).ceil().max(1.0) as u64;
            for d in &mapping.layers[layer + 1] {
                net.inject(FlowSpec { src: s.chiplet, dst: d.chiplet, bytes }, 0);
            }
        }
        let mut last = 0;
        while let Some(c) = net.advance_until(TimeNs::MAX) {
            last = last.max(c.time);
        }
        last as f64
    }

    /// Weight-load time for weight-stationary systems with I/O chiplets
    /// (ViT §V-E) — both baselines do account for this fixed start-up.
    fn solo_weight_load_ns(&self, mapping: &ModelMapping) -> f64 {
        if self.hw.io_chiplets.is_empty() {
            return 0.0;
        }
        let mut net = PacketEngine::new(self.topo.clone());
        for layer in &mapping.layers {
            for seg in layer {
                let io = *self
                    .hw
                    .io_chiplets
                    .iter()
                    .min_by_key(|&&io| self.topo.hops(io, seg.chiplet).unwrap_or(usize::MAX))
                    .unwrap();
                net.inject(FlowSpec { src: io, dst: seg.chiplet, bytes: seg.mem_bytes }, 0);
            }
        }
        let mut last = 0;
        while let Some(c) = net.advance_until(TimeNs::MAX) {
            last = last.max(c.time);
        }
        last as f64
    }

    fn estimate(&mut self, kind: ModelKind, with_compute: bool) -> Option<BaselineEstimate> {
        let model = NeuralModel::build(kind);
        let mapping = self.solo_mapping(&model)?;
        let mut comm = 0.0;
        for l in 0..model.layers.len() - 1 {
            comm += self.solo_comm_ns(&model, &mapping, l);
        }
        let mut compute = 0.0;
        if with_compute {
            for (l, layer) in mapping.layers.iter().enumerate() {
                let _ = l;
                let worst = layer
                    .iter()
                    .map(|seg| {
                        self.backend
                            .evaluate(self.hw.chiplet_type(seg.chiplet), &seg.work)
                            .latency_ns
                    })
                    .fold(0.0f64, f64::max);
                compute += worst;
            }
        }
        Some(BaselineEstimate {
            inference_latency_ns: comm + compute,
            compute_ns: compute,
            comm_ns: comm,
        })
    }

    /// "Comm. Only" baseline: network transfers only.
    pub fn comm_only(&mut self, kind: ModelKind) -> Option<BaselineEstimate> {
        self.estimate(kind, false)
    }

    /// "Comm. + Compute" baseline: decoupled per-layer compute + comm.
    pub fn comm_compute(&mut self, kind: ModelKind) -> Option<BaselineEstimate> {
        self.estimate(kind, true)
    }

    /// Amortized per-inference latency over `n` back-to-back inferences,
    /// including the one-time weight load (relevant for ViT, Fig. 10):
    /// (load + n * inference) / n.
    pub fn amortized_with_weight_load(
        &mut self,
        kind: ModelKind,
        n: u32,
        with_compute: bool,
    ) -> Option<f64> {
        let model = NeuralModel::build(kind);
        let mapping = self.solo_mapping(&model)?;
        let load = self.solo_weight_load_ns(&mapping);
        let est = self.estimate(kind, with_compute)?;
        Some((load + n as f64 * est.inference_latency_ns) / n as f64)
    }

    /// Decoupled estimate of a *pipelined* `n`-inference run:
    /// weight load + first-inference latency + (n−1) × ideal initiation
    /// interval, where the II is the slowest pipeline stage (a layer's
    /// compute or a boundary's solo communication).  This is how a
    /// SIAM/HISIM-style model extrapolates pipelining — it has no notion
    /// of contention *between* the pipelined inputs, which is exactly the
    /// gap CHIPSIM exposes (paper Fig. 10).
    pub fn pipelined_total_with_weight_load(
        &mut self,
        kind: ModelKind,
        n: u32,
        with_compute: bool,
    ) -> Option<f64> {
        let model = NeuralModel::build(kind);
        let mapping = self.solo_mapping(&model)?;
        let load = self.solo_weight_load_ns(&mapping);
        let est = self.estimate(kind, with_compute)?;
        let mut ii: f64 = 0.0;
        for l in 0..model.layers.len() {
            if with_compute {
                let worst = mapping.layers[l]
                    .iter()
                    .map(|seg| {
                        self.backend
                            .evaluate(self.hw.chiplet_type(seg.chiplet), &seg.work)
                            .latency_ns
                    })
                    .fold(0.0f64, f64::max);
                ii = ii.max(worst);
            }
            if l + 1 < model.layers.len() {
                ii = ii.max(self.solo_comm_ns(&model, &mapping, l));
            }
        }
        Some(load + est.inference_latency_ns + (n.saturating_sub(1)) as f64 * ii)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_compute_exceeds_comm_only() {
        let hw = HardwareConfig::homogeneous_mesh(10, 10);
        let mut b = BaselineEstimator::new(hw);
        for kind in crate::workload::ALL_CNNS {
            let co = b.comm_only(kind).unwrap();
            let cc = b.comm_compute(kind).unwrap();
            assert!(cc.inference_latency_ns > co.inference_latency_ns, "{kind:?}");
            assert_eq!(co.compute_ns, 0.0);
            assert!((co.comm_ns - cc.comm_ns).abs() < 1e-9);
        }
    }

    #[test]
    fn baseline_underestimates_cosim_with_parallel_models() {
        use crate::config::{SimParams, WorkloadConfig};
        use crate::sim::Simulation;
        let hw = HardwareConfig::homogeneous_mesh(10, 10);
        let mut b = BaselineEstimator::new(hw.clone());
        let base = b.comm_compute(ModelKind::ResNet18).unwrap();
        let params = SimParams {
            pipelined: true,
            inferences_per_model: 5,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        let report = Simulation::builder()
            .hardware(hw)
            .params(params)
            .build()
            .unwrap()
            .run(WorkloadConfig::from_kinds(&[ModelKind::ResNet18; 6]))
            .unwrap();
        let chipsim = report.mean_latency_of(ModelKind::ResNet18).unwrap();
        assert!(
            chipsim > base.inference_latency_ns,
            "co-sim {chipsim} !> baseline {}",
            base.inference_latency_ns
        );
    }

    #[test]
    fn unmappable_model_estimates_none() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let mut b = BaselineEstimator::new(hw);
        assert!(b.comm_only(ModelKind::AlexNet).is_none());
    }

    #[test]
    fn weight_load_amortizes_out() {
        let hw = HardwareConfig::vit_mesh(10, 10);
        let mut b = BaselineEstimator::new(hw);
        let at1 = b.amortized_with_weight_load(ModelKind::VitB16, 1, true).unwrap();
        let at20 = b.amortized_with_weight_load(ModelKind::VitB16, 20, true).unwrap();
        assert!(at1 > at20, "{at1} !> {at20}");
    }
}
