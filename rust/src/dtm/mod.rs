//! Closed-loop dynamic thermal management (DTM).
//!
//! The paper motivates microsecond-granularity power profiling with
//! "precise transient thermal analysis" (§IV-C/§V-D); this subsystem
//! closes the loop so temperature can act back on execution *during* the
//! run instead of being a post-mortem:
//!
//! ```text
//!   power bins ──drain──▶ ThermalStepper ──temps──▶ SensorBank
//!        ▲                                              │ readings
//!        │                                              ▼
//!   compute latency/energy ◀──f/V state── Governor (threshold / PID)
//! ```
//!
//! Every `window_ns` of virtual time the [`DtmRuntime`] drains the just-
//! closed power window, advances the RC network one window
//! ([`ThermalStepper`](crate::thermal::stepper::ThermalStepper)), polls
//! the per-chiplet [`SensorBank`] (quantized + noisy, seed-
//! deterministic), and lets the configured [`Governor`] pick each
//! chiplet's operating point from a discrete [`DvfsTable`].  The chosen
//! state scales the latency and dynamic energy of *subsequently issued*
//! compute segments (in-flight work finishes at its issued rate) through
//! the hooks in `sim::simulation`.
//!
//! Enable it on any simulation — batch or sustained traffic — with
//! `ThermalSpec::InLoop { window_ns, governor }`; the run then attaches
//! a [`DtmReport`] (throttle residency, ceiling violations, temperature
//! and frequency timelines) to the `SimReport` / `TrafficReport`.  From
//! the CLI: `chipsim dtm` (see `chipsim dtm --help`).

pub mod governor;
pub mod sensors;

use std::collections::VecDeque;

pub use governor::{Governor, NoOpGovernor, PidDvfs, ThresholdThrottle};
pub use sensors::{SensorBank, SensorSpec};

use crate::config::HardwareConfig;
use crate::power::PowerTracker;
use crate::sim::StreamSink;
use crate::thermal::stepper::ThermalStepper;
use crate::TimeNs;

// ------------------------------------------------------------ DVFS table

/// One discrete operating point.  Frequency scales compute latency as
/// `1/freq_scale`; dynamic energy per operation scales as `volt_scale²`
/// (CMOS `E ∝ C·V²`; the `f·V²` power factor follows because the same
/// work then takes `1/f` longer).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DvfsState {
    pub freq_scale: f64,
    pub volt_scale: f64,
}

impl DvfsState {
    pub fn latency_factor(&self) -> f64 {
        1.0 / self.freq_scale.max(1e-6)
    }

    pub fn energy_factor(&self) -> f64 {
        self.volt_scale * self.volt_scale
    }
}

/// Ordered table of operating points, fastest first.
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsTable {
    pub states: Vec<DvfsState>,
}

impl DvfsTable {
    /// The default ladder: nominal plus three throttle steps down to
    /// 0.4× frequency at 0.7× voltage (≈5× lower dynamic power density).
    pub fn default_four() -> DvfsTable {
        DvfsTable {
            states: vec![
                DvfsState { freq_scale: 1.0, volt_scale: 1.0 },
                DvfsState { freq_scale: 0.8, volt_scale: 0.9 },
                DvfsState { freq_scale: 0.6, volt_scale: 0.8 },
                DvfsState { freq_scale: 0.4, volt_scale: 0.7 },
            ],
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.states.is_empty(), "DVFS table has no states");
        for (i, s) in self.states.iter().enumerate() {
            anyhow::ensure!(
                s.freq_scale > 0.0 && s.freq_scale <= 1.0,
                "DVFS state {i}: freq_scale {} outside (0, 1]",
                s.freq_scale
            );
            anyhow::ensure!(
                s.volt_scale > 0.0 && s.volt_scale <= 1.0,
                "DVFS state {i}: volt_scale {} outside (0, 1]",
                s.volt_scale
            );
        }
        for w in self.states.windows(2) {
            anyhow::ensure!(
                w[1].freq_scale < w[0].freq_scale,
                "DVFS table must be ordered fastest first (strictly decreasing freq_scale)"
            );
        }
        anyhow::ensure!(
            (self.states[0].freq_scale - 1.0).abs() < 1e-12,
            "DVFS state 0 must be the nominal 1.0x point"
        );
        Ok(())
    }

    pub fn min_freq_scale(&self) -> f64 {
        self.states.last().map(|s| s.freq_scale).unwrap_or(1.0)
    }

    /// Index of the state whose frequency is closest to `want` (ties go
    /// to the faster state).
    pub fn nearest(&self, want_freq: f64) -> usize {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, s) in self.states.iter().enumerate() {
            let d = (s.freq_scale - want_freq).abs();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }
}

// --------------------------------------------------------- configuration

/// Which control policy drives the loop.
#[derive(Debug, Clone, PartialEq)]
pub enum GovernorPolicy {
    /// Uncontrolled baseline: full speed always.
    NoOp,
    /// Hysteresis-band reactive throttle.
    ThresholdThrottle { hot_c: f64, cold_c: f64 },
    /// Per-chiplet PID toward `target_c`.
    PidDvfs { target_c: f64, kp: f64, ki: f64, kd: f64 },
}

/// Complete control-loop configuration: policy, sensor fidelity, DVFS
/// table, and reporting knobs.  This is the `governor` payload of
/// `ThermalSpec::InLoop { window_ns, governor }`.
#[derive(Debug, Clone, PartialEq)]
pub struct GovernorSpec {
    pub policy: GovernorPolicy,
    pub sensors: SensorSpec,
    pub table: DvfsTable,
    /// Thermal ceiling for violation accounting (and the default anchor
    /// the convenience constructors derive their setpoints from), °C.
    pub ceiling_c: f64,
    /// Power bins per implicit-Euler step inside a control window
    /// (0 = one step per window).
    pub stride_bins: usize,
    /// Trailing per-window samples kept in the [`DtmReport`] timeline.
    pub keep_timeline: usize,
}

impl GovernorSpec {
    fn base(policy: GovernorPolicy, ceiling_c: f64) -> GovernorSpec {
        GovernorSpec {
            policy,
            sensors: SensorSpec::default(),
            table: DvfsTable::default_four(),
            ceiling_c,
            stride_bins: 0,
            keep_timeline: 1024,
        }
    }

    /// Uncontrolled baseline that still steps thermal and reports
    /// ceiling violations.
    pub fn noop(ceiling_c: f64) -> GovernorSpec {
        GovernorSpec::base(GovernorPolicy::NoOp, ceiling_c)
    }

    /// Threshold throttle with a default band just under the ceiling
    /// (hot = ceiling − 1 °C, cold = ceiling − 3 °C).
    pub fn threshold(ceiling_c: f64) -> GovernorSpec {
        GovernorSpec::base(
            GovernorPolicy::ThresholdThrottle { hot_c: ceiling_c - 1.0, cold_c: ceiling_c - 3.0 },
            ceiling_c,
        )
    }

    /// Threshold throttle with an explicit hysteresis band.
    pub fn threshold_band(hot_c: f64, cold_c: f64, ceiling_c: f64) -> GovernorSpec {
        GovernorSpec::base(GovernorPolicy::ThresholdThrottle { hot_c, cold_c }, ceiling_c)
    }

    /// PID toward `target_c` with default gains; the reporting ceiling
    /// sits 2 °C above the target.
    pub fn pid(target_c: f64) -> GovernorSpec {
        GovernorSpec::base(
            GovernorPolicy::PidDvfs { target_c, kp: 0.08, ki: 0.02, kd: 0.04 },
            target_c + 2.0,
        )
    }

    pub fn sensors(mut self, sensors: SensorSpec) -> GovernorSpec {
        self.sensors = sensors;
        self
    }

    /// Override the reporting ceiling (the convenience constructors
    /// derive a default from their setpoint).
    pub fn ceiling(mut self, ceiling_c: f64) -> GovernorSpec {
        self.ceiling_c = ceiling_c;
        self
    }

    pub fn table(mut self, table: DvfsTable) -> GovernorSpec {
        self.table = table;
        self
    }

    pub fn stride_bins(mut self, stride: usize) -> GovernorSpec {
        self.stride_bins = stride;
        self
    }

    pub fn keep_timeline(mut self, n: usize) -> GovernorSpec {
        self.keep_timeline = n.max(1);
        self
    }

    pub fn name(&self) -> &'static str {
        match self.policy {
            GovernorPolicy::NoOp => "noop",
            GovernorPolicy::ThresholdThrottle { .. } => "threshold-throttle",
            GovernorPolicy::PidDvfs { .. } => "pid-dvfs",
        }
    }

    /// Instantiate the policy as a fresh, stateless-at-start governor.
    pub fn build(&self) -> Box<dyn Governor> {
        match self.policy {
            GovernorPolicy::NoOp => Box::new(NoOpGovernor),
            GovernorPolicy::ThresholdThrottle { hot_c, cold_c } => {
                Box::new(ThresholdThrottle::new(hot_c, cold_c))
            }
            GovernorPolicy::PidDvfs { target_c, kp, ki, kd } => {
                Box::new(PidDvfs::with_gains(target_c, kp, ki, kd))
            }
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.table.validate()?;
        if let GovernorPolicy::ThresholdThrottle { hot_c, cold_c } = self.policy {
            anyhow::ensure!(
                hot_c > cold_c,
                "threshold governor needs hot_c ({hot_c}) > cold_c ({cold_c})"
            );
        }
        Ok(())
    }
}

// -------------------------------------------------------------- runtime

/// One finalized control window in the report timeline.
#[derive(Debug, Clone)]
pub struct DtmWindowSample {
    /// Virtual time the window closed.
    pub end_ns: TimeNs,
    /// True hottest chiplet at the boundary, °C.
    pub hottest_c: f64,
    /// Hottest *sensor reading* the governor acted on, °C.
    pub sensor_hottest_c: f64,
    /// Mean frequency scale across chiplets after the decision.
    pub mean_freq_scale: f64,
    /// Slowest chiplet's frequency scale after the decision.
    pub min_freq_scale: f64,
    /// Chiplets below the nominal state after the decision.
    pub throttled: usize,
}

/// The in-loop controller owned by `Simulation::run_with` when built
/// with `ThermalSpec::InLoop`.  Drains power windows on its control
/// cadence (forwarding each to the run's [`StreamSink`] so streaming
/// stats stay fed), steps thermal, polls sensors, and applies the
/// governor.
pub struct DtmRuntime {
    window_ns: TimeNs,
    next_end: TimeNs,
    spec: GovernorSpec,
    stepper: ThermalStepper,
    sensors: SensorBank,
    governor: Box<dyn Governor>,
    /// Streaming runs drain closed windows (constant memory, forwarded
    /// to the sink); state-retaining batch runs peek non-destructively
    /// so the report keeps its full per-bin power trace.
    drain: bool,
    /// Current per-chiplet table index (0 = fastest).
    idx: Vec<usize>,
    windows: u64,
    violations: u64,
    transitions: u64,
    throttled_chiplet_windows: u64,
    peak_c: f64,
    timeline: VecDeque<DtmWindowSample>,
}

impl DtmRuntime {
    /// `run_seed` feeds the sensor-noise stream (the traffic seed for
    /// serving runs, `params.seed` otherwise); `drain` selects between
    /// draining closed windows (streaming) and peeking them (batch).
    pub fn new(
        hw: &HardwareConfig,
        bin_ns: TimeNs,
        window_ns: TimeNs,
        spec: &GovernorSpec,
        run_seed: u64,
        drain: bool,
    ) -> anyhow::Result<DtmRuntime> {
        spec.validate()?;
        anyhow::ensure!(
            window_ns >= bin_ns && window_ns % bin_ns == 0,
            "DTM window ({window_ns} ns) must be a whole multiple of the power bin \
             ({bin_ns} ns) so drain cursors land on window boundaries"
        );
        let window_bins = (window_ns / bin_ns) as usize;
        let stride = if spec.stride_bins == 0 {
            window_bins
        } else {
            spec.stride_bins.min(window_bins)
        };
        // A group spanning a control boundary would leave the governor
        // acting on temperatures that lag the boundary by the carry.
        anyhow::ensure!(
            window_bins % stride == 0,
            "DTM stride_bins ({stride}) must divide the control window ({window_bins} \
             bins) so every window closes on a whole thermal step"
        );
        // In-loop stepping is native-only: the control loop must be
        // deterministic and dispatch-free on the hot path.
        let stepper = ThermalStepper::new(hw, bin_ns, stride, false)?;
        let nch = hw.num_chiplets();
        Ok(DtmRuntime {
            window_ns,
            next_end: window_ns,
            stepper,
            sensors: SensorBank::new(nch, spec.sensors.clone(), run_seed),
            governor: spec.build(),
            spec: spec.clone(),
            drain,
            idx: vec![0; nch],
            windows: 0,
            violations: 0,
            transitions: 0,
            throttled_chiplet_windows: 0,
            peak_c: f64::NEG_INFINITY,
            timeline: VecDeque::new(),
        })
    }

    /// Latency multiplier for work issued on `chiplet` right now.
    pub fn latency_factor(&self, chiplet: usize) -> f64 {
        self.spec.table.states[self.idx[chiplet]].latency_factor()
    }

    /// Dynamic-energy multiplier for work issued on `chiplet` right now.
    pub fn energy_factor(&self, chiplet: usize) -> f64 {
        self.spec.table.states[self.idx[chiplet]].energy_factor()
    }

    /// Hottest chiplet temperature as of the last closed control window
    /// (ambient before any window closed).  The fleet's thermal-aware
    /// routing and emergency-migration predicate read this between epochs.
    pub fn hottest_c(&self) -> f64 {
        self.stepper.hottest_c()
    }

    /// Chiplets currently running below the top DVFS level (the flight
    /// recorder's governor-state gauge; `idx` itself stays private).
    pub fn throttled_chiplets(&self) -> usize {
        self.idx.iter().filter(|&&i| i > 0).count()
    }

    /// Deepest DVFS level currently applied anywhere (0 = no throttle).
    pub fn max_dvfs_level(&self) -> usize {
        self.idx.iter().copied().max().unwrap_or(0)
    }

    /// Install (`Some`) or clear (`None`) a fault-injection overlay on
    /// one chiplet's sensor (see [`SensorBank::set_fault`]); subsequent
    /// control windows act on the lying reading.
    pub fn set_sensor_fault(
        &mut self,
        chiplet: usize,
        fault: Option<(crate::fault::SensorMode, TimeNs)>,
    ) {
        self.sensors.set_fault(chiplet, fault);
    }

    /// Advance the control loop to virtual time `now`: close every
    /// elapsed window — drain its power (forwarded to `sink`), step the
    /// RC network, poll sensors, run the governor.
    pub fn on_advance(
        &mut self,
        now: TimeNs,
        power: &mut PowerTracker,
        sink: &mut dyn StreamSink,
    ) -> anyhow::Result<()> {
        while now >= self.next_end {
            let window = if self.drain {
                let w = power.drain_window(self.next_end);
                sink.on_power_window(&w);
                w
            } else {
                power.window_view(self.next_end - self.window_ns, self.next_end)
            };
            self.stepper.ingest(&window)?;
            let temps = self.stepper.chiplet_temps_c();
            let hottest = temps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            self.peak_c = self.peak_c.max(hottest);
            if hottest > self.spec.ceiling_c {
                self.violations += 1;
            }
            let readings = self.sensors.read(self.next_end, &temps);
            let sensor_hottest = readings.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let prev = self.idx.clone();
            self.governor.decide(self.next_end, readings, &self.spec.table, &mut self.idx);
            self.transitions +=
                prev.iter().zip(&self.idx).filter(|(a, b)| a != b).count() as u64;
            let throttled = self.idx.iter().filter(|&&i| i > 0).count();
            self.throttled_chiplet_windows += throttled as u64;
            self.windows += 1;
            let scales: Vec<f64> =
                self.idx.iter().map(|&i| self.spec.table.states[i].freq_scale).collect();
            self.timeline.push_back(DtmWindowSample {
                end_ns: self.next_end,
                hottest_c: hottest,
                sensor_hottest_c: sensor_hottest,
                mean_freq_scale: scales.iter().sum::<f64>() / scales.len().max(1) as f64,
                min_freq_scale: scales.iter().cloned().fold(1.0, f64::min),
                throttled,
            });
            if self.timeline.len() > self.spec.keep_timeline {
                self.timeline.pop_front();
            }
            self.next_end += self.window_ns;
        }
        Ok(())
    }

    /// Finalize after the event loop returned: fold the still-live power
    /// tail into the thermal state (non-destructively) and assemble the
    /// report.  In drain mode the tail is also forwarded to the sink, so
    /// externally-fed streaming power stats account every joule even
    /// when the run ends mid-window (or before the first one closes).
    pub fn finish(
        mut self,
        power: &PowerTracker,
        sink: &mut dyn StreamSink,
    ) -> anyhow::Result<DtmReport> {
        // The last, still-open control window: everything after the last
        // closed boundary.  In drain mode that is exactly the live bins;
        // in peek mode the live bins also cover already-stepped windows,
        // so the view starts at the boundary instead.
        let start = self.next_end.saturating_sub(self.window_ns);
        let end = power.num_bins() as TimeNs * power.bin_ns;
        if self.drain {
            let tail = power.window_view(start, end);
            if tail.bins() > 0 {
                sink.on_power_window(&tail);
            }
            self.stepper.ingest_live(power)?;
        } else {
            self.stepper.ingest(&power.window_view(start, end))?;
        }
        self.stepper.flush()?;
        let final_temps = self.stepper.chiplet_temps_c();
        let tail_hottest = final_temps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let peak_c = self.peak_c.max(tail_hottest);
        let nch = self.idx.len().max(1) as u64;
        Ok(DtmReport {
            governor: self.governor.name(),
            solver: self.stepper.solver(),
            window_ns: self.window_ns,
            ceiling_c: self.spec.ceiling_c,
            windows: self.windows,
            ceiling_violations: self.violations,
            peak_c,
            throttle_residency: if self.windows == 0 {
                0.0
            } else {
                self.throttled_chiplet_windows as f64 / (self.windows * nch) as f64
            },
            transitions: self.transitions,
            steps: self.stepper.steps(),
            final_freq_scale: self
                .idx
                .iter()
                .map(|&i| self.spec.table.states[i].freq_scale)
                .collect(),
            final_temps_c: final_temps,
            timeline: self.timeline.into_iter().collect(),
        })
    }
}

// --------------------------------------------------------------- report

/// Result of a closed-loop DTM run, attached to `SimReport::dtm` (and
/// therefore reachable from `TrafficReport::dtm()`).
#[derive(Debug, Clone)]
pub struct DtmReport {
    pub governor: &'static str,
    /// Thermal backend that stepped the loop ("native").
    pub solver: &'static str,
    /// Control period, ns.
    pub window_ns: TimeNs,
    pub ceiling_c: f64,
    /// Control windows evaluated.
    pub windows: u64,
    /// Windows whose true hottest chiplet exceeded the ceiling.
    pub ceiling_violations: u64,
    /// Hottest true chiplet temperature observed at any window boundary
    /// (or at run end), °C.
    pub peak_c: f64,
    /// Fraction of (chiplet × window) pairs spent below nominal speed.
    pub throttle_residency: f64,
    /// Total DVFS state changes across chiplets.
    pub transitions: u64,
    /// Implicit-Euler steps integrated (incl. the end-of-run tail).
    pub steps: usize,
    pub final_temps_c: Vec<f64>,
    pub final_freq_scale: Vec<f64>,
    /// Trailing per-window samples (bounded by `keep_timeline`).
    pub timeline: Vec<DtmWindowSample>,
}

impl DtmReport {
    /// Human-readable roll-up (one paragraph, newline-terminated).
    pub fn summary(&self) -> String {
        format!(
            "dtm ({}, {} windows of {:.0} µs): peak {:.2} °C vs ceiling {:.1} °C \
             ({} violations), throttle residency {:.1} %, {} transitions\n",
            self.governor,
            self.windows,
            self.window_ns as f64 / 1e3,
            self.peak_c,
            self.ceiling_c,
            self.ceiling_violations,
            self.throttle_residency * 100.0,
            self.transitions,
        )
    }

    /// Per-window temperature/frequency trace (`chipsim dtm --csv`).
    pub fn timeline_csv(&self) -> String {
        let mut s = String::from(
            "end_us,hottest_c,sensor_hottest_c,mean_freq_scale,min_freq_scale,throttled\n",
        );
        for w in &self.timeline {
            s.push_str(&format!(
                "{:.3},{:.4},{:.4},{:.4},{:.4},{}\n",
                w.end_ns as f64 / 1e3,
                w.hottest_c,
                w.sensor_hottest_c,
                w.mean_freq_scale,
                w.min_freq_scale,
                w.throttled,
            ));
        }
        s
    }

    /// Stable digest for determinism checks: floats enter via their bit
    /// patterns, so two reports are byte-identical iff this matches.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "gov={};win={};n={};viol={};trans={};peak={:016x};res={:016x}",
            self.governor,
            self.window_ns,
            self.windows,
            self.ceiling_violations,
            self.transitions,
            self.peak_c.to_bits(),
            self.throttle_residency.to_bits(),
        );
        for t in &self.final_temps_c {
            let _ = write!(s, ",t{:016x}", t.to_bits());
        }
        for f in &self.final_freq_scale {
            let _ = write!(s, ",f{:016x}", f.to_bits());
        }
        for w in &self.timeline {
            let _ = write!(
                s,
                ";{}:{:016x}:{:016x}:{:016x}:{}",
                w.end_ns,
                w.hottest_c.to_bits(),
                w.sensor_hottest_c.to_bits(),
                w.mean_freq_scale.to_bits(),
                w.throttled
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_table_validates_and_orders() {
        let t = DvfsTable::default_four();
        t.validate().unwrap();
        assert_eq!(t.nearest(1.0), 0);
        assert_eq!(t.nearest(0.75), 1);
        assert_eq!(t.nearest(0.0), 3);
        assert!((t.min_freq_scale() - 0.4).abs() < 1e-12);
        // Deepest state cuts dynamic power density ~5x: E·f factor.
        let s = t.states[3];
        assert!((s.energy_factor() - 0.49).abs() < 1e-12);
        assert!((s.latency_factor() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn bad_tables_are_rejected() {
        let empty = DvfsTable { states: vec![] };
        assert!(empty.validate().is_err());
        let unordered = DvfsTable {
            states: vec![
                DvfsState { freq_scale: 1.0, volt_scale: 1.0 },
                DvfsState { freq_scale: 1.0, volt_scale: 0.9 },
            ],
        };
        assert!(unordered.validate().is_err());
        let no_nominal = DvfsTable {
            states: vec![DvfsState { freq_scale: 0.9, volt_scale: 1.0 }],
        };
        assert!(no_nominal.validate().is_err());
    }

    #[test]
    fn governor_spec_constructors_name_their_policy() {
        assert_eq!(GovernorSpec::noop(80.0).name(), "noop");
        assert_eq!(GovernorSpec::threshold(80.0).name(), "threshold-throttle");
        assert_eq!(GovernorSpec::pid(75.0).name(), "pid-dvfs");
        GovernorSpec::threshold(80.0).validate().unwrap();
        assert!(GovernorSpec::threshold_band(60.0, 70.0, 80.0).validate().is_err());
    }

    #[test]
    fn runtime_requires_aligned_windows() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let spec = GovernorSpec::noop(60.0);
        assert!(DtmRuntime::new(&hw, 1_000, 1_500, &spec, 0, true).is_err());
        assert!(DtmRuntime::new(&hw, 1_000, 2_000, &spec, 0, true).is_ok());
    }

    #[test]
    fn peek_and_drain_modes_agree_thermally() {
        // Batch runs peek windows (report keeps its power trace);
        // streaming runs drain them.  Both must integrate the same
        // thermal trajectory, tail included.
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let spec = GovernorSpec::noop(60.0).sensors(SensorSpec::ideal());
        let run = |drain: bool| {
            let mut rt = DtmRuntime::new(&hw, 1_000, 2_000, &spec, 3, drain).unwrap();
            let mut power = PowerTracker::new(4, 1_000);
            for c in 0..4 {
                power.set_baseline_mw(c, 1.0);
            }
            power.add_energy(0, 500, 6_000, 9_000.0);
            rt.on_advance(7_000, &mut power, &mut crate::sim::NullSink).unwrap();
            let rep = rt.finish(&power, &mut crate::sim::NullSink).unwrap();
            (rep, power.drained_bins())
        };
        let (a, drained_a) = run(true);
        let (b, drained_b) = run(false);
        assert!(drained_a > 0, "drain mode must retire bins");
        assert_eq!(drained_b, 0, "peek mode must leave the tracker intact");
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.steps, b.steps);
        for (x, y) in a.final_temps_c.iter().zip(&b.final_temps_c) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn empty_run_report_is_sane() {
        let hw = HardwareConfig::homogeneous_mesh(2, 2);
        let rt =
            DtmRuntime::new(&hw, 1_000, 2_000, &GovernorSpec::noop(60.0), 7, false).unwrap();
        let power = PowerTracker::new(hw.num_chiplets(), 1_000);
        let rep = rt.finish(&power, &mut crate::sim::NullSink).unwrap();
        assert_eq!(rep.windows, 0);
        assert_eq!(rep.ceiling_violations, 0);
        assert_eq!(rep.throttle_residency, 0.0);
        assert_eq!(rep.final_freq_scale, vec![1.0; 4]);
        // No bins at all: the only temperature evidence is ambient.
        assert!(rep.final_temps_c.iter().all(|t| t.is_finite()));
        assert!(!rep.summary().is_empty());
        assert!(rep.timeline_csv().starts_with("end_us,"));
    }
}
