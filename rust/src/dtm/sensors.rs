//! Per-chiplet temperature sensors: what the governor *sees*.
//!
//! Real DTM controllers act on thermal-diode readings, not ground truth:
//! sensors quantize (typically 0.25–1 °C steps), carry noise, and are
//! polled at a fixed period rather than continuously.  [`SensorBank`]
//! models all three over the stepper's true chiplet temperatures, with
//! seed-deterministic Gaussian noise so a DTM run is byte-reproducible.

use crate::fault::SensorMode;
use crate::util::rng::Rng;
use crate::TimeNs;

/// Sensor fidelity configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SensorSpec {
    /// Quantization step, °C (0 = continuous readout).
    pub quant_c: f64,
    /// Gaussian read-noise sigma, °C (0 = noiseless).
    pub noise_sigma_c: f64,
    /// Polling period, ns.  Between polls the bank holds the last
    /// reading.  0 polls at every control window.
    pub period_ns: TimeNs,
    /// Noise stream seed, mixed with the simulation seed.
    pub seed: u64,
}

impl Default for SensorSpec {
    fn default() -> Self {
        SensorSpec { quant_c: 0.25, noise_sigma_c: 0.1, period_ns: 0, seed: 0x5E45_0217 }
    }
}

impl SensorSpec {
    /// Noiseless, continuous, every-window sensors (testing / oracles).
    pub fn ideal() -> SensorSpec {
        SensorSpec { quant_c: 0.0, noise_sigma_c: 0.0, period_ns: 0, seed: 0 }
    }
}

/// One sensor per chiplet, sharing a deterministic noise stream.
pub struct SensorBank {
    spec: SensorSpec,
    rng: Rng,
    readings: Vec<f64>,
    last_poll_ns: Option<TimeNs>,
    /// Fault-injection overlays: `(mode, since_ns)` per chiplet.  Applied
    /// on top of the honest (noisy, quantized) reading so the governor
    /// acts on lying data; `None` everywhere costs one `any` scan.
    faults: Vec<Option<(SensorMode, TimeNs)>>,
    /// Scratch output when at least one overlay is active — the honest
    /// `readings` stay untouched so clearing a fault restores truth.
    faulted: Vec<f64>,
}

impl SensorBank {
    pub fn new(num_chiplets: usize, spec: SensorSpec, run_seed: u64) -> SensorBank {
        // One PRNG round avalanches (run_seed, sensor seed) pairs apart.
        let mut mixer = Rng::new(run_seed ^ spec.seed.rotate_left(17));
        let rng = mixer.fork();
        SensorBank {
            spec,
            rng,
            readings: vec![0.0; num_chiplets],
            last_poll_ns: None,
            faults: vec![None; num_chiplets],
            faulted: Vec::new(),
        }
    }

    /// Install (`Some`) or clear (`None`) a fault overlay on one sensor.
    /// `since_ns` anchors drift-mode error growth.  Out-of-range indices
    /// are ignored (plans are validated upstream at arm time).
    pub fn set_fault(&mut self, chiplet: usize, fault: Option<(SensorMode, TimeNs)>) {
        if let Some(slot) = self.faults.get_mut(chiplet) {
            *slot = fault;
        }
    }

    /// Sample the sensors at `now` against the true temperatures (°C).
    /// Polls only when the period elapsed; otherwise the previous
    /// readings are returned unchanged (stale data is part of the model).
    pub fn read(&mut self, now: TimeNs, true_temps_c: &[f64]) -> &[f64] {
        let due = match self.last_poll_ns {
            None => true,
            Some(last) => now >= last.saturating_add(self.spec.period_ns),
        };
        if due {
            self.last_poll_ns = Some(now);
            self.readings.clear();
            for &t in true_temps_c {
                let mut v = t;
                if self.spec.noise_sigma_c > 0.0 {
                    v += self.spec.noise_sigma_c * gauss(&mut self.rng);
                }
                if self.spec.quant_c > 0.0 {
                    v = (v / self.spec.quant_c).round() * self.spec.quant_c;
                }
                self.readings.push(v);
            }
        }
        if self.faults.iter().any(|f| f.is_some()) {
            self.faulted.clear();
            self.faulted.extend_from_slice(&self.readings);
            for (i, f) in self.faults.iter().enumerate() {
                if let (Some((mode, since)), Some(out)) = (f, self.faulted.get_mut(i)) {
                    *out = match mode {
                        SensorMode::StuckAt(c) => *c,
                        SensorMode::DriftPerMs(d) => {
                            *out + d * (now.saturating_sub(*since) as f64 / 1e6)
                        }
                    };
                }
            }
            return &self.faulted;
        }
        &self.readings
    }
}

/// Standard-normal sample via Box–Muller (one draw per call; the partner
/// sample is discarded for a simpler deterministic stream).
fn gauss(rng: &mut Rng) -> f64 {
    let u1 = rng.f64().max(1e-300);
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_sensors_pass_truth_through() {
        let mut bank = SensorBank::new(3, SensorSpec::ideal(), 42);
        let truth = [45.0, 52.25, 61.5];
        assert_eq!(bank.read(0, &truth), &truth);
    }

    #[test]
    fn quantization_snaps_to_steps() {
        let spec = SensorSpec { quant_c: 0.5, noise_sigma_c: 0.0, period_ns: 0, seed: 0 };
        let mut bank = SensorBank::new(2, spec, 1);
        let r = bank.read(0, &[45.13, 45.38]).to_vec();
        assert_eq!(r, vec![45.0, 45.5]);
    }

    #[test]
    fn noise_is_seed_deterministic_and_seed_sensitive() {
        let spec = SensorSpec { quant_c: 0.0, noise_sigma_c: 0.5, period_ns: 0, seed: 7 };
        let truth = [50.0; 4];
        let mut a = SensorBank::new(4, spec.clone(), 99);
        let mut b = SensorBank::new(4, spec.clone(), 99);
        assert_eq!(a.read(0, &truth), b.read(0, &truth));
        let mut c = SensorBank::new(4, spec, 100);
        assert_ne!(a.read(1, &truth), c.read(1, &truth));
    }

    #[test]
    fn polling_period_holds_readings_between_polls() {
        let spec = SensorSpec { quant_c: 0.0, noise_sigma_c: 0.0, period_ns: 1_000, seed: 0 };
        let mut bank = SensorBank::new(1, spec, 0);
        assert_eq!(bank.read(0, &[45.0]), &[45.0]);
        // Truth moved, but the next poll is not due yet: stale reading.
        assert_eq!(bank.read(500, &[60.0]), &[45.0]);
        assert_eq!(bank.read(1_000, &[60.0]), &[60.0]);
    }

    #[test]
    fn fault_overlays_lie_and_clear_back_to_truth() {
        let mut bank = SensorBank::new(3, SensorSpec::ideal(), 42);
        let truth = [45.0, 52.0, 61.0];
        bank.set_fault(1, Some((SensorMode::StuckAt(95.0), 0)));
        bank.set_fault(2, Some((SensorMode::DriftPerMs(0.5), 1_000_000)));
        // Stuck sensor reads the lie; drift grows with fault age.
        assert_eq!(bank.read(1_000_000, &truth), &[45.0, 95.0, 61.0]);
        let r = bank.read(3_000_000, &truth).to_vec();
        assert_eq!(r[1], 95.0);
        assert!((r[2] - 62.0).abs() < 1e-9, "0.5 °C/ms over 2 ms: {}", r[2]);
        // Repair restores the honest reading (held state untouched).
        bank.set_fault(1, None);
        bank.set_fault(2, None);
        assert_eq!(bank.read(4_000_000, &truth), &truth);
        // Out-of-range target is a no-op, not a panic.
        bank.set_fault(17, Some((SensorMode::StuckAt(1.0), 0)));
    }

    #[test]
    fn gauss_is_roughly_standard_normal() {
        let mut rng = Rng::new(1234);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| gauss(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
