//! DVFS governors: the policy half of the closed thermal loop.
//!
//! A [`Governor`] looks at the latest sensor readings once per control
//! window and picks a per-chiplet operating point from the discrete
//! [`DvfsTable`](super::DvfsTable).  Three built-ins:
//!
//! * [`NoOpGovernor`] — never throttles (the uncontrolled baseline every
//!   DTM experiment compares against);
//! * [`ThresholdThrottle`] — reactive hysteresis band: one state slower
//!   above `hot_c`, one state faster below `cold_c`, hold in between
//!   (the band prevents limit-cycling on sensor noise);
//! * [`PidDvfs`] — per-chiplet PID on the temperature error, mapped onto
//!   the nearest discrete state (smoother residency near the target at
//!   the cost of tuning).

use super::DvfsTable;
use crate::TimeNs;

/// A DVFS policy: maps sensor temperatures to per-chiplet table indices.
///
/// `state[c]` holds chiplet `c`'s current index into the table (0 =
/// fastest); implementations mutate it in place.  Called once per
/// control window with monotonically increasing `now_ns`.  `Send` so DTM
/// state can ride a run session across fleet worker-pool threads.
pub trait Governor: Send {
    fn name(&self) -> &'static str;

    fn decide(&mut self, now_ns: TimeNs, temps_c: &[f64], table: &DvfsTable, state: &mut [usize]);
}

/// Never throttles: every chiplet stays at the fastest state.
pub struct NoOpGovernor;

impl Governor for NoOpGovernor {
    fn name(&self) -> &'static str {
        "noop"
    }

    fn decide(&mut self, _now: TimeNs, _temps: &[f64], _table: &DvfsTable, _state: &mut [usize]) {}
}

/// Reactive throttle with a hysteresis band: step one table position
/// slower while the reading exceeds `hot_c`, one position faster once it
/// falls below `cold_c`, hold inside the band.
pub struct ThresholdThrottle {
    pub hot_c: f64,
    pub cold_c: f64,
}

impl ThresholdThrottle {
    pub fn new(hot_c: f64, cold_c: f64) -> ThresholdThrottle {
        assert!(hot_c > cold_c, "hysteresis band needs hot_c ({hot_c}) > cold_c ({cold_c})");
        ThresholdThrottle { hot_c, cold_c }
    }
}

impl Governor for ThresholdThrottle {
    fn name(&self) -> &'static str {
        "threshold-throttle"
    }

    fn decide(&mut self, _now: TimeNs, temps_c: &[f64], table: &DvfsTable, state: &mut [usize]) {
        let slowest = table.states.len() - 1;
        for (c, idx) in state.iter_mut().enumerate() {
            let t = temps_c.get(c).copied().unwrap_or(0.0);
            if t > self.hot_c {
                *idx = (*idx + 1).min(slowest);
            } else if t < self.cold_c {
                *idx = idx.saturating_sub(1);
            }
        }
    }
}

/// Per-chiplet PID controller on `reading - target_c`, mapped to the
/// nearest discrete frequency scale.  Positive control output means "too
/// hot, slow down"; the integral term is clamped for anti-windup.
pub struct PidDvfs {
    pub target_c: f64,
    pub kp: f64,
    pub ki: f64,
    pub kd: f64,
    integral: Vec<f64>,
    prev_err: Vec<f64>,
}

impl PidDvfs {
    /// Default gains: proportional-dominant with a slow integral, sized
    /// so a ~5 K excursion above target commands roughly half the DVFS
    /// range.
    pub fn new(target_c: f64) -> PidDvfs {
        PidDvfs::with_gains(target_c, 0.08, 0.02, 0.04)
    }

    pub fn with_gains(target_c: f64, kp: f64, ki: f64, kd: f64) -> PidDvfs {
        PidDvfs { target_c, kp, ki, kd, integral: Vec::new(), prev_err: Vec::new() }
    }
}

impl Governor for PidDvfs {
    fn name(&self) -> &'static str {
        "pid-dvfs"
    }

    fn decide(&mut self, _now: TimeNs, temps_c: &[f64], table: &DvfsTable, state: &mut [usize]) {
        if self.integral.len() != state.len() {
            self.integral = vec![0.0; state.len()];
            self.prev_err = vec![0.0; state.len()];
        }
        let min_f = table.min_freq_scale();
        for (c, idx) in state.iter_mut().enumerate() {
            let err = temps_c.get(c).copied().unwrap_or(self.target_c) - self.target_c;
            // Anti-windup: bound the integral so a long hot spell does
            // not lock the chiplet slow for the rest of the run.
            self.integral[c] = (self.integral[c] + err).clamp(-25.0, 25.0);
            let deriv = err - self.prev_err[c];
            self.prev_err[c] = err;
            let u = self.kp * err + self.ki * self.integral[c] + self.kd * deriv;
            let want = (1.0 - u).clamp(min_f, 1.0);
            *idx = table.nearest(want);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtm::DvfsTable;

    fn table() -> DvfsTable {
        DvfsTable::default_four()
    }

    #[test]
    fn noop_never_moves() {
        let t = table();
        let mut g = NoOpGovernor;
        let mut state = vec![0usize; 3];
        g.decide(0, &[500.0, 500.0, 500.0], &t, &mut state);
        assert_eq!(state, vec![0, 0, 0]);
    }

    #[test]
    fn threshold_steps_down_when_hot_and_back_up_when_cold() {
        let t = table();
        let mut g = ThresholdThrottle::new(80.0, 70.0);
        let mut state = vec![0usize; 1];
        // Hot: one step per decision, saturating at the slowest state.
        for want in [1, 2, 3, 3] {
            g.decide(0, &[85.0], &t, &mut state);
            assert_eq!(state[0], want);
        }
        // Inside the band: hold.
        g.decide(0, &[75.0], &t, &mut state);
        assert_eq!(state[0], 3);
        // Cold: step back up to full speed.
        for want in [2, 1, 0, 0] {
            g.decide(0, &[60.0], &t, &mut state);
            assert_eq!(state[0], want);
        }
    }

    #[test]
    fn pid_throttles_above_target_and_releases_below() {
        let t = table();
        let mut g = PidDvfs::new(70.0);
        let mut state = vec![0usize; 1];
        // Far above target: drives toward the slow end.
        for _ in 0..6 {
            g.decide(0, &[85.0], &t, &mut state);
        }
        assert!(state[0] >= 2, "hot PID should throttle, got state {}", state[0]);
        // Well below target: recovers to full speed (anti-windup lets
        // the integral unwind in bounded time).
        for _ in 0..60 {
            g.decide(0, &[50.0], &t, &mut state);
        }
        assert_eq!(state[0], 0);
    }
}
