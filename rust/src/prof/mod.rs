//! Host-side self-profiler: where does the *simulator's* wall-clock go?
//!
//! PR 7's flight recorder observes the **simulated** system at nanosecond
//! granularity; this module gives the same visibility into the **simulator
//! itself**, so parallelization work (ROADMAP item 2) and bench ratcheting
//! (item 6) are driven by measured shares instead of guesses.
//!
//! # Model
//!
//! * **Scoped timers** ([`scope`]) attribute wall-clock to a fixed set of
//!   [`Subsystem`]s: event-loop dispatch, mapping/ledger, compute issue,
//!   the packet and flit network engines, thermal stepping, the DTM
//!   governor, trace export, and the fleet's dispatch vs parallel-advance
//!   phases.  Scopes nest on a per-thread stack; a parent's **self** time
//!   is its elapsed time minus the elapsed time of its direct children,
//!   so `self` sums across subsystems to total scoped time with no
//!   double-counting.  The nesting stacks are also exported as
//!   inferno-compatible collapsed lines ([`ProfileReport::collapsed`])
//!   for flamegraph rendering.
//! * **Monotonic counters** ([`count`]) track work items (events
//!   processed, flit-hops, mapping attempts, ledger journal ops,
//!   requests completed, sims completed) and derive rates (events/s,
//!   flit-hops/s, sims/s) against the profiled wall-clock.
//! * **Worker utilization**: `util::pool` wraps each job in a
//!   [`busy_scope`], so the report carries per-worker busy time and a
//!   busy/wall utilization — the parallel-efficiency baseline the
//!   sharded-core plan needs.
//!
//! # Zero perturbation
//!
//! The profiler only ever *reads* [`std::time::Instant`] and bumps its own
//! atomics; it never touches simulation state, event order, or RNG
//! streams.  Report fingerprints are byte-identical per seed with and
//! without profiling (`rust/tests/prof.rs` asserts this on both NoC
//! fidelities), and the `ProfileReport` itself is excluded from every
//! report fingerprint, mirroring how `BreakdownStats` is handled.
//!
//! # Cost
//!
//! Collection is gated behind the `prof` cargo feature (on by default)
//! *and* a runtime switch ([`enable`]).  Compiled in but disabled, every
//! hook costs one relaxed atomic load and a branch; built with
//! `--no-default-features`, the hooks compile to empty inlined stubs.
//! The report/JSON/collapsed-export types below are always compiled so
//! CLI and report plumbing work identically in both builds (a no-feature
//! build simply never produces a report).
//!
//! # Aggregation
//!
//! State is process-global: [`enable`] resets it, [`snapshot`] reads it
//! without resetting.  Per-thread stats are keyed by **thread name**, so
//! the short-lived `chipsim-worker-N` threads the pool spawns every fleet
//! epoch accumulate into one row per worker index rather than one per
//! incarnation.

#[cfg(feature = "prof")]
use std::sync::atomic::Ordering;

// ------------------------------------------------------------- subsystems

/// A simulator subsystem wall-clock is attributed to.  The variant order
/// is the presentation order in tables and JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Subsystem {
    /// `advance_run`: the co-simulation event loop (self time = dispatch
    /// overhead left after nested subsystems are subtracted out).
    EventLoop,
    /// Mapper probe + commit against the `MemoryLedger`.
    Mapping,
    /// Compute issue: segment latency/energy evaluation and scheduling.
    ComputeIssue,
    /// Packet-fidelity NoI engine (`noc::engine`).
    PacketEngine,
    /// Flit-fidelity wormhole engine cycles (`noc::flit`).
    FlitEngine,
    /// RC thermal stepping (`thermal::stepper` ingest).
    Thermal,
    /// DTM governor: sensor polling + DVFS decisions.
    Dtm,
    /// Flight-recorder export to Chrome trace-event JSON.
    TraceExport,
    /// Fleet single-threaded control section (snapshot, migrate,
    /// autoscale, route).
    FleetDispatch,
    /// Fleet parallel replica advance (the epoch's worker-pool phase).
    FleetAdvance,
    /// Sharded flit engine: one region advancing a synchronization
    /// window on a pool worker (`crate::par`).  Accumulates on the
    /// worker threads; compare against `sync_barrier` and the
    /// coordinator's `flit_engine` time for parallel efficiency.
    RegionAdvance,
    /// Sharded flit engine: the coordinator's serial sections between
    /// windows (credit snapshots, completion pre-scan, boundary/event
    /// merge) — the Amdahl ceiling of the parallel NoI core.
    SyncBarrier,
}

impl Subsystem {
    pub const COUNT: usize = 12;
    pub const ALL: [Subsystem; Self::COUNT] = [
        Subsystem::EventLoop,
        Subsystem::Mapping,
        Subsystem::ComputeIssue,
        Subsystem::PacketEngine,
        Subsystem::FlitEngine,
        Subsystem::Thermal,
        Subsystem::Dtm,
        Subsystem::TraceExport,
        Subsystem::FleetDispatch,
        Subsystem::FleetAdvance,
        Subsystem::RegionAdvance,
        Subsystem::SyncBarrier,
    ];

    /// Stable snake_case name used in JSON, collapsed stacks, and the
    /// `share_<name>` bench metrics.
    pub fn name(self) -> &'static str {
        match self {
            Subsystem::EventLoop => "event_loop",
            Subsystem::Mapping => "mapping",
            Subsystem::ComputeIssue => "compute_issue",
            Subsystem::PacketEngine => "packet_engine",
            Subsystem::FlitEngine => "flit_engine",
            Subsystem::Thermal => "thermal",
            Subsystem::Dtm => "dtm",
            Subsystem::TraceExport => "trace_export",
            Subsystem::FleetDispatch => "fleet_dispatch",
            Subsystem::FleetAdvance => "fleet_advance",
            Subsystem::RegionAdvance => "region_advance",
            Subsystem::SyncBarrier => "sync_barrier",
        }
    }
}

/// A monotonic work counter.  Counters only ever increase between
/// [`enable`]/[`reset`] and a [`snapshot`], and never feed back into the
/// simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Events dispatched by the co-simulation loop (arrivals + queue).
    Events,
    /// Flit-hops simulated by the wormhole engine (one per flit per
    /// link traversal; × link `width_bytes` == `SimReport::noc_work` on
    /// uniform-width topologies).
    FlitHops,
    /// Mapper `try_map` invocations (probes and commits).
    MappingAttempts,
    /// `MemoryLedger` journal deltas recorded under a checkpoint.
    JournalOps,
    /// Request instances finished by the event loop (pre-warm-up
    /// completions included; drops excluded).
    RequestsCompleted,
    /// Whole simulation runs finalized (`finish_run`) — derives sims/s
    /// for batch sweeps and fleets.
    SimsCompleted,
}

impl Counter {
    pub const COUNT: usize = 6;
    pub const ALL: [Counter; Self::COUNT] = [
        Counter::Events,
        Counter::FlitHops,
        Counter::MappingAttempts,
        Counter::JournalOps,
        Counter::RequestsCompleted,
        Counter::SimsCompleted,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Events => "events",
            Counter::FlitHops => "flit_hops",
            Counter::MappingAttempts => "mapping_attempts",
            Counter::JournalOps => "journal_ops",
            Counter::RequestsCompleted => "requests_completed",
            Counter::SimsCompleted => "sims_completed",
        }
    }
}

// ------------------------------------------------- report (always built)

/// Per-subsystem wall-clock attribution.
#[derive(Debug, Clone)]
pub struct SubsystemStat {
    pub name: &'static str,
    /// Elapsed time inside this subsystem's scopes, children included.
    pub total_ns: u64,
    /// Elapsed time minus direct children — sums to `cpu_ns` across
    /// subsystems without double-counting.
    pub self_ns: u64,
    pub calls: u64,
    /// `self_ns / cpu_ns` — fraction of all *scoped* time, so shares sum
    /// to ≤ 1 even when workers run in parallel.
    pub share: f64,
}

/// One monotonic counter with its rate against the profiled wall-clock.
#[derive(Debug, Clone)]
pub struct CounterStat {
    pub name: &'static str,
    pub value: u64,
    pub per_s: f64,
}

/// Busy/idle accounting for one (named) thread.
#[derive(Debug, Clone)]
pub struct WorkerStat {
    pub name: String,
    pub busy_ns: u64,
    /// `busy_ns / wall_ns`, clamped to [0, 1].
    pub util: f64,
}

/// One nesting stack ("chipsim;fleet_advance;event_loop;mapping") with
/// its total and self time — the flamegraph raw material.
#[derive(Debug, Clone)]
pub struct PathStat {
    pub stack: String,
    pub total_ns: u64,
    pub self_ns: u64,
}

/// Snapshot of the self-profiler: subsystem attribution, counters with
/// derived rates, per-worker utilization, and collapsed-stack paths.
///
/// Rides on `SimReport` (and therefore `TrafficReport`/`MixReport`) and
/// on `FleetReport`; excluded from every fingerprint.
#[derive(Debug, Clone, Default)]
pub struct ProfileReport {
    /// Wall-clock of the profiled window (the run's host time).
    pub wall_ns: u64,
    /// Total scoped time summed over all threads — the share
    /// denominator.  Exceeds `wall_ns` when workers run in parallel.
    pub cpu_ns: u64,
    /// Subsystems with non-zero time, in [`Subsystem::ALL`] order.
    pub subsystems: Vec<SubsystemStat>,
    /// Counters with non-zero values, in [`Counter::ALL`] order.
    pub counters: Vec<CounterStat>,
    /// Threads that recorded pool busy-time, sorted by name.
    pub workers: Vec<WorkerStat>,
    /// Nesting stacks sorted lexicographically.
    pub paths: Vec<PathStat>,
}

impl ProfileReport {
    /// Inferno-compatible collapsed stacks: one `frame;frame;... value`
    /// line per path, value = self time in nanoseconds.  Feed to
    /// `inferno-flamegraph` (or flamegraph.pl) as-is.
    pub fn collapsed(&self) -> String {
        let mut out = String::new();
        for p in &self.paths {
            if p.self_ns > 0 {
                out.push_str(&format!("{} {}\n", p.stack, p.self_ns));
            }
        }
        out
    }

    /// One-line headline: wall, scoped coverage, and the top subsystem.
    pub fn summary(&self) -> String {
        let top = self
            .subsystems
            .iter()
            .max_by(|a, b| a.self_ns.cmp(&b.self_ns))
            .map(|s| format!("{} {:.1}%", s.name, s.share * 100.0))
            .unwrap_or_else(|| "no scopes".to_string());
        format!(
            "profile: wall {} | scoped {} ({} thread-rows) | top {}",
            crate::util::benchkit::fmt_ns(self.wall_ns as f64),
            crate::util::benchkit::fmt_ns(self.cpu_ns as f64),
            self.workers.len().max(1),
            top
        )
    }

    /// Human tables: subsystem shares, counters/rates, worker
    /// utilization.
    pub fn render(&self) -> String {
        use crate::util::benchkit::{fmt_ns, Table};
        let mut t = Table::new(
            "self-profile: wall-clock by subsystem",
            &["subsystem", "self", "total", "calls", "share"],
        );
        for s in &self.subsystems {
            t.row(vec![
                s.name.to_string(),
                fmt_ns(s.self_ns as f64),
                fmt_ns(s.total_ns as f64),
                s.calls.to_string(),
                format!("{:.1}%", s.share * 100.0),
            ]);
        }
        let mut out = t.render();
        if !self.counters.is_empty() {
            let mut c = Table::new("work counters", &["counter", "value", "rate"]);
            for k in &self.counters {
                c.row(vec![
                    k.name.to_string(),
                    k.value.to_string(),
                    format!("{:.0}/s", k.per_s),
                ]);
            }
            out.push('\n');
            out.push_str(&c.render());
        }
        if !self.workers.is_empty() {
            let mut w = Table::new("worker utilization", &["thread", "busy", "util"]);
            for k in &self.workers {
                w.row(vec![
                    k.name.clone(),
                    fmt_ns(k.busy_ns as f64),
                    format!("{:.1}%", k.util * 100.0),
                ]);
            }
            out.push('\n');
            out.push_str(&w.render());
        }
        out
    }

    /// JSON document (`schema: chipsim-profile-v1`) with the collapsed
    /// lines embedded, so one artifact serves both dashboards and
    /// flamegraphs.  `python/prof_check.py` schema-gates this shape.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let subs: Vec<Value> = self
            .subsystems
            .iter()
            .map(|s| {
                Value::obj(vec![
                    ("name", Value::from(s.name)),
                    ("total_ns", Value::from(s.total_ns)),
                    ("self_ns", Value::from(s.self_ns)),
                    ("calls", Value::from(s.calls)),
                    ("share", Value::from(s.share)),
                ])
            })
            .collect();
        let counters: Vec<Value> = self
            .counters
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("name", Value::from(c.name)),
                    ("value", Value::from(c.value)),
                    ("per_s", Value::from(c.per_s)),
                ])
            })
            .collect();
        let workers: Vec<Value> = self
            .workers
            .iter()
            .map(|w| {
                Value::obj(vec![
                    ("name", Value::from(w.name.clone())),
                    ("busy_ns", Value::from(w.busy_ns)),
                    ("util", Value::from(w.util)),
                ])
            })
            .collect();
        let paths: Vec<Value> = self
            .paths
            .iter()
            .map(|p| {
                Value::obj(vec![
                    ("stack", Value::from(p.stack.clone())),
                    ("total_ns", Value::from(p.total_ns)),
                    ("self_ns", Value::from(p.self_ns)),
                ])
            })
            .collect();
        let collapsed: Vec<Value> = self.collapsed().lines().map(Value::from).collect();
        Value::obj(vec![
            ("schema", Value::from("chipsim-profile-v1")),
            ("wall_ns", Value::from(self.wall_ns)),
            ("cpu_ns", Value::from(self.cpu_ns)),
            ("subsystems", Value::Arr(subs)),
            ("counters", Value::Arr(counters)),
            ("workers", Value::Arr(workers)),
            ("paths", Value::Arr(paths)),
            ("collapsed", Value::Arr(collapsed)),
        ])
    }
}

// --------------------------------------------------- collection (gated)

#[cfg(feature = "prof")]
mod collect {
    use super::{
        Counter, CounterStat, PathStat, ProfileReport, Subsystem, SubsystemStat, WorkerStat,
    };
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    /// Collapsed paths pack one 4-bit frame per nesting level into a
    /// u64; deeper nests fold into their depth-15 ancestor.
    const MAX_DEPTH: usize = 15;

    pub(super) static ENABLED: AtomicBool = AtomicBool::new(false);

    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static COUNTERS: [AtomicU64; Counter::COUNT] = [ZERO; Counter::COUNT];

    /// Global registry of per-thread stat rows, keyed by thread *name*
    /// so every `chipsim-worker-N` incarnation shares one row.
    static REGISTRY: Mutex<Vec<Arc<ThreadShared>>> = Mutex::new(Vec::new());

    struct ThreadShared {
        name: String,
        stats: Mutex<ThreadStats>,
    }

    struct ThreadStats {
        total_ns: [u64; Subsystem::COUNT],
        self_ns: [u64; Subsystem::COUNT],
        calls: [u64; Subsystem::COUNT],
        /// packed path -> (total_ns, self_ns)
        paths: std::collections::HashMap<u64, (u64, u64)>,
        /// Sum of root-scope elapsed — this thread's scoped time.
        root_ns: u64,
        /// Pool busy time ([`super::busy_scope`]).
        busy_ns: u64,
    }

    impl ThreadStats {
        fn new() -> ThreadStats {
            ThreadStats {
                total_ns: [0; Subsystem::COUNT],
                self_ns: [0; Subsystem::COUNT],
                calls: [0; Subsystem::COUNT],
                paths: std::collections::HashMap::new(),
                root_ns: 0,
                busy_ns: 0,
            }
        }

        fn clear(&mut self) {
            *self = ThreadStats::new();
        }
    }

    struct Frame {
        sub: Subsystem,
        path: u64,
        start: Instant,
        child_ns: u64,
    }

    struct Local {
        shared: Arc<ThreadShared>,
        stack: Vec<Frame>,
    }

    thread_local! {
        static LOCAL: RefCell<Option<Local>> = const { RefCell::new(None) };
    }

    /// Lock that shrugs off poisoning: a panicking pool job must not
    /// take the profiler down with it (the pool catches the panic and
    /// keeps going).
    fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn shared_for_current_thread() -> Arc<ThreadShared> {
        let name = std::thread::current()
            .name()
            .unwrap_or("unnamed")
            .to_string();
        let mut reg = lock(&REGISTRY);
        if let Some(e) = reg.iter().find(|e| e.name == name) {
            return e.clone();
        }
        let e = Arc::new(ThreadShared { name, stats: Mutex::new(ThreadStats::new()) });
        reg.push(e.clone());
        e
    }

    /// RAII scope: records on drop.  Inert when profiling is disabled.
    #[must_use]
    pub struct Scope {
        armed: bool,
    }

    pub(super) fn scope(sub: Subsystem) -> Scope {
        if !ENABLED.load(Ordering::Relaxed) {
            return Scope { armed: false };
        }
        let ok = LOCAL
            .try_with(|cell| {
                let mut slot = cell.borrow_mut();
                let local = slot.get_or_insert_with(|| Local {
                    shared: shared_for_current_thread(),
                    stack: Vec::with_capacity(8),
                });
                let path = match local.stack.last() {
                    Some(p) if local.stack.len() >= MAX_DEPTH => p.path,
                    Some(p) => (p.path << 4) | (sub as u64 + 1),
                    None => sub as u64 + 1,
                };
                local.stack.push(Frame { sub, path, start: Instant::now(), child_ns: 0 });
            })
            .is_ok();
        Scope { armed: ok }
    }

    impl Drop for Scope {
        fn drop(&mut self) {
            if !self.armed {
                return;
            }
            let _ = LOCAL.try_with(|cell| {
                let mut slot = cell.borrow_mut();
                let Some(local) = slot.as_mut() else { return };
                let Some(frame) = local.stack.pop() else { return };
                let elapsed = frame.start.elapsed().as_nanos() as u64;
                let self_ns = elapsed.saturating_sub(frame.child_ns);
                if let Some(parent) = local.stack.last_mut() {
                    parent.child_ns += elapsed;
                }
                let is_root = local.stack.is_empty();
                let mut st = lock(&local.shared.stats);
                let i = frame.sub as usize;
                st.total_ns[i] += elapsed;
                st.self_ns[i] += self_ns;
                st.calls[i] += 1;
                let slot = st.paths.entry(frame.path).or_insert((0, 0));
                slot.0 += elapsed;
                slot.1 += self_ns;
                if is_root {
                    st.root_ns += elapsed;
                }
            });
        }
    }

    /// RAII pool busy-time tracker.
    #[must_use]
    pub struct BusyScope {
        start: Option<Instant>,
    }

    pub(super) fn busy_scope() -> BusyScope {
        if !ENABLED.load(Ordering::Relaxed) {
            return BusyScope { start: None };
        }
        BusyScope { start: Some(Instant::now()) }
    }

    impl Drop for BusyScope {
        fn drop(&mut self) {
            let Some(start) = self.start else { return };
            let elapsed = start.elapsed().as_nanos() as u64;
            let _ = LOCAL.try_with(|cell| {
                let mut slot = cell.borrow_mut();
                let local = slot.get_or_insert_with(|| Local {
                    shared: shared_for_current_thread(),
                    stack: Vec::with_capacity(8),
                });
                lock(&local.shared.stats).busy_ns += elapsed;
            });
        }
    }

    pub(super) fn count(c: Counter, n: u64) {
        if ENABLED.load(Ordering::Relaxed) {
            COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    pub(super) fn counter_value(c: Counter) -> u64 {
        COUNTERS[c as usize].load(Ordering::Relaxed)
    }

    pub(super) fn reset() {
        for c in &COUNTERS {
            c.store(0, Ordering::Relaxed);
        }
        for e in lock(&REGISTRY).iter() {
            lock(&e.stats).clear();
        }
    }

    fn decode_path(mut path: u64) -> String {
        let mut frames = Vec::new();
        while path != 0 {
            let nib = (path & 0xF) as usize;
            if (1..=Subsystem::COUNT).contains(&nib) {
                frames.push(Subsystem::ALL[nib - 1].name());
            }
            path >>= 4;
        }
        frames.reverse();
        let mut s = String::from("chipsim");
        for f in frames {
            s.push(';');
            s.push_str(f);
        }
        s
    }

    pub(super) fn report(wall_ns: u64) -> ProfileReport {
        let mut total = [0u64; Subsystem::COUNT];
        let mut self_ns = [0u64; Subsystem::COUNT];
        let mut calls = [0u64; Subsystem::COUNT];
        let mut paths: std::collections::HashMap<u64, (u64, u64)> =
            std::collections::HashMap::new();
        let mut cpu_ns = 0u64;
        let mut workers = Vec::new();
        for e in lock(&REGISTRY).iter() {
            let st = lock(&e.stats);
            for i in 0..Subsystem::COUNT {
                total[i] += st.total_ns[i];
                self_ns[i] += st.self_ns[i];
                calls[i] += st.calls[i];
            }
            for (path, (t, s)) in st.paths.iter() {
                let slot = paths.entry(*path).or_insert((0, 0));
                slot.0 += t;
                slot.1 += s;
            }
            cpu_ns += st.root_ns;
            if st.busy_ns > 0 {
                workers.push(WorkerStat {
                    name: e.name.clone(),
                    busy_ns: st.busy_ns,
                    util: if wall_ns > 0 {
                        (st.busy_ns as f64 / wall_ns as f64).min(1.0)
                    } else {
                        0.0
                    },
                });
            }
        }
        workers.sort_by(|a, b| a.name.cmp(&b.name));
        let subsystems: Vec<SubsystemStat> = Subsystem::ALL
            .iter()
            .enumerate()
            .filter(|(i, _)| calls[*i] > 0)
            .map(|(i, s)| SubsystemStat {
                name: s.name(),
                total_ns: total[i],
                self_ns: self_ns[i],
                calls: calls[i],
                share: if cpu_ns > 0 { self_ns[i] as f64 / cpu_ns as f64 } else { 0.0 },
            })
            .collect();
        let secs = (wall_ns as f64 / 1e9).max(1e-12);
        let counters: Vec<CounterStat> = Counter::ALL
            .iter()
            .map(|c| (*c, counter_value(*c)))
            .filter(|(_, v)| *v > 0)
            .map(|(c, v)| CounterStat { name: c.name(), value: v, per_s: v as f64 / secs })
            .collect();
        let mut path_stats: Vec<PathStat> = paths
            .into_iter()
            .map(|(p, (t, s))| PathStat { stack: decode_path(p), total_ns: t, self_ns: s })
            .collect();
        path_stats.sort_by(|a, b| a.stack.cmp(&b.stack));
        ProfileReport {
            wall_ns,
            cpu_ns,
            subsystems,
            counters,
            workers,
            paths: path_stats,
        }
    }
}

// ----------------------------------------------------------- public API

#[cfg(feature = "prof")]
pub use collect::{BusyScope, Scope};

/// Is profiling currently collecting?
#[cfg(feature = "prof")]
pub fn enabled() -> bool {
    collect::ENABLED.load(Ordering::Relaxed)
}

/// Start collecting (resets all prior state first).
#[cfg(feature = "prof")]
pub fn enable() {
    collect::reset();
    collect::ENABLED.store(true, Ordering::SeqCst);
}

/// Stop collecting (state is kept until the next [`enable`]/[`reset`]).
#[cfg(feature = "prof")]
pub fn disable() {
    collect::ENABLED.store(false, Ordering::SeqCst);
}

/// Zero every counter, scope stat, and worker row.
#[cfg(feature = "prof")]
pub fn reset() {
    collect::reset();
}

/// Enter a subsystem scope; time is recorded when the guard drops.
#[cfg(feature = "prof")]
#[inline]
pub fn scope(sub: Subsystem) -> Scope {
    collect::scope(sub)
}

/// Track pool busy-time for the current (worker) thread.
#[cfg(feature = "prof")]
#[inline]
pub fn busy_scope() -> BusyScope {
    collect::busy_scope()
}

/// Bump a monotonic counter by `n` (no-op when disabled).
#[cfg(feature = "prof")]
#[inline]
pub fn count(c: Counter, n: u64) {
    collect::count(c, n);
}

/// Current value of a counter (0 when the feature is off).
#[cfg(feature = "prof")]
pub fn counter_value(c: Counter) -> u64 {
    collect::counter_value(c)
}

/// Snapshot the profiler against a wall-clock window, or `None` when
/// profiling is disabled (or compiled out).  Does not reset.
#[cfg(feature = "prof")]
pub fn snapshot(wall_ns: u64) -> Option<ProfileReport> {
    if enabled() {
        Some(collect::report(wall_ns))
    } else {
        None
    }
}

// Feature-off stubs: identical signatures, empty bodies, so every hook
// site compiles away under --no-default-features.

/// Inert scope guard (feature off).
#[cfg(not(feature = "prof"))]
#[must_use]
pub struct Scope;

/// Inert busy-time guard (feature off).
#[cfg(not(feature = "prof"))]
#[must_use]
pub struct BusyScope;

#[cfg(not(feature = "prof"))]
pub fn enabled() -> bool {
    false
}

#[cfg(not(feature = "prof"))]
pub fn enable() {}

#[cfg(not(feature = "prof"))]
pub fn disable() {}

#[cfg(not(feature = "prof"))]
pub fn reset() {}

#[cfg(not(feature = "prof"))]
#[inline(always)]
pub fn scope(_sub: Subsystem) -> Scope {
    Scope
}

#[cfg(not(feature = "prof"))]
#[inline(always)]
pub fn busy_scope() -> BusyScope {
    BusyScope
}

#[cfg(not(feature = "prof"))]
#[inline(always)]
pub fn count(_c: Counter, _n: u64) {}

#[cfg(not(feature = "prof"))]
pub fn counter_value(_c: Counter) -> u64 {
    0
}

#[cfg(not(feature = "prof"))]
pub fn snapshot(_wall_ns: u64) -> Option<ProfileReport> {
    None
}

// Tests that *arm* the profiler live in `rust/tests/prof.rs`: this lib
// test binary runs sim/fleet/noc tests concurrently on other threads,
// and their hook sites would record into the armed global profiler.
// The integration binary contains only serialized profiler tests.
#[cfg(all(test, feature = "prof"))]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_records_nothing() {
        // Nothing in the lib test binary enables profiling, so state
        // stays empty no matter which tests run concurrently.
        {
            let _s = scope(Subsystem::EventLoop);
        }
        count(Counter::Events, 5);
        assert!(!enabled());
        assert!(snapshot(1).is_none());
        assert_eq!(counter_value(Counter::Events), 0);
    }
}
