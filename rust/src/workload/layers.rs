//! Layer descriptors and constructors for the supported layer types.
//!
//! Each layer records exactly what the compute backends and the traffic
//! generator need: MAC count, stationary weight footprint, activation
//! input/output volumes.  Quantization follows the IMC setting of the
//! paper's cited chips: int8 weights and activations (1 byte/element).

/// Layer category (used by mapping and the compute backends).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Spatial convolution.
    Conv,
    /// Fully connected / linear projection.
    Fc,
    /// Pooling (no weights, negligible MACs, reduces activation volume).
    Pool,
    /// Attention score + weighted-sum compute (no stationary weights).
    Attention,
    /// Patch / token embedding (a strided conv in ViT).
    Embed,
}

/// One DNN layer in the layer-wise workload representation (paper §III-B).
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    /// Multiply-accumulate operations for one inference of this layer.
    pub macs: u64,
    /// Stationary weight bytes (int8) — the chiplet memory the layer needs.
    pub weight_bytes: u64,
    /// Input activation bytes received from the previous layer.
    pub in_bytes: u64,
    /// Output activation elements (drives ADC conversions on IMC).
    pub out_elems: u64,
    /// Output activation bytes sent to the next layer (int8).
    pub out_bytes: u64,
}

impl LayerDesc {
    /// Convolution: input (h, w, c), `k` output channels, `ksize`^2 kernel,
    /// stride, `same`-style padding (output spatial dims = ceil(h/stride)).
    pub fn conv(name: &str, h: u64, w: u64, c: u64, k: u64, ksize: u64, stride: u64) -> LayerDesc {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let out_elems = oh * ow * k;
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Conv,
            macs: out_elems * ksize * ksize * c,
            weight_bytes: ksize * ksize * c * k,
            in_bytes: h * w * c,
            out_elems,
            out_bytes: out_elems,
        }
    }

    /// Fully connected `n_in -> n_out` (optionally over `tokens` rows).
    pub fn fc(name: &str, n_in: u64, n_out: u64, tokens: u64) -> LayerDesc {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Fc,
            macs: tokens * n_in * n_out,
            weight_bytes: n_in * n_out,
            in_bytes: tokens * n_in,
            out_elems: tokens * n_out,
            out_bytes: tokens * n_out,
        }
    }

    /// Pooling over (h, w, c) with the given stride (no weights).
    pub fn pool(name: &str, h: u64, w: u64, c: u64, stride: u64) -> LayerDesc {
        let oh = h / stride;
        let ow = w / stride;
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Pool,
            // Comparisons/adds — negligible next to convs, but non-zero.
            macs: oh * ow * c * stride * stride,
            weight_bytes: 0,
            in_bytes: h * w * c,
            out_elems: oh * ow * c,
            out_bytes: oh * ow * c,
        }
    }

    /// Multi-head self-attention core: scores (T×T×D) + weighted sum.
    /// No stationary weights (QKV/proj are separate `fc` layers).
    pub fn attention(name: &str, tokens: u64, dim: u64) -> LayerDesc {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Attention,
            macs: 2 * tokens * tokens * dim,
            weight_bytes: 0,
            in_bytes: 3 * tokens * dim, // Q, K, V
            out_elems: tokens * dim,
            out_bytes: tokens * dim,
        }
    }

    /// ViT patch embedding: a `p`×`p` stride-`p` conv from 3 channels.
    pub fn patch_embed(name: &str, img: u64, p: u64, dim: u64) -> LayerDesc {
        let tokens = (img / p) * (img / p) + 1; // + class token
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Embed,
            macs: (img / p) * (img / p) * dim * p * p * 3,
            weight_bytes: p * p * 3 * dim,
            in_bytes: img * img * 3,
            out_elems: tokens * dim,
            out_bytes: tokens * dim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_dims_match_hand_calc() {
        // AlexNet conv1: 224x224x3, 96 kernels 11x11 stride 4 -> 56x56x96.
        let l = LayerDesc::conv("conv1", 224, 224, 3, 96, 11, 4);
        assert_eq!(l.out_elems, 56 * 56 * 96);
        assert_eq!(l.weight_bytes, 11 * 11 * 3 * 96);
        assert_eq!(l.macs, 56 * 56 * 96 * 11 * 11 * 3);
    }

    #[test]
    fn fc_is_dense_matmul() {
        let l = LayerDesc::fc("fc6", 9216, 4096, 1);
        assert_eq!(l.macs, 9216 * 4096);
        assert_eq!(l.weight_bytes, 9216 * 4096);
        assert_eq!(l.out_bytes, 4096);
    }

    #[test]
    fn pool_has_no_weights_and_shrinks_acts() {
        let l = LayerDesc::pool("p", 56, 56, 96, 2);
        assert_eq!(l.weight_bytes, 0);
        assert_eq!(l.out_bytes, 28 * 28 * 96);
        assert!(l.out_bytes < l.in_bytes);
    }

    #[test]
    fn attention_quadratic_in_tokens() {
        let a = LayerDesc::attention("attn", 197, 768);
        assert_eq!(a.macs, 2 * 197 * 197 * 768);
        assert_eq!(a.weight_bytes, 0);
    }
}
