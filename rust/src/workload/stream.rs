//! The streaming model queue and age-aware arbitration (paper §III-B, §V-A).
//!
//! A workload is a stream of DNN model requests.  The Global Manager pulls
//! from an [`ArbitrationQueue`] that allows out-of-order mapping (so small
//! models are not starved behind a large one) but becomes head-of-line
//! blocking once a request exceeds the age threshold — exactly the policy
//! described in the paper's experimental setup.

use std::collections::VecDeque;

use crate::workload::models::{ModelKind, ALL_CNNS};
use crate::util::rng::Rng;
use crate::TimeNs;

/// One model request in the stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelRequest {
    pub id: usize,
    pub kind: ModelKind,
    /// Time the request entered the queue.
    pub arrival_ns: TimeNs,
    /// Back-to-back inferences to execute once mapped (paper Table III).
    pub inferences: u32,
    /// Owning tenant in a multi-tenant mix (0 for single-tenant runs).
    /// Placement masks, SLO accounting, and NoI flow attribution key off
    /// this index (see [`crate::serving::mix`]).
    pub tenant: usize,
}

/// Generator for the paper's driver workload: `n` models uniformly sampled
/// from the four CNN types, injected at the given interval (the paper uses
/// injection rate 1 — effectively all requests are queued immediately).
#[derive(Debug, Clone)]
pub struct WorkloadStream {
    pub requests: Vec<ModelRequest>,
}

impl WorkloadStream {
    /// Uniformly sample `n` CNN models (paper §V-A: 50 models from 4 types).
    pub fn sample_cnns(n: usize, inferences: u32, injection_interval_ns: TimeNs, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let requests = (0..n)
            .map(|id| ModelRequest {
                id,
                kind: *rng.choice(&ALL_CNNS),
                arrival_ns: id as TimeNs * injection_interval_ns,
                inferences,
                tenant: 0,
            })
            .collect();
        WorkloadStream { requests }
    }

    /// A fixed list of kinds, all arriving back-to-back.
    pub fn from_kinds(kinds: &[ModelKind], inferences: u32, injection_interval_ns: TimeNs) -> Self {
        let requests = kinds
            .iter()
            .enumerate()
            .map(|(id, &kind)| ModelRequest {
                id,
                kind,
                arrival_ns: id as TimeNs * injection_interval_ns,
                inferences,
                tenant: 0,
            })
            .collect();
        WorkloadStream { requests }
    }

    /// Single-model workload (used by the ViT evaluation and baselines).
    pub fn single(kind: ModelKind, inferences: u32) -> Self {
        WorkloadStream::from_kinds(&[kind], inferences, 0)
    }
}

/// Age-aware arbitration queue (paper §V-A):
/// * oldest requests are tried first;
/// * a request that cannot be mapped *may* be skipped so younger requests
///   can map (out-of-order execution, prevents starvation of small models);
/// * once a request's age exceeds `age_threshold_ns` it becomes
///   non-skippable and blocks all younger requests until it maps.
#[derive(Debug)]
pub struct ArbitrationQueue {
    pending: VecDeque<ModelRequest>, // kept sorted by arrival (oldest first)
    pub age_threshold_ns: TimeNs,
}

impl ArbitrationQueue {
    pub fn new(age_threshold_ns: TimeNs) -> Self {
        ArbitrationQueue { pending: VecDeque::new(), age_threshold_ns }
    }

    pub fn push(&mut self, req: ModelRequest) {
        // In-order arrivals (every stream generator emits monotone times)
        // append at the back in O(1).  Out-of-order pushes — bursty
        // arrival generators, or a request re-queued after a failed drop
        // probe — fall back to an ordered insert that keeps ties stable
        // (a new request goes after existing equals).
        let in_order = match self.pending.back() {
            Some(back) => back.arrival_ns <= req.arrival_ns,
            None => true,
        };
        if in_order {
            self.pending.push_back(req);
            return;
        }
        let pos = self
            .pending
            .iter()
            .position(|r| r.arrival_ns > req.arrival_ns)
            .unwrap_or(self.pending.len());
        self.pending.insert(pos, req);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Find the next request to map at time `now`: walk oldest-to-youngest,
    /// return the first for which `can_map` holds; stop the walk at any
    /// non-mappable request that is over the age threshold (it blocks).
    /// Removes and returns the selected request.
    pub fn take_next_mappable<F>(&mut self, now: TimeNs, mut can_map: F) -> Option<ModelRequest>
    where
        F: FnMut(&ModelRequest) -> bool,
    {
        for i in 0..self.pending.len() {
            let req = &self.pending[i];
            if can_map(req) {
                return self.pending.remove(i);
            }
            let age = now.saturating_sub(req.arrival_ns);
            if age >= self.age_threshold_ns {
                // Non-skippable: blocks all younger requests.
                return None;
            }
        }
        None
    }

    /// Iterate pending requests, oldest first (diagnostics).
    pub fn pending(&self) -> impl Iterator<Item = &ModelRequest> {
        self.pending.iter()
    }

    /// Remove and return every pending request, oldest first.  The fleet
    /// migration hook uses this to pull the backlog off a board that
    /// tripped its thermal-emergency predicate and re-route it elsewhere
    /// (original arrival times are preserved by the caller).
    pub fn drain_pending(&mut self) -> Vec<ModelRequest> {
        self.pending.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, kind: ModelKind, arrival: TimeNs) -> ModelRequest {
        ModelRequest { id, kind, arrival_ns: arrival, inferences: 1, tenant: 0 }
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        let a = WorkloadStream::sample_cnns(50, 10, 1, 7);
        let b = WorkloadStream::sample_cnns(50, 10, 1, 7);
        let kinds_a: Vec<_> = a.requests.iter().map(|r| r.kind).collect();
        let kinds_b: Vec<_> = b.requests.iter().map(|r| r.kind).collect();
        assert_eq!(kinds_a, kinds_b);
        assert_eq!(a.requests.len(), 50);
    }

    #[test]
    fn stream_samples_all_four_kinds() {
        let s = WorkloadStream::sample_cnns(100, 10, 1, 3);
        for kind in ALL_CNNS {
            assert!(s.requests.iter().any(|r| r.kind == kind), "{kind:?} missing");
        }
    }

    #[test]
    fn arbitration_prefers_oldest_mappable() {
        let mut q = ArbitrationQueue::new(1_000_000);
        q.push(req(0, ModelKind::ResNet50, 0));
        q.push(req(1, ModelKind::AlexNet, 10));
        q.push(req(2, ModelKind::ResNet18, 20));
        // ResNet50 can't map; next oldest mappable is AlexNet.
        let got = q
            .take_next_mappable(100, |r| r.kind != ModelKind::ResNet50)
            .unwrap();
        assert_eq!(got.id, 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn over_age_request_blocks_younger() {
        let mut q = ArbitrationQueue::new(1_000);
        q.push(req(0, ModelKind::ResNet50, 0));
        q.push(req(1, ModelKind::AlexNet, 10));
        // Age of request 0 is 5000 >= threshold -> blocks, even though
        // request 1 would map.
        assert!(q.take_next_mappable(5_000, |r| r.kind != ModelKind::ResNet50).is_none());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn out_of_order_push_keeps_arrival_order() {
        // Bursty generators and re-queued requests can push behind the
        // back of the queue; the ordered-insert fallback must keep the
        // oldest-first invariant that arbitration depends on.
        let mut q = ArbitrationQueue::new(1_000_000);
        q.push(req(0, ModelKind::AlexNet, 100));
        q.push(req(1, ModelKind::ResNet18, 50)); // out of order
        q.push(req(2, ModelKind::ResNet34, 100)); // tie: goes after id 0
        q.push(req(3, ModelKind::ResNet50, 200)); // fast path
        let order: Vec<usize> = q.pending().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 0, 2, 3]);
        assert_eq!(q.take_next_mappable(0, |_| true).unwrap().id, 1);
    }

    #[test]
    fn under_age_request_is_skippable() {
        let mut q = ArbitrationQueue::new(1_000_000);
        q.push(req(0, ModelKind::ResNet50, 0));
        q.push(req(1, ModelKind::AlexNet, 10));
        let got = q.take_next_mappable(100, |r| r.kind != ModelKind::ResNet50);
        assert_eq!(got.unwrap().id, 1);
    }
}
