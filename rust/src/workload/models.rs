//! The DNN model zoo: AlexNet, ResNet-18/34/50, ViT-B/16 (paper §V-A).
//!
//! Architectures follow the original papers ([51], [52], [53]); layer
//! tables are generated programmatically from the stage definitions so
//! MAC/weight/activation numbers are self-consistent with `LayerDesc`.

use super::layers::LayerDesc;
#[cfg(test)]
use super::layers::LayerKind;

/// The model types used in the paper's evaluations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    AlexNet,
    ResNet18,
    ResNet34,
    ResNet50,
    VitB16,
    /// VGG-16 [Simonyan & Zisserman] — the classic heavyweight CNN;
    /// useful for DSE because its 138 M parameters stress the mapper.
    Vgg16,
    /// MobileNetV1 — depthwise-separable CNN; the small/latency-bound
    /// end of the workload spectrum.
    MobileNetV1,
}

/// The four CNNs sampled by the driver workload (paper Table III).
pub const ALL_CNNS: [ModelKind; 4] = [
    ModelKind::AlexNet,
    ModelKind::ResNet18,
    ModelKind::ResNet34,
    ModelKind::ResNet50,
];

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::AlexNet => "AlexNet",
            ModelKind::ResNet18 => "ResNet18",
            ModelKind::ResNet34 => "ResNet34",
            ModelKind::ResNet50 => "ResNet50",
            ModelKind::VitB16 => "ViT-B/16",
            ModelKind::Vgg16 => "VGG16",
            ModelKind::MobileNetV1 => "MobileNetV1",
        }
    }

    pub fn from_name(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "alexnet" => Some(ModelKind::AlexNet),
            "resnet18" => Some(ModelKind::ResNet18),
            "resnet34" => Some(ModelKind::ResNet34),
            "resnet50" => Some(ModelKind::ResNet50),
            "vit" | "vitb16" | "vit-b/16" | "vit-b16" => Some(ModelKind::VitB16),
            "vgg16" | "vgg" => Some(ModelKind::Vgg16),
            "mobilenet" | "mobilenetv1" => Some(ModelKind::MobileNetV1),
            _ => None,
        }
    }
}

/// A layer-wise DNN model instance description.
#[derive(Debug, Clone)]
pub struct NeuralModel {
    pub kind: ModelKind,
    pub layers: Vec<LayerDesc>,
}

impl NeuralModel {
    /// Build the layer table for a model kind.
    pub fn build(kind: ModelKind) -> NeuralModel {
        let layers = match kind {
            ModelKind::AlexNet => alexnet(),
            ModelKind::ResNet18 => resnet(&[2, 2, 2, 2], false),
            ModelKind::ResNet34 => resnet(&[3, 4, 6, 3], false),
            ModelKind::ResNet50 => resnet(&[3, 4, 6, 3], true),
            ModelKind::VitB16 => vit_b16(),
            ModelKind::Vgg16 => vgg16(),
            ModelKind::MobileNetV1 => mobilenet_v1(),
        };
        NeuralModel { kind, layers }
    }

    /// Total stationary weight bytes (the memory the mapper must place).
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes).sum()
    }

    /// Total MACs per inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
}

// --------------------------------------------------------------- AlexNet

fn alexnet() -> Vec<LayerDesc> {
    let mut v = Vec::new();
    v.push(LayerDesc::conv("conv1", 224, 224, 3, 96, 11, 4)); // 56x56x96
    v.push(LayerDesc::pool("pool1", 56, 56, 96, 2)); // 28x28x96
    v.push(LayerDesc::conv("conv2", 28, 28, 96, 256, 5, 1));
    v.push(LayerDesc::pool("pool2", 28, 28, 256, 2)); // 14x14
    v.push(LayerDesc::conv("conv3", 14, 14, 256, 384, 3, 1));
    v.push(LayerDesc::conv("conv4", 14, 14, 384, 384, 3, 1));
    v.push(LayerDesc::conv("conv5", 14, 14, 384, 256, 3, 1));
    v.push(LayerDesc::pool("pool5", 14, 14, 256, 2)); // 7x7x256
    v.push(LayerDesc::fc("fc6", 7 * 7 * 256, 4096, 1));
    v.push(LayerDesc::fc("fc7", 4096, 4096, 1));
    v.push(LayerDesc::fc("fc8", 4096, 1000, 1));
    v
}

// --------------------------------------------------------------- ResNets

/// ResNet with the given blocks-per-stage; `bottleneck` selects the
/// 1x1-3x3-1x1 block (ResNet-50) vs the 3x3-3x3 basic block (18/34).
fn resnet(blocks: &[usize; 4], bottleneck: bool) -> Vec<LayerDesc> {
    let mut v = Vec::new();
    v.push(LayerDesc::conv("conv1", 224, 224, 3, 64, 7, 2)); // 112x112x64
    v.push(LayerDesc::pool("maxpool", 112, 112, 64, 2)); // 56x56x64

    let stage_channels = [64u64, 128, 256, 512];
    let mut h = 56u64;
    let mut c_in = 64u64;
    for (s, (&nblocks, &ch)) in blocks.iter().zip(stage_channels.iter()).enumerate() {
        for b in 0..nblocks {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            if stride == 2 {
                h /= 2;
            }
            if bottleneck {
                // 1x1 reduce -> 3x3 -> 1x1 expand (4x).
                let pre = format!("s{}b{}", s + 1, b + 1);
                v.push(LayerDesc::conv(&format!("{pre}_c1"), h * stride, h * stride, c_in, ch, 1, stride));
                v.push(LayerDesc::conv(&format!("{pre}_c2"), h, h, ch, ch, 3, 1));
                v.push(LayerDesc::conv(&format!("{pre}_c3"), h, h, ch, ch * 4, 1, 1));
                c_in = ch * 4;
            } else {
                let pre = format!("s{}b{}", s + 1, b + 1);
                v.push(LayerDesc::conv(&format!("{pre}_c1"), h * stride, h * stride, c_in, ch, 3, stride));
                v.push(LayerDesc::conv(&format!("{pre}_c2"), h, h, ch, ch, 3, 1));
                c_in = ch;
            }
        }
    }
    // Global average pool + classifier.
    v.push(LayerDesc::pool("avgpool", h, h, c_in, h));
    v.push(LayerDesc::fc("fc", c_in, 1000, 1));
    v
}

// ----------------------------------------------------------------- VGG-16

fn vgg16() -> Vec<LayerDesc> {
    // Stages: 2x64, 2x128, 3x256, 3x512, 3x512 (3x3 convs), pool between,
    // then 4096-4096-1000 classifier.
    let mut v = Vec::new();
    let stages: [(usize, u64); 5] = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)];
    let mut h = 224u64;
    let mut c_in = 3u64;
    for (s, &(n, ch)) in stages.iter().enumerate() {
        for b in 0..n {
            v.push(LayerDesc::conv(&format!("s{}c{}", s + 1, b + 1), h, h, c_in, ch, 3, 1));
            c_in = ch;
        }
        v.push(LayerDesc::pool(&format!("pool{}", s + 1), h, h, ch, 2));
        h /= 2;
    }
    v.push(LayerDesc::fc("fc6", 7 * 7 * 512, 4096, 1));
    v.push(LayerDesc::fc("fc7", 4096, 4096, 1));
    v.push(LayerDesc::fc("fc8", 4096, 1000, 1));
    v
}

// ------------------------------------------------------------ MobileNetV1

/// Depthwise 3x3 conv: per-channel spatial filter (groups == channels).
fn dw_conv(name: &str, h: u64, c: u64, stride: u64) -> LayerDesc {
    let oh = h.div_ceil(stride);
    LayerDesc {
        name: name.to_string(),
        kind: super::layers::LayerKind::Conv,
        macs: oh * oh * c * 9,
        weight_bytes: 9 * c,
        in_bytes: h * h * c,
        out_elems: oh * oh * c,
        out_bytes: oh * oh * c,
    }
}

fn mobilenet_v1() -> Vec<LayerDesc> {
    let mut v = Vec::new();
    v.push(LayerDesc::conv("conv1", 224, 224, 3, 32, 3, 2)); // 112x112x32
    // (stride, out_channels) sequence of the 13 depthwise-separable blocks.
    let blocks: [(u64, u64); 13] = [
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
        (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
    ];
    let mut h = 112u64;
    let mut c = 32u64;
    for (i, &(stride, ch)) in blocks.iter().enumerate() {
        v.push(dw_conv(&format!("dw{}", i + 1), h, c, stride));
        h = h.div_ceil(stride);
        // Pointwise 1x1 expansion.
        v.push(LayerDesc::conv(&format!("pw{}", i + 1), h, h, c, ch, 1, 1));
        c = ch;
    }
    v.push(LayerDesc::pool("avgpool", h, h, c, h));
    v.push(LayerDesc::fc("fc", c, 1000, 1));
    v
}

// --------------------------------------------------------------- ViT-B/16

fn vit_b16() -> Vec<LayerDesc> {
    let dim = 768u64;
    let tokens = 197u64;
    let mlp = 3072u64;
    let mut v = Vec::new();
    v.push(LayerDesc::patch_embed("patch_embed", 224, 16, dim));
    for b in 0..12 {
        v.push(LayerDesc::fc(&format!("blk{b}_qkv"), dim, 3 * dim, tokens));
        v.push(LayerDesc::attention(&format!("blk{b}_attn"), tokens, dim));
        v.push(LayerDesc::fc(&format!("blk{b}_proj"), dim, dim, tokens));
        v.push(LayerDesc::fc(&format!("blk{b}_mlp1"), dim, mlp, tokens));
        v.push(LayerDesc::fc(&format!("blk{b}_mlp2"), mlp, dim, tokens));
    }
    v.push(LayerDesc::fc("head", dim, 1000, 1));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_architectures() {
        assert_eq!(NeuralModel::build(ModelKind::AlexNet).layers.len(), 11);
        // 18/34/50 conv+fc counts (pool layers extra).
        let count_weighted = |k: ModelKind| {
            NeuralModel::build(k)
                .layers
                .iter()
                .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Fc | LayerKind::Embed))
                .count()
        };
        assert_eq!(count_weighted(ModelKind::ResNet18), 18);
        assert_eq!(count_weighted(ModelKind::ResNet34), 34);
        assert_eq!(count_weighted(ModelKind::ResNet50), 50);
    }

    #[test]
    fn parameter_counts_are_plausible() {
        // Known ballparks (int8 => bytes == params): AlexNet ~61M (ours is
        // ~76M: even-dimension pooling gives fc6 a 7x7x256 input vs the
        // original 6x6x256, and convs are ungrouped), ResNet18 ~11.7M,
        // ResNet34 ~21.8M, ResNet50 ~25.6M, ViT-B ~86M.
        let wb = |k| NeuralModel::build(k).total_weight_bytes() as f64 / 1e6;
        assert!((55.0..80.0).contains(&wb(ModelKind::AlexNet)), "{}", wb(ModelKind::AlexNet));
        assert!((10.0..13.0).contains(&wb(ModelKind::ResNet18)), "{}", wb(ModelKind::ResNet18));
        assert!((19.0..24.0).contains(&wb(ModelKind::ResNet34)), "{}", wb(ModelKind::ResNet34));
        assert!((20.0..28.0).contains(&wb(ModelKind::ResNet50)), "{}", wb(ModelKind::ResNet50));
        assert!((80.0..92.0).contains(&wb(ModelKind::VitB16)), "{}", wb(ModelKind::VitB16));
    }

    #[test]
    fn mac_counts_are_plausible() {
        // Ballparks: AlexNet ~0.7-1.1 GMAC, ResNet18 ~1.8G, ResNet34 ~3.6G,
        // ResNet50 ~4G, ViT-B ~17G.
        let gm = |k| NeuralModel::build(k).total_macs() as f64 / 1e9;
        assert!((0.6..1.5).contains(&gm(ModelKind::AlexNet)), "{}", gm(ModelKind::AlexNet));
        assert!((1.4..2.5).contains(&gm(ModelKind::ResNet18)), "{}", gm(ModelKind::ResNet18));
        assert!((3.0..4.6).contains(&gm(ModelKind::ResNet34)), "{}", gm(ModelKind::ResNet34));
        assert!((3.2..5.5).contains(&gm(ModelKind::ResNet50)), "{}", gm(ModelKind::ResNet50));
        assert!((14.0..20.0).contains(&gm(ModelKind::VitB16)), "{}", gm(ModelKind::VitB16));
    }

    #[test]
    fn resnet_stage_downsampling_halves_dims() {
        let m = NeuralModel::build(ModelKind::ResNet18);
        // Final feature map is 7x7x512 -> avgpool out 512 elements.
        let avg = m.layers.iter().find(|l| l.name == "avgpool").unwrap();
        assert_eq!(avg.out_elems, 512);
    }

    #[test]
    fn vgg16_matches_published_stats() {
        let m = NeuralModel::build(ModelKind::Vgg16);
        // ~138M params, ~15.5 GMACs; 13 convs + 3 fc.
        let params = m.total_weight_bytes() as f64 / 1e6;
        let gmacs = m.total_macs() as f64 / 1e9;
        assert!((130.0..145.0).contains(&params), "{params}");
        assert!((14.0..17.5).contains(&gmacs), "{gmacs}");
        let weighted = m
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Fc))
            .count();
        assert_eq!(weighted, 16);
    }

    #[test]
    fn mobilenet_matches_published_stats() {
        let m = NeuralModel::build(ModelKind::MobileNetV1);
        // ~4.2M params, ~570 MMACs.
        let params = m.total_weight_bytes() as f64 / 1e6;
        let mmacs = m.total_macs() as f64 / 1e6;
        assert!((3.5..5.0).contains(&params), "{params}");
        assert!((450.0..700.0).contains(&mmacs), "{mmacs}");
        // Depthwise layers are tiny in weights but not in activations.
        let dw1 = m.layers.iter().find(|l| l.name == "dw1").unwrap();
        assert_eq!(dw1.weight_bytes, 9 * 32);
        assert!(dw1.out_bytes > 100_000);
    }

    #[test]
    fn new_models_map_and_simulate() {
        use crate::config::{HardwareConfig, SimParams, WorkloadConfig};
        use crate::sim::Simulation;
        let hw = HardwareConfig::homogeneous_mesh(10, 10);
        let params = SimParams {
            inferences_per_model: 1,
            warmup_ns: 0,
            cooldown_ns: 0,
            ..SimParams::default()
        };
        for kind in [ModelKind::Vgg16, ModelKind::MobileNetV1] {
            let report = Simulation::builder()
                .hardware(hw.clone())
                .params(params.clone())
                .build()
                .unwrap()
                .run(WorkloadConfig::single(kind))
                .unwrap();
            assert_eq!(report.outcomes.len(), 1, "{kind:?}");
            assert!(report.outcomes[0].mean_latency_ns() > 0.0);
        }
    }

    #[test]
    fn names_roundtrip() {
        for k in ALL_CNNS
            .iter()
            .chain([ModelKind::VitB16, ModelKind::Vgg16, ModelKind::MobileNetV1].iter())
        {
            assert_eq!(ModelKind::from_name(k.name()), Some(*k));
        }
    }
}
