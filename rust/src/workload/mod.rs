//! Input workloads: DNN model zoo, layer descriptors, traffic generation,
//! and the streaming model queue with age-aware arbitration (paper §III-B,
//! §V-A).
//!
//! Models are represented layer-wise; each layer carries the operation
//! counts the compute backends need (MACs, weight bytes, activation sizes)
//! and the activation volume the traffic generator turns into NoI flows.

mod layers;
mod models;
mod stream;

pub use layers::{LayerDesc, LayerKind};
pub use models::{ModelKind, NeuralModel, ALL_CNNS};
pub use stream::{ArbitrationQueue, ModelRequest, WorkloadStream};

/// Bytes moved from layer `i` to layer `i+1` (int8 activations).
///
/// The paper's Traffic Generator: layer-wise activations are known ahead
/// of simulation; the Global Manager turns them into chiplet-to-chiplet
/// flows once the mapping is known.
pub fn activation_traffic_bytes(model: &NeuralModel, layer_idx: usize) -> u64 {
    model.layers[layer_idx].out_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_equals_out_bytes() {
        let m = NeuralModel::build(ModelKind::AlexNet);
        for i in 0..m.layers.len() {
            assert_eq!(activation_traffic_bytes(&m, i), m.layers[i].out_bytes);
        }
    }
}
