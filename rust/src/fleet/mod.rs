//! Fleet-scale serving: a datacenter of chiplet boards behind one
//! dispatcher.
//!
//! A [`Fleet`] owns N replica boards — each a full board-level
//! [`Simulation`] with its own NoI state, thermal RC network, DTM
//! governor, and independent deterministic seed — and drives them from a
//! single global arrival stream through a pluggable
//! [`RoutingPolicy`](routing::RoutingPolicy).  An optional
//! [`Autoscaler`](autoscale::Autoscaler) grows and shrinks the fleet
//! with an explicit model cold-start cost, and a thermal-emergency
//! predicate migrates queued work away from boards that trip it.
//!
//! # The epoch-barrier clock model
//!
//! Replicas are discrete-event simulations with private virtual clocks;
//! the dispatcher needs a *consistent* view of all of them to route
//! well.  The fleet therefore advances in bounded virtual-time epochs:
//!
//! ```text
//!   barrier k                      barrier k+1
//!      |   epoch k: (B, B+epoch_ns]   |
//!      v                              v
//!  snapshot ──► migrate ──► autoscale ──► route ──► advance ∥ ──► ...
//! ```
//!
//! At each barrier the dispatcher (single-threaded) takes a
//! [`ReplicaSnapshot`] of every board — outstanding work, compute
//! utilization, hottest sensor reading — then performs all control
//! decisions against that frozen state: thermal-emergency migration,
//! scale up/down, and routing of every arrival that falls inside the
//! upcoming epoch.  Only then do all boards advance *in parallel* on the
//! shared worker pool ([`crate::util::pool`]) to the common epoch end;
//! the pool join is the barrier.  No replica ever runs ahead of another
//! by more than one epoch, so routing never observes a board's future,
//! and the whole construction is deterministic: identical seeds produce
//! byte-identical [`FleetReport`]s for any worker-thread count, because
//! threads only decide *when* a replica advances, never *what* it
//! observes.
//!
//! Epochs whose span contains no arrivals and no replica events are
//! skipped (the dispatcher fast-forwards to the next known wake time),
//! so a sparse trace does not pay per-epoch overhead across dead time.
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let spec = FleetSpec::new(TrafficSpec::poisson(8_000.0).steady(None), 4);
//! let report = Fleet::new(
//!     spec,
//!     || {
//!         Simulation::builder()
//!             .hardware(HardwareConfig::homogeneous_mesh(6, 6))
//!             .build()
//!     },
//!     Box::new(chipsim::fleet::LeastOutstanding),
//! )
//! .run(7)
//! .expect("fleet run");
//! println!("{}", report.summary());
//! ```

pub mod autoscale;
pub mod routing;

pub use autoscale::{parse_autoscaler, Autoscaler, QueueDepth, ScaleEvent, TargetUtilization};
pub use routing::{
    parse_routing, LeastOutstanding, RoundRobin, RoutingPolicy, SessionAffinity, ThermalAware,
};

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use crate::fault::{
    DowntimeTracker, FaultKind, FaultPlan, FaultReport, FaultTimelineEntry, RetryPolicy,
};
use crate::serving::engine::WindowRoller;
use crate::serving::{ServingStats, StreamingSource, TrafficSpec, WindowSummary};
use crate::sim::{
    ModelOutcome, PowerPort, RequestSource, RunStatus, SimReport, Simulation, StreamSink,
};
use crate::trace::{handle, BreakdownStats, TraceConfig, TraceHandle, TraceRecorder, PID_STRIDE};
use crate::util::rng::Rng;
use crate::workload::{ModelKind, ModelRequest};
use crate::TimeNs;

// -------------------------------------------------------------------- spec

/// Configuration of a fleet run.  The embedded [`TrafficSpec`] describes
/// the *global* offered load and the SLO every replica is held to;
/// steady-state early stop is ignored (a fleet always runs its full
/// horizon — convergence of one board says nothing about the others).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub traffic: TrafficSpec,
    /// Boards at t=0 (these start warm).
    pub replicas: usize,
    /// Autoscaling ceiling; clamped up to `replicas`.
    pub max_replicas: usize,
    /// Epoch width: the routing/control cadence and the bound on
    /// replica clock skew.
    pub epoch_ns: TimeNs,
    /// Virtual time a scaled-up board spends loading weights before it
    /// accepts requests.
    pub cold_start_ns: TimeNs,
    /// Hottest-sensor threshold (°C) above which a board's queued work
    /// is migrated away at the barrier; `None` disables migration.
    pub emergency_c: Option<f64>,
    /// Worker threads for the parallel advance (0 = available
    /// parallelism).  Does not affect results, only wall clock.
    pub threads: usize,
    /// Fault-injection plan.  `board:` events crash replicas at the
    /// dispatcher level (queued work migrates, in-flight work retries
    /// under the plan's [`RetryPolicy`]); every other kind is armed
    /// identically on each replica board.
    pub faults: Option<FaultPlan>,
}

impl FleetSpec {
    pub fn new(traffic: TrafficSpec, replicas: usize) -> FleetSpec {
        FleetSpec {
            traffic,
            replicas,
            max_replicas: replicas,
            epoch_ns: 200_000, // 200 µs
            cold_start_ns: 5_000_000, // 5 ms to load weights
            emergency_c: None,
            threads: 0,
            faults: None,
        }
    }

    pub fn faults(mut self, plan: Option<FaultPlan>) -> FleetSpec {
        self.faults = plan;
        self
    }

    pub fn max_replicas(mut self, n: usize) -> FleetSpec {
        self.max_replicas = n;
        self
    }

    pub fn epoch_us(mut self, us: f64) -> FleetSpec {
        self.epoch_ns = (us * 1e3) as TimeNs;
        self
    }

    pub fn cold_start_ms(mut self, ms: f64) -> FleetSpec {
        self.cold_start_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn emergency_c(mut self, c: f64) -> FleetSpec {
        self.emergency_c = Some(c);
        self
    }

    pub fn threads(mut self, n: usize) -> FleetSpec {
        self.threads = n;
        self
    }

    fn validate(&self) -> anyhow::Result<()> {
        self.traffic.validate()?;
        anyhow::ensure!(self.replicas >= 1, "fleet needs at least one replica");
        anyhow::ensure!(self.epoch_ns > 0, "fleet epoch_ns must be > 0");
        Ok(())
    }
}

// --------------------------------------------------------------- snapshot

/// Barrier-consistent view of one replica, as seen by routing,
/// autoscaling, and migration.  All fields are frozen at the barrier;
/// the dispatcher bumps `outstanding` as it routes within an epoch so
/// consecutive decisions see their own effect.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSnapshot {
    /// Stable replica index (position in [`FleetReport::replicas`]).
    pub id: usize,
    /// Warm, not retiring, not in thermal emergency.
    pub accepting: bool,
    /// Requests on the board (admission queue + in flight) plus the
    /// dispatcher-side epoch buffer.
    pub outstanding: usize,
    /// Board admission-queue depth only.
    pub queue_depth: usize,
    /// Fraction of chiplets busy at the barrier.
    pub busy_frac: f64,
    /// Hottest sensor/solver reading, if the board runs thermal state.
    pub hottest_c: Option<f64>,
    /// The replica's virtual clock at the barrier.
    pub now: TimeNs,
}

// ----------------------------------------------------------------- source

/// Dispatcher-side arrival buffer for one replica: requests routed (or
/// migrated) to the board but not yet consumed by its event loop.
/// Ordered by arrival time with stable ties, so migrated-in work
/// interleaves correctly with routed work.
#[derive(Debug, Default)]
struct ReplicaSource {
    buf: VecDeque<ModelRequest>,
}

impl ReplicaSource {
    fn push(&mut self, req: ModelRequest) {
        match self.buf.back() {
            Some(last) if last.arrival_ns > req.arrival_ns => {
                let at = self.buf.partition_point(|r| r.arrival_ns <= req.arrival_ns);
                self.buf.insert(at, req);
            }
            _ => self.buf.push_back(req),
        }
    }

    fn drain(&mut self) -> Vec<ModelRequest> {
        self.buf.drain(..).collect()
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl RequestSource for ReplicaSource {
    fn peek_arrival_ns(&mut self) -> Option<TimeNs> {
        self.buf.front().map(|r| r.arrival_ns)
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        self.buf.pop_front()
    }
}

// ------------------------------------------------------------------- sink

/// Per-replica streaming sink: the single-board `TrafficSink` without
/// steady-state detection (fleets run the full horizon).  Latency is
/// end-to-end from the *global* arrival time, so dispatcher queueing,
/// cold starts, and migration delays all count against the SLO.
struct FleetSink {
    stats: ServingStats,
    roller: WindowRoller,
    breakdown: BreakdownStats,
}

impl FleetSink {
    fn new(spec: &TrafficSpec, external_power: bool) -> FleetSink {
        FleetSink {
            stats: ServingStats::new(spec.slo_ns, spec.warmup_ns),
            roller: WindowRoller::new(spec.window_ns, spec.keep_windows, external_power),
            breakdown: BreakdownStats::new(),
        }
    }

    fn into_parts(
        self,
        sim: &mut SimReport,
    ) -> (ServingStats, BreakdownStats, Vec<WindowSummary>) {
        let windows = self.roller.finish(sim);
        (self.stats, self.breakdown, windows)
    }
}

impl StreamSink for FleetSink {
    fn on_outcome(&mut self, outcome: &ModelOutcome, _now: TimeNs) -> bool {
        let latency = outcome.finished_ns.saturating_sub(outcome.arrival_ns);
        if self.stats.record(outcome.kind, latency, outcome.finished_ns) {
            self.roller.record(latency);
            if let Some(bd) = &outcome.breakdown {
                self.breakdown.record(bd);
            }
        }
        true
    }

    fn on_advance(&mut self, now: TimeNs, power: &mut PowerPort<'_>) -> bool {
        while self.roller.due(now) {
            self.roller.roll(power);
        }
        true
    }

    fn on_power_window(&mut self, window: &crate::power::PowerWindow) {
        self.roller.on_power_window(window);
    }

    fn on_dropped(&mut self, _id: usize, _kind: ModelKind, _tenant: usize, _now: TimeNs) {
        self.stats.dropped += 1;
    }

    fn retain_state(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------- replica

/// One board plus its open run session and dispatcher-side state.
struct Replica {
    id: usize,
    sim: Simulation,
    session: crate::sim::RunSession,
    source: ReplicaSource,
    sink: FleetSink,
    status: RunStatus,
    /// Virtual time the board finishes its cold start (0 = born warm).
    ready_at: TimeNs,
    /// Scaled down: drains in-flight work, accepts nothing new.
    retiring: bool,
    /// Crashed by a board fault (scheduled or worker panic): stopped
    /// for good, excluded from autoscaler capacity counts.
    crashed: bool,
    routed: u64,
    migrated_out: u64,
    util_timeline: Vec<(TimeNs, f64)>,
    temp_timeline: Vec<(TimeNs, f64)>,
}

impl Replica {
    fn snapshot(&self, barrier: TimeNs) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id: self.id,
            accepting: !self.retiring
                && barrier >= self.ready_at
                && !matches!(self.status, RunStatus::Stopped),
            outstanding: self.session.outstanding() + self.source.len(),
            queue_depth: self.session.queue_depth(),
            busy_frac: self.session.busy_frac(),
            hottest_c: self.session.hottest_c(),
            now: self.session.now(),
        }
    }
}

/// Independent per-replica run seed: FNV-1a over the replica index,
/// keyed by the fleet seed, whitened through the PRNG — the same
/// derivation the scenario sweep uses per scenario name, so replica 0
/// of seed S never collides with a single-board run of seed S+1.
fn replica_seed(seed: u64, id: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed;
    for b in (id as u64).to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Rng::new(h).next_u64()
}

// ------------------------------------------------------------------ fleet

/// N replica boards, one dispatcher, one global arrival stream.  See the
/// module docs for the epoch-barrier clock model.
pub struct Fleet {
    spec: FleetSpec,
    make_sim: Box<dyn FnMut() -> anyhow::Result<Simulation>>,
    routing: Box<dyn RoutingPolicy>,
    autoscaler: Option<Box<dyn Autoscaler>>,
    trace_cfg: Option<TraceConfig>,
    tracers: Vec<TraceHandle>,
}

impl Fleet {
    /// `make_sim` builds one replica board; it is called once per
    /// initial replica and again on every scale-up (boards must be
    /// identical for routing to be meaningful).
    pub fn new(
        spec: FleetSpec,
        make_sim: impl FnMut() -> anyhow::Result<Simulation> + 'static,
        routing: Box<dyn RoutingPolicy>,
    ) -> Fleet {
        Fleet {
            spec,
            make_sim: Box::new(make_sim),
            routing,
            autoscaler: None,
            trace_cfg: None,
            tracers: Vec::new(),
        }
    }

    pub fn autoscaler(mut self, autoscaler: Option<Box<dyn Autoscaler>>) -> Fleet {
        self.autoscaler = autoscaler;
        self
    }

    /// Install a flight recorder on every replica board (including
    /// scale-ups).  Replica `r` records with pid base `r * PID_STRIDE`,
    /// so [`tracers`](Self::tracers) merge into one Perfetto document
    /// with disjoint track ids via [`crate::trace::merge_export`].
    pub fn trace(mut self, cfg: Option<TraceConfig>) -> Fleet {
        self.trace_cfg = cfg;
        self
    }

    /// Per-replica recorders of the last [`run`](Self::run), in replica
    /// order.  Empty unless [`trace`](Self::trace) was set.
    pub fn tracers(&self) -> &[TraceHandle] {
        &self.tracers
    }

    /// Run the fleet to completion: the arrival horizon passes and every
    /// board drains.  Deterministic in `seed` for any `threads`.
    pub fn run(&mut self, seed: u64) -> anyhow::Result<FleetReport> {
        self.spec.validate()?;
        self.tracers.clear();
        let prof_start = std::time::Instant::now();
        let Fleet { spec, make_sim, routing, autoscaler, trace_cfg, tracers } = self;
        let max_replicas = spec.max_replicas.max(spec.replicas);
        let epoch = spec.epoch_ns;

        let generator = spec.traffic.arrivals.build(seed)?;
        let mut global = StreamingSource::new(generator, spec.traffic.horizon_ns);

        let mut spawn = |id: usize, ready_at: TimeNs| -> anyhow::Result<Replica> {
            let mut sim = make_sim()?;
            // Board-level fault kinds (link/router/chiplet/sensor) arm
            // identically on every replica; `board:` events are skipped
            // by the sim and executed here by the dispatcher.
            if spec.faults.is_some() {
                sim.set_fault_plan(spec.faults.clone());
            }
            if let Some(cfg) = trace_cfg.as_ref() {
                let rec = TraceRecorder::new(cfg.clone()).with_pid_base(id as u32 * PID_STRIDE);
                tracers.push(sim.set_tracer(handle(rec)));
            }
            let external_power = sim.thermal_spec().is_in_loop();
            let sink = FleetSink::new(&spec.traffic, external_power);
            let session = sim.begin_run(replica_seed(seed, id), sink.retain_state())?;
            Ok(Replica {
                id,
                sim,
                session,
                source: ReplicaSource::default(),
                sink,
                status: RunStatus::Idle,
                ready_at,
                retiring: false,
                crashed: false,
                routed: 0,
                migrated_out: 0,
                util_timeline: Vec::new(),
                temp_timeline: Vec::new(),
            })
        };

        let mut replicas: Vec<Replica> = Vec::new();
        for id in 0..spec.replicas {
            replicas.push(spawn(id, 0)?);
        }

        let mut scale_events: Vec<ScaleEvent> = Vec::new();
        let mut migrations: u64 = 0;
        let mut epochs: u64 = 0;
        let mut barrier: TimeNs = 0;
        let mut until: TimeNs = epoch;

        // Scheduled board crashes (sorted), the retry policy they run
        // under, and the dispatcher-side fault runtime.  The runtime is
        // created lazily on the first actual crash, so an armed plan
        // with no board events (and no worker panic) leaves the run —
        // and its fingerprint — untouched.
        let retry_policy = spec.faults.as_ref().map(|p| p.retry).unwrap_or_default();
        let mut board_crashes: VecDeque<(TimeNs, usize)> = match &spec.faults {
            Some(plan) if !plan.is_empty() => plan.arm_boards(spec.replicas)?.into(),
            _ => VecDeque::new(),
        };
        let mut fault_rt: Option<FleetFaultRt> = None;
        let mut pending_crashes: Vec<usize> = Vec::new();
        if let Some(&(at, _)) = board_crashes.front() {
            if at > 0 {
                // Land a barrier exactly on the crash instant.
                until = until.min(at);
            }
        }

        loop {
            // ---- barrier: all control decisions on frozen state ----
            // Self-profiling splits each epoch into the single-threaded
            // control section (dispatch) and the parallel advance, the
            // two numbers Amdahl's law cares about.
            let prof_dispatch = crate::prof::scope(crate::prof::Subsystem::FleetDispatch);

            // ---- board crashes due at this barrier ----
            // Scheduled crashes join panic-crashed boards from the last
            // advance; both take the same path: stop the board, queue
            // its in-flight requests for retry, and (below, once the
            // snapshot exists) migrate its backlog to the survivors.
            while let Some(&(at, id)) = board_crashes.front() {
                if at > barrier {
                    break;
                }
                board_crashes.pop_front();
                pending_crashes.push(id);
            }
            let mut crashed_now: Vec<usize> = Vec::new();
            for id in std::mem::take(&mut pending_crashes) {
                if replicas[id].crashed {
                    continue;
                }
                replicas[id].crashed = true;
                replicas[id].status = RunStatus::Stopped;
                let rt = fault_rt.get_or_insert_with(FleetFaultRt::default);
                rt.report.injected += 1;
                rt.report.timeline.push(FaultTimelineEntry {
                    at_ns: barrier,
                    kind: "board",
                    target: id,
                    up: false,
                });
                rt.downtime.down(FaultKind::Board, id, barrier);
                // Best-effort on a panicked board: its session may be
                // mid-mutation, so a second panic means no requests are
                // recoverable from it (they count dropped, not lost).
                let aborted = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    replicas[id].session.take_unfinished_requests()
                }))
                .unwrap_or_default();
                rt.report.aborts += aborted.len() as u64;
                for req in aborted {
                    rt.schedule_retry(req, barrier, &retry_policy);
                }
                rt.sort_queue();
                crashed_now.push(id);
            }

            let mut snaps: Vec<ReplicaSnapshot> =
                replicas.iter().map(|r| r.snapshot(barrier)).collect();

            // Queued (not yet in-flight) work leaves a crashed board via
            // the same migration path emergencies use; if nobody accepts
            // right now, it joins the retry queue instead of stranding
            // on the dead board.
            for id in crashed_now.drain(..) {
                migrations += migrate_out(&mut replicas, id, routing.as_mut(), &mut snaps);
                let leftover = replicas[id].source.drain();
                if !leftover.is_empty() {
                    let rt = fault_rt.as_mut().expect("crash created fault runtime");
                    for req in leftover {
                        rt.retryq.push((barrier, 0, req));
                    }
                    rt.sort_queue();
                }
            }

            // Thermal emergency: stop routing to tripped boards and move
            // their queued (not yet in-flight) work to the survivors.
            if let Some(limit) = spec.emergency_c {
                let hot: Vec<usize> = snaps
                    .iter()
                    .filter(|s| s.accepting && s.hottest_c.map_or(false, |t| t >= limit))
                    .map(|s| s.id)
                    .collect();
                for id in &hot {
                    snaps[*id].accepting = false;
                }
                for id in hot {
                    migrations += migrate_out(&mut replicas, id, routing.as_mut(), &mut snaps);
                }
            }

            // Autoscale against the same frozen state.
            if let Some(scaler) = autoscaler.as_mut() {
                let current = replicas.iter().filter(|r| !r.retiring && !r.crashed).count();
                let desired = scaler
                    .desired(barrier, &snaps, current, max_replicas)
                    .clamp(1, max_replicas);
                if desired != current {
                    scale_events.push(ScaleEvent { at_ns: barrier, from: current, to: desired });
                }
                for _ in current..desired {
                    let id = replicas.len();
                    replicas.push(spawn(id, barrier + spec.cold_start_ns)?);
                    snaps.push(replicas[id].snapshot(barrier));
                }
                // Retire highest-index boards first; their queued work
                // migrates to the survivors, in-flight work drains.
                for _ in desired..current {
                    if let Some(id) = replicas.iter().rposition(|r| !r.retiring && !r.crashed) {
                        replicas[id].retiring = true;
                        snaps[id].accepting = false;
                        migrations +=
                            migrate_out(&mut replicas, id, routing.as_mut(), &mut snaps);
                    }
                }
            }

            // Route every arrival inside the upcoming epoch.  Arrivals
            // stay in the global stream while no board is accepting
            // (all cold / emergency); they are routed — with their
            // original arrival time — as soon as one is.
            let mut accepting: Vec<ReplicaSnapshot> =
                snaps.iter().filter(|s| s.accepting).copied().collect();
            if accepting.is_empty() {
                let all_stopped =
                    replicas.iter().all(|r| matches!(r.status, RunStatus::Stopped));
                if all_stopped && replicas.iter().all(|r| r.crashed) {
                    // Every board crashed: nothing will ever accept
                    // again.  Retry-queue survivors count dropped (they
                    // were offered); un-pulled arrivals were never
                    // offered, so conservation still holds.
                    if let Some(rt) = fault_rt.as_mut() {
                        rt.drop_all();
                    }
                    break;
                }
                anyhow::ensure!(
                    global.peek_arrival_ns().is_none() || !all_stopped,
                    "all replicas stopped (max_sim_time?) with arrivals pending"
                );
            } else {
                // Retry-queue requests first (they are the oldest), then
                // the epoch's fresh arrivals.
                if let Some(rt) = fault_rt.as_mut() {
                    while rt.retryq.first().is_some_and(|e| e.0 <= until) {
                        let (_, attempt, req) = rt.retryq.remove(0);
                        if attempt > 0 {
                            rt.report.retries += 1;
                        }
                        let j = routing.route(&req, &accepting);
                        let id = accepting[j].id;
                        accepting[j].outstanding += 1;
                        snaps[id].outstanding += 1;
                        replicas[id].routed += 1;
                        replicas[id].source.push(req);
                    }
                }
                while let Some(t) = global.peek_arrival_ns() {
                    if t > until {
                        break;
                    }
                    let req = global.next_request().expect("peeked request");
                    let j = routing.route(&req, &accepting);
                    let id = accepting[j].id;
                    accepting[j].outstanding += 1;
                    replicas[id].routed += 1;
                    replicas[id].source.push(req);
                }
            }

            // ---- advance every board to the epoch end, in parallel ----
            drop(prof_dispatch);
            let prof_advance = crate::prof::scope(crate::prof::Subsystem::FleetAdvance);
            let cells: Vec<Mutex<&mut Replica>> = replicas.iter_mut().map(Mutex::new).collect();
            let pool = crate::util::pool::WorkerPool::new(spec.threads);
            let results = pool.map_catching(cells.len(), |i| {
                let mut guard = cells[i].lock().expect("replica cell");
                let r: &mut Replica = &mut guard;
                if matches!(r.status, RunStatus::Stopped) {
                    return Ok(RunStatus::Stopped);
                }
                let Replica { sim, session, source, sink, .. } = r;
                // Seed the worker thread's log clock with this board's
                // virtual time so worker-side lines carry sim
                // timestamps even before the first event advances it.
                crate::util::logging::set_sim_now(session.now());
                sim.advance_run(session, source, sink, until).map_err(|e| format!("{e:#}"))
            });
            drop(cells);
            drop(prof_advance);
            let mut statuses: Vec<RunStatus> = replicas.iter().map(|r| r.status).collect();
            apply_advance_results(results, &mut statuses, &mut pending_crashes)?;
            for (r, s) in replicas.iter_mut().zip(statuses) {
                r.status = s;
            }
            epochs += 1;
            for r in replicas.iter_mut() {
                r.util_timeline.push((until, r.session.busy_frac()));
                if let Some(t) = r.session.hottest_c() {
                    r.temp_timeline.push((until, t));
                }
            }

            // ---- termination / fast-forward across dead time ----
            let exhausted = global.peek_arrival_ns().is_none();
            let drained = replicas.iter().all(|r| match r.status {
                RunStatus::Stopped => true,
                RunStatus::Idle => r.source.is_empty(),
                RunStatus::Paused { .. } => false,
            });
            let retries_pending = fault_rt.as_ref().is_some_and(|rt| !rt.retryq.is_empty());
            if exhausted && drained && !retries_pending && pending_crashes.is_empty() {
                break;
            }
            let mut wake = global.peek_arrival_ns().unwrap_or(TimeNs::MAX);
            for r in &replicas {
                if let RunStatus::Paused { next_event_ns } = r.status {
                    wake = wake.min(next_event_ns);
                }
                if r.ready_at > until {
                    wake = wake.min(r.ready_at);
                }
            }
            if let Some(rt) = fault_rt.as_ref() {
                if let Some(e) = rt.retryq.first() {
                    wake = wake.min(e.0);
                }
            }
            barrier = until;
            until = if wake != TimeNs::MAX && wake > until {
                // Next epoch boundary at or after the wake time.
                wake.saturating_add(epoch - 1) / epoch * epoch
            } else {
                until + epoch
            };
            if let Some(&(at, _)) = board_crashes.front() {
                if at > barrier {
                    // Land a barrier exactly on the next crash instant.
                    until = until.min(at);
                }
            }
        }

        // ---- aggregate ----
        let offered = global.emitted();
        let span_ns = replicas.iter().map(|r| r.session.now()).max().unwrap_or(0);
        // Dispatcher-level fault accounting first: recovered/availability
        // close out against the whole-run span, and dispatcher-dropped
        // requests join the global drop count (they never reached a
        // board's sink).  Per-replica sim-level fault reports merge in
        // below as each board is folded up.
        let mut fleet_dropped = 0;
        let mut fault: Option<FaultReport> = fault_rt.map(|mut rt| {
            rt.report.recovered =
                (rt.attempts.len() as u64).saturating_sub(rt.dropped_in_flight);
            rt.report.finish(&rt.downtime, span_ns);
            fleet_dropped = rt.report.fault_dropped;
            rt.report
        });
        let mut global_stats =
            ServingStats::new(spec.traffic.slo_ns, spec.traffic.warmup_ns);
        global_stats.dropped += fleet_dropped;
        let mut global_breakdown = BreakdownStats::new();
        let mut reports = Vec::with_capacity(replicas.len());
        for r in replicas {
            let Replica {
                id,
                mut sim,
                session,
                mut sink,
                source,
                status: _,
                ready_at,
                retiring,
                crashed,
                routed,
                migrated_out,
                util_timeline,
                temp_timeline,
            } = r;
            debug_assert!(source.is_empty(), "replica {id} retains unserved arrivals");
            let mut sim_report = sim.finish_run(session, &mut sink)?;
            let (stats, breakdown, windows) = sink.into_parts(&mut sim_report);
            global_stats.merge(&stats);
            global_breakdown.merge(&breakdown);
            if let Some(rf) = &sim_report.fault {
                match &mut fault {
                    Some(total) => total.merge(rf),
                    None => fault = Some(rf.clone()),
                }
            }
            reports.push(ReplicaReport {
                id,
                routed,
                migrated_out,
                ready_at,
                retired: retiring,
                crashed,
                stats,
                breakdown,
                windows,
                sim: sim_report,
                util_timeline,
                temp_timeline,
            });
        }
        Ok(FleetReport {
            seed,
            offered,
            epochs,
            migrations,
            scale_events,
            global: global_stats,
            breakdown: global_breakdown,
            fault,
            replicas: reports,
            // Host-timing data only; never part of the fingerprint.
            profile: crate::prof::snapshot(prof_start.elapsed().as_nanos() as u64),
        })
    }
}

// ------------------------------------------------------------------ faults

/// Dispatcher-side fault state: the fleet [`FaultReport`] under
/// construction, per-board downtime, and the retry queue of requests
/// aborted by a board crash.  Created lazily on the first crash so a
/// fault-free run carries no fault state at all.
#[derive(Default)]
struct FleetFaultRt {
    report: FaultReport,
    downtime: DowntimeTracker,
    /// Times each request id has been aborted so far (drives backoff
    /// and the attempt cap).
    attempts: BTreeMap<usize, u32>,
    /// Aborted requests counted into `fault_dropped` (vs. queued-work
    /// re-dispatches, which carry no attempt and no deadline).
    dropped_in_flight: u64,
    /// `(retry_at, attempt, request)`, sorted by `(retry_at, id)`.
    retryq: Vec<(TimeNs, u32, ModelRequest)>,
}

impl FleetFaultRt {
    /// Queue one aborted in-flight request for retry under `policy`, or
    /// count it dropped when its attempts or deadline are exhausted.
    fn schedule_retry(&mut self, req: ModelRequest, now: TimeNs, policy: &RetryPolicy) {
        let a = self.attempts.entry(req.id).or_insert(0);
        *a += 1;
        let attempt = *a;
        let retry_at = now.saturating_add(policy.backoff_for(attempt));
        if attempt > policy.max_attempts
            || retry_at > req.arrival_ns.saturating_add(policy.deadline_ns)
        {
            self.report.fault_dropped += 1;
            self.dropped_in_flight += 1;
        } else {
            self.retryq.push((retry_at, attempt, req));
        }
    }

    fn sort_queue(&mut self) {
        self.retryq.sort_by_key(|(at, _, r)| (*at, r.id));
    }

    /// Nothing will ever accept again: everything still queued counts
    /// dropped-by-fault.
    fn drop_all(&mut self) {
        for (_, attempt, _) in self.retryq.drain(..) {
            self.report.fault_dropped += 1;
            if attempt > 0 {
                self.dropped_in_flight += 1;
            }
        }
    }
}

/// Fold the parallel-advance results back onto the boards.  A clean
/// error fails the run; a worker *panic* fails only that replica — it
/// is recorded as a board crash and fed through the same migrate/retry
/// path a scheduled `board:` fault takes at the next barrier.
fn apply_advance_results(
    results: Vec<Result<Result<RunStatus, String>, String>>,
    statuses: &mut [RunStatus],
    pending_crashes: &mut Vec<usize>,
) -> anyhow::Result<()> {
    for (i, slot) in results.into_iter().enumerate() {
        match slot {
            Ok(Ok(status)) => statuses[i] = status,
            Ok(Err(e)) => anyhow::bail!("replica {i} failed: {e}"),
            Err(panic) => {
                crate::warn_once!(
                    "replica {i} panicked during advance (treated as board crash): {panic}"
                );
                statuses[i] = RunStatus::Stopped;
                pending_crashes.push(i);
            }
        }
    }
    Ok(())
}

/// Move a replica's queued work — its dispatcher buffer plus the board's
/// admission backlog (in-flight instances stay put) — onto the accepting
/// replicas, preserving original arrival times.  Returns the number of
/// requests moved; a no-op when nowhere accepts.
fn migrate_out(
    replicas: &mut [Replica],
    from: usize,
    routing: &mut dyn RoutingPolicy,
    snaps: &mut [ReplicaSnapshot],
) -> u64 {
    let mut moved = replicas[from].source.drain();
    moved.extend(replicas[from].session.drain_backlog());
    if moved.is_empty() {
        return 0;
    }
    moved.sort_by_key(|r| (r.arrival_ns, r.id));
    let accepting: Vec<usize> =
        snaps.iter().filter(|s| s.accepting && s.id != from).map(|s| s.id).collect();
    if accepting.is_empty() {
        for req in moved {
            replicas[from].source.push(req);
        }
        return 0;
    }
    let mut view: Vec<ReplicaSnapshot> =
        accepting.iter().map(|&id| snaps[id]).collect();
    let n = moved.len() as u64;
    for req in moved {
        let j = routing.route(&req, &view);
        let id = view[j].id;
        view[j].outstanding += 1;
        snaps[id].outstanding += 1;
        replicas[id].source.push(req);
    }
    replicas[from].migrated_out += n;
    n
}

// ----------------------------------------------------------------- report

/// Everything one board did over the fleet run.
#[derive(Debug)]
pub struct ReplicaReport {
    pub id: usize,
    /// Requests the dispatcher routed here (including later migrations
    /// away; excludes migrations in).
    pub routed: u64,
    /// Requests migrated off this board (emergency or retirement).
    pub migrated_out: u64,
    /// When the board finished cold start (0 = initial board).
    pub ready_at: TimeNs,
    /// Scaled down before the run ended.
    pub retired: bool,
    /// Crashed by a board fault or worker panic before the run ended.
    pub crashed: bool,
    /// Post-warm-up serving stats for requests served *by this board*.
    pub stats: ServingStats,
    /// Per-component latency breakdown for requests served by this board
    /// (empty unless the fleet was traced with breakdowns enabled).
    pub breakdown: BreakdownStats,
    /// Trailing per-window summaries.
    pub windows: Vec<WindowSummary>,
    /// Tail board-level simulation report (power, energy, NoI work).
    pub sim: SimReport,
    /// `(epoch_end_ns, busy_frac)` at every barrier.
    pub util_timeline: Vec<(TimeNs, f64)>,
    /// `(epoch_end_ns, hottest_c)` at barriers with thermal state.
    pub temp_timeline: Vec<(TimeNs, f64)>,
}

/// Aggregate of a fleet run: global SLO stats plus per-replica detail.
#[derive(Debug)]
pub struct FleetReport {
    pub seed: u64,
    /// Requests pulled from the global arrival stream.
    pub offered: u64,
    /// Barriers executed (epochs actually advanced, dead time skipped).
    pub epochs: u64,
    /// Requests re-routed away from emergency/retiring boards.
    pub migrations: u64,
    pub scale_events: Vec<ScaleEvent>,
    /// Fleet-wide post-warm-up serving stats (all replicas merged).
    pub global: ServingStats,
    /// Fleet-wide latency breakdown (all replicas merged; empty unless
    /// traced with breakdowns on — excluded from
    /// [`fingerprint`](Self::fingerprint)).
    pub breakdown: BreakdownStats,
    /// Fault accounting: dispatcher-level board crashes merged with
    /// every replica's board-level fault report.  `None` when no fault
    /// ever fired (zero-perturbation rule).
    pub fault: Option<FaultReport>,
    pub replicas: Vec<ReplicaReport>,
    /// Fleet-level self-profile (dispatch vs parallel-advance split,
    /// worker utilization) when [`crate::prof`] collection is enabled.
    /// Host-timing data — excluded from [`fingerprint`](Self::fingerprint).
    pub profile: Option<crate::prof::ProfileReport>,
}

impl FleetReport {
    /// Fleet-wide goodput: SLO-met completions over the global span.
    pub fn goodput_rps(&self) -> f64 {
        self.global.goodput_rps()
    }

    /// Peak number of simultaneously live (non-retired) boards.
    pub fn peak_replicas(&self) -> usize {
        self.scale_events
            .iter()
            .map(|e| e.to)
            .chain(std::iter::once(self.replicas.iter().filter(|r| !r.retired).count()))
            .max()
            .unwrap_or(self.replicas.len())
    }

    /// Human-readable roll-up.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let st = &self.global;
        let h = &st.overall.hist;
        let mut s = format!(
            "fleet: {} boards ({} scale events, {} migrations), {} offered, \
             {} completed, {} dropped over {:.3} ms\n",
            self.replicas.len(),
            self.scale_events.len(),
            self.migrations,
            self.offered,
            st.completed(),
            st.dropped,
            st.span_ns() as f64 / 1e6,
        );
        let _ = writeln!(
            s,
            "global latency (µs): p50 {:.1}  p99 {:.1}  max {:.1};  slo {:.1} µs: \
             {} violations ({:.2} %), goodput {:.0} req/s",
            h.quantile(0.5) as f64 / 1e3,
            h.quantile(0.99) as f64 / 1e3,
            h.max() as f64 / 1e3,
            st.slo_ns as f64 / 1e3,
            st.violations(),
            st.violation_frac() * 100.0,
            st.goodput_rps(),
        );
        if let Some(f) = &self.fault {
            s.push_str(&f.summary());
        }
        for r in &self.replicas {
            let peak_c = r
                .temp_timeline
                .iter()
                .map(|(_, t)| *t)
                .fold(f64::NEG_INFINITY, f64::max);
            let mean_util = if r.util_timeline.is_empty() {
                0.0
            } else {
                r.util_timeline.iter().map(|(_, u)| *u).sum::<f64>()
                    / r.util_timeline.len() as f64
            };
            let _ = write!(
                s,
                "  board {:<2} {} routed, {} completed, p99 {:>8.1} µs, mean util {:>5.1}%",
                r.id,
                r.routed,
                r.stats.completed(),
                r.stats.overall.hist.quantile(0.99) as f64 / 1e3,
                mean_util * 100.0,
            );
            if peak_c.is_finite() {
                let _ = write!(s, ", peak {peak_c:.1} °C");
            }
            if r.migrated_out > 0 {
                let _ = write!(s, ", {} migrated out", r.migrated_out);
            }
            if r.ready_at > 0 {
                let _ = write!(s, ", cold-started @{:.2} ms", r.ready_at as f64 / 1e6);
            }
            if r.retired {
                s.push_str(", retired");
            }
            if r.crashed {
                s.push_str(", crashed");
            }
            s.push('\n');
        }
        for e in &self.scale_events {
            let _ = writeln!(
                s,
                "  scale @{:.2} ms: {} -> {} boards",
                e.at_ns as f64 / 1e6,
                e.from,
                e.to
            );
        }
        if !self.breakdown.is_empty() {
            s.push_str(&self.breakdown.table().render());
        }
        s
    }

    /// Stable digest for determinism checks: two fleet runs are
    /// byte-identical iff their fingerprints are equal.  Wall-clock
    /// fields are excluded; floats compare via bit patterns.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "seed={};offered={};epochs={};migr={};global[{}]",
            self.seed,
            self.offered,
            self.epochs,
            self.migrations,
            self.global.fingerprint(),
        );
        for e in &self.scale_events {
            let _ = write!(s, ";scale@{}:{}->{}", e.at_ns, e.from, e.to);
        }
        if let Some(f) = &self.fault {
            let _ = write!(s, ";fault[{}]", f.fingerprint());
        }
        for r in &self.replicas {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            let mut fold = |v: u64| {
                for b in v.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
            };
            for (t, u) in &r.util_timeline {
                fold(*t);
                fold(u.to_bits());
            }
            for (t, c) in &r.temp_timeline {
                fold(*t);
                fold(c.to_bits());
            }
            let _ = write!(
                s,
                ";r{}[routed={};out={};ready={};{};sim:{};tl:{:016x}]",
                r.id,
                r.routed,
                r.migrated_out,
                r.ready_at,
                r.stats.fingerprint(),
                r.sim.fingerprint(),
                h,
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_source_orders_by_arrival_with_stable_ties() {
        let mut src = ReplicaSource::default();
        let req = |id: usize, t: TimeNs| ModelRequest {
            id,
            kind: ModelKind::AlexNet,
            arrival_ns: t,
            inferences: 1,
            tenant: 0,
        };
        src.push(req(0, 50));
        src.push(req(1, 10)); // migrated-in, older
        src.push(req(2, 50)); // tie: lands after id 0
        src.push(req(3, 30));
        let order: Vec<usize> =
            std::iter::from_fn(|| src.next_request()).map(|r| r.id).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn worker_panic_fails_only_that_replica() {
        // A panic slot becomes a board crash for that replica alone;
        // the healthy boards keep their advance results.
        let mut statuses =
            vec![RunStatus::Idle, RunStatus::Idle, RunStatus::Paused { next_event_ns: 7 }];
        let mut pending = Vec::new();
        let results = vec![
            Ok(Ok(RunStatus::Idle)),
            Err("index out of bounds".to_string()),
            Ok(Ok(RunStatus::Paused { next_event_ns: 9 })),
        ];
        apply_advance_results(results, &mut statuses, &mut pending).unwrap();
        assert!(matches!(statuses[0], RunStatus::Idle));
        assert!(matches!(statuses[1], RunStatus::Stopped));
        assert!(matches!(statuses[2], RunStatus::Paused { next_event_ns: 9 }));
        assert_eq!(pending, vec![1]);
        // A clean error (bad config, not a panic) still fails the run.
        let results = vec![Ok(Err("bad hardware".to_string()))];
        assert!(apply_advance_results(results, &mut statuses, &mut pending).is_err());
    }

    #[test]
    fn retry_scheduling_respects_attempts_and_deadline() {
        let policy = RetryPolicy {
            max_attempts: 2,
            backoff_ns: 100,
            backoff_cap_ns: 1_000,
            deadline_ns: 10_000,
        };
        let req = |id: usize| ModelRequest {
            id,
            kind: ModelKind::AlexNet,
            arrival_ns: 0,
            inferences: 1,
            tenant: 0,
        };
        let mut rt = FleetFaultRt::default();
        rt.schedule_retry(req(1), 500, &policy);
        assert_eq!(rt.retryq.len(), 1);
        assert_eq!(rt.retryq[0].0, 600, "first retry after one backoff step");
        // Second abort doubles the backoff; third exhausts max_attempts.
        rt.retryq.clear();
        rt.schedule_retry(req(1), 1_000, &policy);
        assert_eq!(rt.retryq[0].0, 1_200);
        rt.retryq.clear();
        rt.schedule_retry(req(1), 2_000, &policy);
        assert!(rt.retryq.is_empty());
        assert_eq!(rt.report.fault_dropped, 1);
        // Past the per-request deadline: dropped even on attempt 1.
        rt.schedule_retry(req(2), 50_000, &policy);
        assert_eq!(rt.report.fault_dropped, 2);
        assert_eq!(rt.dropped_in_flight, 2);
    }

    #[test]
    fn replica_seeds_are_distinct_and_stable() {
        let a = replica_seed(7, 0);
        let b = replica_seed(7, 1);
        let c = replica_seed(8, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, replica_seed(7, 0));
    }
}
