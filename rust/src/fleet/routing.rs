//! Pluggable request-routing policies for the fleet dispatcher.
//!
//! A policy sees only the dispatcher's per-epoch [`ReplicaSnapshot`]s —
//! never the live boards — so routing is a pure function of barrier
//! state plus the policy's own memory (the round-robin cursor).  The
//! dispatcher bumps the chosen snapshot's `outstanding` after every
//! decision, so a burst of arrivals inside one epoch still spreads
//! instead of dog-piling the replica that looked emptiest at the
//! barrier.

use crate::fleet::ReplicaSnapshot;
use crate::workload::ModelRequest;

/// Picks a replica for each incoming request.
///
/// `snaps` holds only *accepting* replicas (alive, warm, not retiring);
/// the returned value is an index into that slice, and the slice is
/// never empty when `route` is called.  Policies must be deterministic:
/// identical snapshots and request must yield the identical choice.
pub trait RoutingPolicy: Send {
    fn name(&self) -> &'static str;
    fn route(&mut self, req: &ModelRequest, snaps: &[ReplicaSnapshot]) -> usize;
}

/// Cycle through accepting replicas in order, ignoring load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &ModelRequest, snaps: &[ReplicaSnapshot]) -> usize {
        let i = self.next % snaps.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Send each request to the replica with the fewest requests in flight
/// (board queue + epoch buffer), breaking ties by replica id.
#[derive(Debug, Default)]
pub struct LeastOutstanding;

impl RoutingPolicy for LeastOutstanding {
    fn name(&self) -> &'static str {
        "least-outstanding"
    }

    fn route(&mut self, _req: &ModelRequest, snaps: &[ReplicaSnapshot]) -> usize {
        snaps
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.outstanding, s.id))
            .map(|(i, _)| i)
            .expect("route called with accepting replicas")
    }
}

/// Pin each request *kind* to one replica (hash of the kind name modulo
/// the accepting count) — the stand-in for session/model affinity: a
/// replica serves a stable subset of models, so its weight cache and
/// mapper state stay hot.  Affinity degrades when the accepting set
/// changes size (scale events remap kinds), matching real consistent-ish
/// hashing behaviour under churn.
#[derive(Debug, Default)]
pub struct SessionAffinity;

impl RoutingPolicy for SessionAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn route(&mut self, req: &ModelRequest, snaps: &[ReplicaSnapshot]) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in req.kind.name().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % snaps.len() as u64) as usize
    }
}

/// Prefer the coolest board: minimize the hottest-chiplet temperature
/// reported by each replica's thermal sensors at the barrier.  Replicas
/// without thermal state (thermal coupling off, or no control window
/// closed yet) sort after instrumented ones; ties fall back to least
/// outstanding, then id — so on an athermal fleet this degrades to
/// [`LeastOutstanding`].
#[derive(Debug, Default)]
pub struct ThermalAware;

impl RoutingPolicy for ThermalAware {
    fn name(&self) -> &'static str {
        "thermal"
    }

    fn route(&mut self, _req: &ModelRequest, snaps: &[ReplicaSnapshot]) -> usize {
        snaps
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let ta = a.hottest_c.unwrap_or(f64::INFINITY);
                let tb = b.hottest_c.unwrap_or(f64::INFINITY);
                ta.total_cmp(&tb)
                    .then_with(|| a.outstanding.cmp(&b.outstanding))
                    .then_with(|| a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
            .expect("route called with accepting replicas")
    }
}

/// Resolve a policy by CLI/preset name.
pub fn parse_routing(name: &str) -> anyhow::Result<Box<dyn RoutingPolicy>> {
    Ok(match name {
        "round-robin" | "rr" => Box::new(RoundRobin::default()),
        "least-outstanding" | "lo" => Box::new(LeastOutstanding),
        "affinity" => Box::new(SessionAffinity),
        "thermal" => Box::new(ThermalAware),
        other => anyhow::bail!(
            "unknown routing policy '{other}' \
             (expected round-robin, least-outstanding, affinity, or thermal)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelKind;

    fn snap(id: usize, outstanding: usize, hottest_c: Option<f64>) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id,
            accepting: true,
            outstanding,
            queue_depth: 0,
            busy_frac: 0.0,
            hottest_c,
            now: 0,
        }
    }

    fn req(kind: ModelKind) -> ModelRequest {
        ModelRequest { id: 0, kind, arrival_ns: 0, inferences: 1, tenant: 0 }
    }

    #[test]
    fn round_robin_cycles() {
        let snaps = [snap(0, 9, None), snap(1, 0, None), snap(2, 5, None)];
        let mut p = RoundRobin::default();
        let picks: Vec<usize> =
            (0..6).map(|_| p.route(&req(ModelKind::AlexNet), &snaps)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_outstanding_picks_emptiest_then_lowest_id() {
        let snaps = [snap(0, 3, None), snap(1, 1, None), snap(2, 1, None)];
        let mut p = LeastOutstanding;
        assert_eq!(p.route(&req(ModelKind::AlexNet), &snaps), 1);
    }

    #[test]
    fn affinity_is_stable_per_kind() {
        let snaps = [snap(0, 0, None), snap(1, 0, None), snap(2, 0, None)];
        let mut p = SessionAffinity;
        let a = p.route(&req(ModelKind::AlexNet), &snaps);
        let b = p.route(&req(ModelKind::AlexNet), &snaps);
        assert_eq!(a, b);
        for kind in [ModelKind::AlexNet, ModelKind::ResNet18, ModelKind::ResNet34] {
            let i = p.route(&req(kind), &snaps);
            assert!(i < snaps.len());
        }
    }

    #[test]
    fn thermal_prefers_coolest_and_falls_back_to_load() {
        let snaps = [snap(0, 0, Some(71.0)), snap(1, 4, Some(58.5)), snap(2, 0, None)];
        let mut p = ThermalAware;
        assert_eq!(p.route(&req(ModelKind::AlexNet), &snaps), 1);
        // All athermal: degrades to least-outstanding.
        let cold = [snap(0, 2, None), snap(1, 1, None)];
        assert_eq!(p.route(&req(ModelKind::AlexNet), &cold), 1);
    }

    #[test]
    fn parse_rejects_unknown_names() {
        assert!(parse_routing("least-outstanding").is_ok());
        assert!(parse_routing("banana").is_err());
    }
}
