//! Autoscaling policies: how many boards the fleet *wants* at a barrier.
//!
//! A policy only states the desired replica count; the fleet applies it
//! with the mechanical costs — a scaled-up board spends
//! `cold_start_ns` loading model weights before it accepts work, and a
//! scaled-down board drains its backlog onto the survivors before it
//! retires.  Policies are consulted once per epoch barrier from the
//! same snapshots routing sees.

use crate::fleet::ReplicaSnapshot;
use crate::TimeNs;

/// Desired fleet size as a function of barrier state.  The returned
/// count is clamped by the caller to `[1, max]`; policies should still
/// clamp themselves so hysteresis reasoning stays local.
pub trait Autoscaler: Send {
    fn name(&self) -> &'static str;
    /// `current` counts live boards, including ones still cold-starting
    /// (they are capacity already paid for).
    fn desired(
        &mut self,
        now_ns: TimeNs,
        snaps: &[ReplicaSnapshot],
        current: usize,
        max: usize,
    ) -> usize;
}

/// One scale decision the fleet acted on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleEvent {
    pub at_ns: TimeNs,
    pub from: usize,
    pub to: usize,
}

/// Hold mean compute utilization near a target: one board up when the
/// fleet runs hotter than `target + band`, one down when cooler than
/// `target - band`.  The dead band is the hysteresis that stops the
/// fleet oscillating around the target every epoch.
#[derive(Debug, Clone, Copy)]
pub struct TargetUtilization {
    pub target: f64,
    pub band: f64,
}

impl Default for TargetUtilization {
    fn default() -> TargetUtilization {
        TargetUtilization { target: 0.65, band: 0.15 }
    }
}

impl Autoscaler for TargetUtilization {
    fn name(&self) -> &'static str {
        "util"
    }

    fn desired(
        &mut self,
        _now_ns: TimeNs,
        snaps: &[ReplicaSnapshot],
        current: usize,
        max: usize,
    ) -> usize {
        if snaps.is_empty() {
            return current.clamp(1, max);
        }
        let mean = snaps.iter().map(|s| s.busy_frac).sum::<f64>() / snaps.len() as f64;
        if mean > self.target + self.band {
            (current + 1).min(max)
        } else if mean < self.target - self.band {
            current.saturating_sub(1).max(1)
        } else {
            current
        }
    }
}

/// Size the fleet from backlog: enough boards that no replica carries
/// more than `per_replica` outstanding requests.  Reacts faster than
/// utilization (queues grow before compute saturates) at the price of
/// more scale churn on bursty arrivals.
#[derive(Debug, Clone, Copy)]
pub struct QueueDepth {
    pub per_replica: usize,
}

impl Default for QueueDepth {
    fn default() -> QueueDepth {
        QueueDepth { per_replica: 16 }
    }
}

impl Autoscaler for QueueDepth {
    fn name(&self) -> &'static str {
        "queue"
    }

    fn desired(
        &mut self,
        _now_ns: TimeNs,
        snaps: &[ReplicaSnapshot],
        _current: usize,
        max: usize,
    ) -> usize {
        let total: usize = snaps.iter().map(|s| s.outstanding).sum();
        let per = self.per_replica.max(1);
        total.div_ceil(per).clamp(1, max)
    }
}

/// Resolve an autoscaler by CLI/preset name; `"none"`/`"off"` disables
/// autoscaling (fixed fleet).  `util` and `queue` accept an optional
/// `:value` parameter (target fraction / queue depth).
pub fn parse_autoscaler(name: &str) -> anyhow::Result<Option<Box<dyn Autoscaler>>> {
    let (kind, arg) = match name.split_once(':') {
        Some((k, v)) => (k, Some(v)),
        None => (name, None),
    };
    Ok(match kind {
        "none" | "off" => None,
        "util" => {
            let mut p = TargetUtilization::default();
            if let Some(v) = arg {
                p.target = v
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad util target '{v}'"))?;
                anyhow::ensure!(
                    p.target > 0.0 && p.target < 1.0,
                    "util target must be in (0, 1), got {}",
                    p.target
                );
            }
            Some(Box::new(p))
        }
        "queue" => {
            let mut p = QueueDepth::default();
            if let Some(v) = arg {
                p.per_replica = v
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad queue depth '{v}'"))?;
                anyhow::ensure!(p.per_replica > 0, "queue depth must be positive");
            }
            Some(Box::new(p))
        }
        other => anyhow::bail!(
            "unknown autoscaler '{other}' (expected none, util[:target], or queue[:depth])"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(id: usize, outstanding: usize, busy_frac: f64) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id,
            accepting: true,
            outstanding,
            queue_depth: 0,
            busy_frac,
            hottest_c: None,
            now: 0,
        }
    }

    #[test]
    fn util_scales_up_down_and_holds_in_band() {
        let mut p = TargetUtilization { target: 0.6, band: 0.1 };
        let hot = [snap(0, 0, 0.95), snap(1, 0, 0.85)];
        assert_eq!(p.desired(0, &hot, 2, 4), 3);
        let cool = [snap(0, 0, 0.1), snap(1, 0, 0.2)];
        assert_eq!(p.desired(0, &cool, 2, 4), 1);
        let inband = [snap(0, 0, 0.55), snap(1, 0, 0.65)];
        assert_eq!(p.desired(0, &inband, 2, 4), 2);
        // Never below one board, never above max.
        assert_eq!(p.desired(0, &cool, 1, 4), 1);
        assert_eq!(p.desired(0, &hot, 4, 4), 4);
    }

    #[test]
    fn queue_depth_sizes_from_backlog() {
        let mut p = QueueDepth { per_replica: 8 };
        let snaps = [snap(0, 20, 0.0), snap(1, 5, 0.0)];
        assert_eq!(p.desired(0, &snaps, 2, 8), 4); // ceil(25 / 8)
        let idle = [snap(0, 0, 0.0)];
        assert_eq!(p.desired(0, &idle, 1, 8), 1);
    }

    #[test]
    fn parse_handles_args_and_rejects_junk() {
        assert!(parse_autoscaler("none").unwrap().is_none());
        assert_eq!(parse_autoscaler("util:0.8").unwrap().unwrap().name(), "util");
        assert_eq!(parse_autoscaler("queue:4").unwrap().unwrap().name(), "queue");
        assert!(parse_autoscaler("util:1.5").is_err());
        assert!(parse_autoscaler("queue:0").is_err());
        assert!(parse_autoscaler("banana").is_err());
    }
}
