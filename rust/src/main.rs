//! `chipsim` — launcher for the CHIPSIM co-simulation framework.
//!
//! Subcommands:
//!   run          generic co-simulation run with configurable system/workload
//!   traffic      sustained open-loop serving run (p50/p99, goodput, SLO)
//!   mix          multi-tenant co-execution (per-tenant SLOs, interference matrix)
//!   dtm          closed-loop dynamic thermal management run / governor sweep
//!   fleet        fleet-scale serving: N replica boards behind one dispatcher
//!   trace        flight-recorder run of a named scenario -> Perfetto JSON
//!   profile      self-profiling run of a named scenario -> subsystem wall-clock shares
//!   faults       fault-injection reference: model table, plan grammar, plan validation
//!   scenarios    list the named presets in the scenario registry
//!   batch        run a batch of registry scenarios (threaded SweepRunner)
//!   sweep        DSE grid sweep (topology x link width x pipelining) -> CSV
//!   table4..8    regenerate the paper's tables (see DESIGN.md §6)
//!   fig6..11     regenerate the paper's figures
//!   all          run every experiment artifact in sequence
//!   artifacts    list the AOT artifacts the PJRT runtime can load
//!
//! Examples:
//!   chipsim run --rows 10 --cols 10 --models 50 --inferences 10 --pipelined
//!   chipsim run --scenario vit-pipeline
//!   chipsim traffic --scenario traffic-poisson-mesh --rate 2000 --seed 7
//!   chipsim traffic --rows 8 --cols 8 --arrivals burst --rate 3000 --pipelined
//!   chipsim traffic --sweep --lo 500 --hi 8000       # saturation knee
//!   chipsim traffic --rows 8 --cols 8 --noc flit --threads 8   # sharded parallel NoI
//!   chipsim mix --scenario mix-contended-interleaved --sweep interference
//!   chipsim mix --tenants resnet18@1500,resnet50@400@5000 --placement disjoint
//!   chipsim dtm --scenario dtm-thermal-ceiling --csv dtm.csv
//!   chipsim dtm --rows 6 --cols 6 --pipelined --sweep  # governor tradeoff
//!   chipsim fleet --scenario fleet-least-outstanding --seed 7
//!   chipsim fleet --replicas 4 --routing thermal --rate 9000 --rows 6 --cols 6
//!   chipsim fleet --scenario fleet-round-robin --sweep routing-compare
//!   chipsim fleet --scenario fleet-least-outstanding --sweep knee --lo 2000 --hi 20000
//!   chipsim trace --scenario fleet-least-outstanding   # results/trace_<name>.json
//!   chipsim traffic --scenario traffic-poisson-mesh --trace --trace-filter request,noi
//!   chipsim profile --scenario fleet-least-outstanding # results/profile_<name>.json
//!   chipsim traffic --scenario traffic-poisson-mesh --profile
//!   chipsim traffic --scenario fault-chiplet-kill --faults-out results/fault.json
//!   chipsim traffic --rows 6 --cols 6 --faults "link:14-15@4ms+1ms%4ms*3"
//!   chipsim fleet --scenario fault-fleet-board-crash --seed 7
//!   chipsim faults --plan "chiplet:7@3ms+6ms" --rows 6 --cols 6  # validate a plan
//!   chipsim batch --scenarios mesh-10x10-cnn,hetero-mesh,floret --threads 4
//!   chipsim fig9                 # power -> thermal heatmap via PJRT AOT
//!   chipsim table7               # hardware-validation comparison

use chipsim::config::{
    ComputeBackendKind, HardwareConfig, NocFidelity, SimParams, WorkloadConfig,
};
use chipsim::experiments;
use chipsim::instrument::RunOptions;
use chipsim::scenario::{self, Registry, SweepRunner};
use chipsim::sim::Simulation;
use chipsim::util::cli::{Args, HelpText};
use chipsim::util::logging;

fn help() -> HelpText {
    HelpText {
        name: "chipsim",
        about: "co-simulation framework for DNNs on chiplet-based systems",
        usage: "chipsim <run|traffic|mix|dtm|fleet|trace|profile|faults|scenarios|batch|sweep|table4|fig6|fig7|table5|table6|fig8|fig9|fig10|fig11|table7|table8|all|artifacts> [options]",
        entries: vec![
            ("--rows N / --cols N", "chiplet grid (default 10x10)"),
            ("--topo mesh|floret|hetero|vit|ccd", "system preset (default mesh)"),
            ("--scenario NAME", "run a named registry scenario (see `chipsim scenarios`)"),
            ("--scenarios a,b,c|all", "batch: which scenarios to run (default all)"),
            (
                "--threads N",
                "workers: fleet/batch pool size (default all cores); traffic/mix/run: \
                 shard the flit NoI over N regions (byte-identical to sequential)",
            ),
            ("--models N", "stream length (default 50)"),
            ("--inferences N", "back-to-back inferences per model (default 10)"),
            ("--pipelined", "enable layer pipelining"),
            ("--noc packet|flit", "network fidelity (default packet)"),
            ("--compute analytical|pjrt", "compute backend (default analytical)"),
            ("--seed S", "workload sampling seed"),
            ("--hw FILE.json", "load hardware config from JSON"),
            ("--quick", "shrink experiment workloads (CI mode)"),
            ("--power-csv FILE", "dump per-chiplet power trace"),
            ("--arrivals poisson|burst|diurnal|trace", "traffic: arrival process (default poisson)"),
            ("--rate R", "traffic: mean arrival rate, req/s (default 2000)"),
            ("--trace-file FILE.json", "traffic: arrival trace for --arrivals trace"),
            ("--horizon-ms/--warmup-ms/--window-ms", "traffic: run shape (default 50/5/5)"),
            ("--slo-ms S", "traffic: end-to-end latency SLO (default 1.0)"),
            ("--sweep --lo R0 --hi R1 [--iters N]", "traffic: bisect for the saturation knee"),
            ("--tenants k@r[@slo_us],...", "mix: e.g. resnet18@1500,resnet50@400@5000"),
            ("--placement disjoint|interleaved|greedy", "mix: placement (default disjoint)"),
            ("mix --sweep interference", "mix: run tenants solo too; print the matrix"),
            ("--governor noop|threshold|pid", "dtm: DVFS policy (default threshold)"),
            ("--ceiling C", "dtm: thermal ceiling, °C (default 48)"),
            ("--dtm-window-us W", "dtm: control period, µs (default 100)"),
            ("--csv FILE", "dtm: write the per-window temperature/frequency trace"),
            ("--keep-timeline N", "dtm: window samples kept for --csv (default: whole horizon)"),
            ("dtm --sweep", "dtm: run noop/threshold/pid at one seed, print the tradeoff"),
            ("--replicas N / --max-replicas N", "fleet: boards at t=0 / autoscale ceiling"),
            ("--routing round-robin|least-outstanding|affinity|thermal", "fleet: dispatch policy"),
            ("--autoscale none|util[:target]|queue[:depth]", "fleet: autoscaling policy"),
            ("--epoch-us E", "fleet: barrier cadence, µs (default 200)"),
            ("--cold-start-ms C", "fleet: scale-up weight-load time (default 5)"),
            ("--emergency-c T", "fleet: migrate queued work off boards above T °C"),
            ("fleet --sweep routing-compare", "fleet: run all four routing policies at one seed"),
            ("fleet --sweep knee --lo R0 --hi R1", "fleet: bisect for the fleet saturation knee"),
            ("--trace", "traffic/mix/fleet: record a flight-recorder trace of the run"),
            ("--trace-filter CATS", "trace categories: all or request,compute,noi,dtm,gauges"),
            ("--trace-out FILE.json", "trace output path (default results/trace_<name>.json)"),
            ("trace --scenario NAME", "run any preset fully traced; also prints the breakdown"),
            ("--profile", "traffic/mix/fleet/batch: self-profile the simulator itself"),
            ("--profile-out FILE.json", "profile output path (default results/profile_<name>.json)"),
            ("--faults PLAN", "traffic/mix/fleet: arm a fault plan (grammar: `chipsim faults`)"),
            ("--faults-out FILE.json", "write the run's FaultReport JSON (needs an armed plan)"),
            ("profile --scenario NAME", "run any preset self-profiled; writes JSON + .collapsed"),
        ],
    }
}

fn build_hw(args: &Args) -> anyhow::Result<HardwareConfig> {
    if let Some(path) = args.get("hw") {
        return HardwareConfig::load(path);
    }
    let rows = args.get_usize("rows", 10)?;
    let cols = args.get_usize("cols", 10)?;
    let petals = args.get_usize("petals", 10)?;
    let ccds = args.get_usize("ccds", 8)?;
    scenario::hardware_preset(args.get_or("topo", "mesh"), rows, cols, petals, ccds)
}

fn build_params(args: &Args) -> anyhow::Result<SimParams> {
    Ok(SimParams {
        pipelined: args.flag("pipelined"),
        inferences_per_model: args.get_u64("inferences", 10)? as u32,
        seed: args.get_u64("seed", 0xC0FFEE)?,
        warmup_ns: args.get_u64("warmup-ns", 0)?,
        cooldown_ns: args.get_u64("cooldown-ns", 0)?,
        noc_fidelity: match args.get_or("noc", "packet") {
            "packet" => NocFidelity::Packet,
            "flit" => NocFidelity::Flit,
            other => anyhow::bail!("unknown --noc '{other}'"),
        },
        compute_backend: match args.get_or("compute", "analytical") {
            "analytical" => ComputeBackendKind::Analytical,
            "pjrt" => ComputeBackendKind::Pjrt,
            other => anyhow::bail!("unknown --compute '{other}'"),
        },
        ..SimParams::default()
    })
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let report = if let Some(name) = args.get("scenario") {
        // A scenario bundles hardware + params + workload; flags that
        // would override those pieces are rejected, not silently eaten.
        let fixed_by_scenario = [
            "topo", "rows", "cols", "models", "inferences", "noc", "compute", "hw", "model",
            "petals", "ccds",
        ];
        for opt in fixed_by_scenario {
            anyhow::ensure!(
                args.get(opt).is_none(),
                "--{opt} conflicts with --scenario '{name}' (the scenario fixes it); \
                 drop --scenario or use the generic flags alone"
            );
        }
        anyhow::ensure!(
            !args.flag("pipelined"),
            "--pipelined conflicts with --scenario '{name}' (the scenario fixes it)"
        );
        let reg = Registry::builtin();
        let sc = reg.get(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
        })?;
        anyhow::ensure!(
            !sc.is_traffic(),
            "scenario '{name}' is a sustained-traffic scenario; its report is serving \
             stats, not per-model outcomes — run it with `chipsim traffic --scenario {name}`"
        );
        anyhow::ensure!(
            !sc.is_mix(),
            "scenario '{name}' is a multi-tenant mix; its report is per-tenant serving \
             stats — run it with `chipsim mix --scenario {name}`"
        );
        let seed = args.get_u64("seed", sc.default_seed)?;
        sc.run(seed)?
    } else {
        let hw = build_hw(args)?;
        let params = build_params(args)?;
        let n = args.get_usize("models", 50)?;
        let seed = params.seed;
        let inferences = params.inferences_per_model;
        let wl = match args.get("model") {
            Some(name) => {
                let kind = chipsim::workload::ModelKind::from_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
                WorkloadConfig::single(kind)
            }
            None => WorkloadConfig::cnn_stream(n, inferences, seed),
        };
        Simulation::builder()
            .hardware(hw)
            .params(params)
            .exec(RunOptions::from_args(args)?.exec())
            .build()?
            .run(wl)?
    };
    print!("{}", report.summary());
    if let Some(path) = args.get("power-csv") {
        let chiplets: Vec<usize> = (0..report.power.num_chiplets()).collect();
        std::fs::write(path, report.power.to_csv(&chiplets))?;
        println!("power trace written to {path}");
    }
    Ok(())
}

/// Sustained open-loop serving run: arrivals keep coming at the given
/// rate whether or not the system kept up, and the report is the serving
/// truth — p50/p99/p99.9, goodput, SLO violations, and a windowed power
/// trace.  `--sweep` instead bisects over the rate for the saturation
/// knee.
fn cmd_traffic(args: &Args) -> anyhow::Result<()> {
    use chipsim::serving::{ArrivalSpec, LoadSweep, TrafficSpec};
    let inst = RunOptions::from_args(args)?.instrument();
    let reg = Registry::builtin();
    type SimFactory = Box<dyn Fn() -> anyhow::Result<Simulation>>;
    let (spec, seed, make_sim): (TrafficSpec, u64, SimFactory) = if let Some(name) =
        args.get("scenario")
    {
        let sc = reg.get(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
        })?;
        let seed = args.get_u64("seed", sc.default_seed)?;
        let spec = sc.traffic_spec(seed).ok_or_else(|| {
            if sc.is_mix() {
                anyhow::anyhow!(
                    "scenario '{name}' is a multi-tenant mix; run it with \
                     `chipsim mix --scenario {name}`"
                )
            } else {
                anyhow::anyhow!(
                    "scenario '{name}' is a batch scenario; run it with \
                     `chipsim run --scenario {name}`"
                )
            }
        })?;
        let sc = sc.clone();
        (spec, seed, Box::new(move || sc.build()))
    } else {
        let hw = build_hw(args)?;
        let params = build_params(args)?;
        let seed = args.get_u64("seed", params.seed)?;
        let rate = args.get_f64("rate", 2_000.0)?;
        let arrivals = match args.get_or("arrivals", "poisson") {
            "poisson" => ArrivalSpec::poisson(rate),
            // Bursts at 2x the mean rate, silence between: same offered
            // load as poisson at --rate, much worse tail.
            "burst" => ArrivalSpec::on_off(2.0 * rate, 0.0, 5e6, 5e6),
            "diurnal" => ArrivalSpec::diurnal(
                rate,
                0.6,
                (args.get_f64("period-ms", 20.0)? * 1e6) as u64,
            ),
            "trace" => ArrivalSpec::trace_file(args.get("trace-file").ok_or_else(|| {
                anyhow::anyhow!("--arrivals trace requires --trace-file FILE.json")
            })?)?,
            other => anyhow::bail!("unknown --arrivals '{other}' (poisson|burst|diurnal|trace)"),
        }
        .inferences(args.get_u64("inferences", 1)? as u32);
        let spec = TrafficSpec::new(arrivals)
            .horizon_ms(args.get_f64("horizon-ms", 50.0)?)
            .warmup_ms(args.get_f64("warmup-ms", 5.0)?)
            .window_ms(args.get_f64("window-ms", 5.0)?)
            .slo_ms(args.get_f64("slo-ms", 1.0)?);
        (
            spec,
            seed,
            Box::new(move || {
                Simulation::builder().hardware(hw.clone()).params(params.clone()).build()
            }),
        )
    };
    // --rate on a scenario rescales its arrival shape (generic runs
    // already consumed --rate above).
    let spec = if args.get("scenario").is_some() && args.get("rate").is_some() {
        TrafficSpec {
            arrivals: spec.arrivals.with_rate(args.get_f64("rate", 0.0)?)?,
            ..spec
        }
    } else {
        spec
    };
    if args.flag("sweep") {
        anyhow::ensure!(
            inst.options().trace.is_none(),
            "--trace does not combine with --sweep (trace a single run)"
        );
        anyhow::ensure!(
            inst.options().faults_out.is_none(),
            "--faults-out does not combine with --sweep (write a single run's report)"
        );
        let lo = args.get_f64("lo", 500.0)?;
        let hi = args.get_f64("hi", 10_000.0)?;
        let sweep = LoadSweep::new(spec, lo, hi).iters(args.get_usize("iters", 5)?);
        // Every probe board gets the shared cluster: --threads and a
        // --faults plan that replaces a scenario's built-in one.
        let result = sweep.run(
            || {
                let mut sim = make_sim()?;
                inst.attach(&mut sim);
                Ok(sim)
            },
            seed,
        )?;
        println!("load sweep ({} probes):", result.probes.len());
        for p in &result.probes {
            println!(
                "  {:>8.0} req/s  p99 {:>9.1} µs  goodput {:>8.0} req/s  viol {:>6.2} %  {}",
                p.rate_rps,
                p.p99_ns as f64 / 1e3,
                p.goodput_rps,
                p.violation_frac * 100.0,
                if p.meets_slo { "PASS" } else { "fail" },
            );
        }
        println!(
            "saturation knee: ~{:.0} req/s (highest probed rate meeting the SLO)",
            result.knee_rps
        );
        // The sweep's probes share one collection; attribute against
        // the whole sweep's wall-clock.
        inst.finish_profile(None, "profile_sweep.json")?;
        return Ok(());
    }
    let mut sim = make_sim()?;
    inst.attach(&mut sim);
    let report = sim.run_traffic_with(&spec, seed)?;
    print!("{}", report.summary());
    inst.write_fault_report(report.sim.fault.as_ref())?;
    inst.finish_profile(
        report.sim.profile.as_ref(),
        &format!("profile_{}.json", args.get("scenario").unwrap_or("traffic")),
    )?;
    inst.export_trace(&format!("trace_{}.json", args.get("scenario").unwrap_or("traffic")))?;
    if let Some(path) = args.get("power-csv") {
        let chiplets: Vec<usize> = (0..report.sim.power.num_chiplets()).collect();
        std::fs::write(path, report.sim.power.to_csv(&chiplets))?;
        println!("tail power trace written to {path}");
    }
    Ok(())
}

/// Multi-tenant co-execution: N tenants (model + arrival process + SLO
/// each) share one chiplet system under a placement policy, so NoI
/// contention, chiplet queueing, and memory pressure between them are
/// simulated, not estimated.  `--sweep interference` additionally runs
/// every tenant solo on its same placement and prints the interference
/// matrix (solo vs co-located tail latency).
fn cmd_mix(args: &Args) -> anyhow::Result<()> {
    use chipsim::mapping::PlacementPolicy;
    use chipsim::serving::mix::{run_mix, TenantSpec, WorkloadMix};
    use chipsim::sim::ThermalSpec;
    let mut inst = RunOptions::from_args(args)?.instrument();
    let reg = Registry::builtin();
    // `--sweep interference` (also accepted: bare `--sweep`, `--sweep=interference`).
    let sweep = if args.flag("sweep") || args.get("sweep").is_some() {
        let kind = args
            .get("sweep")
            .map(|s| s.to_string())
            .or_else(|| args.positionals.get(1).cloned())
            .unwrap_or_else(|| "interference".to_string());
        anyhow::ensure!(
            kind == "interference",
            "unknown mix sweep '{kind}' (expected: interference)"
        );
        true
    } else {
        false
    };
    let (hw, params, thermal, mix, seed) = if let Some(name) = args.get("scenario") {
        let sc = reg.get(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
        })?;
        // The preset fixes its tenants and run shape; flags that would
        // override them are rejected, not silently eaten (--placement
        // and --sweep deliberately remain live overrides).
        for opt in [
            "tenants", "horizon-ms", "warmup-ms", "window-ms", "slo-ms", "topo", "rows",
            "cols", "noc", "compute", "hw",
        ] {
            anyhow::ensure!(
                args.get(opt).is_none(),
                "--{opt} conflicts with --scenario '{name}' (the scenario fixes it); \
                 drop --scenario or use the generic mix flags alone"
            );
        }
        let seed = args.get_u64("seed", sc.default_seed)?;
        let mix = sc.mix_spec(seed).ok_or_else(|| {
            anyhow::anyhow!(
                "scenario '{name}' is not a multi-tenant mix; `chipsim scenarios` tags \
                 mix presets with [mix]"
            )
        })?;
        (sc.hardware(), sc.params(), sc.thermal().clone(), mix, seed)
    } else {
        let hw = build_hw(args)?;
        let params = build_params(args)?;
        let seed = args.get_u64("seed", params.seed)?;
        let tenants_arg = args.get("tenants").ok_or_else(|| {
            anyhow::anyhow!(
                "mix needs --tenants kind@rate[@slo_us],... or --scenario mix-* \
                 (see `chipsim scenarios`)"
            )
        })?;
        let default_slo_ms = args.get_f64("slo-ms", 2.0)?;
        let mut tenants = Vec::new();
        for (idx, part) in tenants_arg.split(',').enumerate() {
            let part = part.trim();
            let fields: Vec<&str> = part.split('@').collect();
            anyhow::ensure!(
                fields.len() == 2 || fields.len() == 3,
                "tenant '{part}': expected kind@rate[@slo_us]"
            );
            let kind = chipsim::workload::ModelKind::from_name(fields[0])
                .ok_or_else(|| anyhow::anyhow!("tenant '{part}': unknown model '{}'", fields[0]))?;
            let rate: f64 = fields[1]
                .parse()
                .map_err(|e| anyhow::anyhow!("tenant '{part}': bad rate '{}': {e}", fields[1]))?;
            let mut tenant = TenantSpec::poisson(&format!("{}-{idx}", fields[0]), kind, rate);
            tenant = match fields.get(2) {
                Some(slo) => tenant.slo_us(slo.parse().map_err(|e| {
                    anyhow::anyhow!("tenant '{part}': bad slo_us '{slo}': {e}")
                })?),
                None => tenant.slo_ms(default_slo_ms),
            };
            tenants.push(tenant);
        }
        let mix = WorkloadMix::new(tenants)
            .horizon_ms(args.get_f64("horizon-ms", 30.0)?)
            .warmup_ms(args.get_f64("warmup-ms", 4.0)?)
            .window_ms(args.get_f64("window-ms", 5.0)?);
        (hw, params, ThermalSpec::Off, mix, seed)
    };
    let mix = match args.get("placement") {
        Some(p) => mix.placement(PlacementPolicy::from_name(p).ok_or_else(|| {
            anyhow::anyhow!("unknown --placement '{p}' (disjoint|interleaved|greedy)")
        })?),
        None => mix,
    };
    let interference = sweep || mix.interference;
    let mix = mix.interference(interference);
    // Boards are assembled from the scenario's parts here (not
    // `sc.build()`), so a preset-carried plan needs an explicit pickup;
    // --faults replaces it.  Solo interference baselines share the
    // plan: the matrix compares tenants under the *same* fault
    // schedule.
    if inst.options().faults.is_none() {
        inst.options_mut().faults = args
            .get("scenario")
            .and_then(|n| reg.get(n))
            .and_then(|sc| sc.fault_plan().cloned());
    }
    let report = run_mix(
        || {
            let mut sim = Simulation::builder()
                .hardware(hw.clone())
                .params(params.clone())
                .thermal(thermal.clone())
                .build()?;
            // The shared cluster: --threads, faults, and the recorder —
            // first board (the co-located pass) only; solo baselines
            // run untraced, they would otherwise reset the recorder.
            inst.attach(&mut sim);
            Ok(sim)
        },
        &mix,
        seed,
    )?;
    print!("{}", report.summary());
    inst.write_fault_report(report.sim.fault.as_ref())?;
    inst.export_trace(&format!("trace_{}.json", args.get("scenario").unwrap_or("mix")))?;
    // With `--sweep interference` the co-located pass and the solo
    // baselines share one collection; the attached profile (co-located
    // pass only) is still the representative one.
    inst.finish_profile(
        report.sim.profile.as_ref(),
        &format!("profile_{}.json", args.get("scenario").unwrap_or("mix")),
    )?;
    if let Some(path) = args.get("power-csv") {
        let chiplets: Vec<usize> = (0..report.sim.power.num_chiplets()).collect();
        std::fs::write(path, report.sim.power.to_csv(&chiplets))?;
        println!("tail power trace written to {path}");
    }
    Ok(())
}

/// Closed-loop DTM run: the thermal stepper runs inside the event loop,
/// per-chiplet sensors feed a DVFS governor, and the chosen operating
/// points act back on compute latency and dynamic power.  Prints the
/// serving stats plus the DtmReport roll-up; `--csv` dumps the
/// per-window temperature/frequency trace; `--sweep` compares the three
/// built-in governors on one seed (the throttle-vs-SLO tradeoff).
fn cmd_dtm(args: &Args) -> anyhow::Result<()> {
    use chipsim::dtm::GovernorSpec;
    use chipsim::serving::{TrafficReport, TrafficSpec};
    use chipsim::sim::ThermalSpec;
    let reg = Registry::builtin();
    let window_ns = (args.get_f64("dtm-window-us", 100.0)? * 1e3) as u64;
    let ceiling = args.get_f64("ceiling", 48.0)?;
    let governor_of = |name: &str| -> anyhow::Result<GovernorSpec> {
        Ok(match name {
            "noop" => GovernorSpec::noop(ceiling),
            "threshold" => GovernorSpec::threshold(ceiling),
            // Violations are accounted against the *requested* ceiling
            // (pid() would otherwise derive its own from the setpoint).
            "pid" => GovernorSpec::pid(ceiling - 1.5).ceiling(ceiling),
            other => anyhow::bail!("unknown --governor '{other}' (noop|threshold|pid)"),
        })
    };
    let (hw, params, spec, seed, scenario_thermal) = if let Some(name) = args.get("scenario") {
        let sc = reg.get(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
        })?;
        if args.get("governor").is_none() && !args.flag("sweep") && sc.thermal().is_in_loop() {
            // The preset's in-loop spec will run as-is: reject knobs that
            // would otherwise be silently ignored (--governor and --sweep
            // take the generic path, where they apply).
            for opt in ["ceiling", "dtm-window-us"] {
                anyhow::ensure!(
                    args.get(opt).is_none(),
                    "--{opt} is ignored by scenario '{name}' unless --governor or --sweep \
                     is also given (the preset fixes its own control spec)"
                );
            }
        }
        let seed = args.get_u64("seed", sc.default_seed)?;
        let mut spec = sc.traffic_spec(seed).ok_or_else(|| {
            anyhow::anyhow!(
                "scenario '{name}' is a batch scenario; dtm needs a traffic scenario \
                 (try dtm-thermal-ceiling or dtm-throttle-slo)"
            )
        })?;
        // --rate rescales the preset's arrival shape, like `chipsim
        // traffic --scenario ... --rate R`.
        if args.get("rate").is_some() {
            spec.arrivals = spec.arrivals.with_rate(args.get_f64("rate", 0.0)?)?;
        }
        (sc.hardware(), sc.params(), spec, seed, Some(sc.thermal().clone()))
    } else {
        let hw = build_hw(args)?;
        let params = build_params(args)?;
        let seed = args.get_u64("seed", params.seed)?;
        let spec = TrafficSpec::poisson(args.get_f64("rate", 3_000.0)?)
            .horizon_ms(args.get_f64("horizon-ms", 30.0)?)
            .warmup_ms(args.get_f64("warmup-ms", 5.0)?)
            .window_ms(args.get_f64("window-ms", 5.0)?)
            .slo_ms(args.get_f64("slo-ms", 2.0)?)
            .steady(None);
        (hw, params, spec, seed, None)
    };
    // Timeline ring size: default covers the whole horizon so --csv is
    // never silently truncated; --keep-timeline bounds it explicitly.
    let keep_timeline = args.get_usize(
        "keep-timeline",
        ((spec.horizon_ns / window_ns.max(1)) as usize + 2).max(1024),
    )?;
    let run_one = |win: u64, governor: GovernorSpec| -> anyhow::Result<TrafficReport> {
        Simulation::builder()
            .hardware(hw.clone())
            .params(params.clone())
            .thermal(ThermalSpec::InLoop { window_ns: win, governor })
            .build()?
            .run_traffic_with(&spec, seed)
    };
    if args.flag("sweep") {
        use chipsim::util::benchkit::Table;
        let mut table = Table::new(
            "DTM governor sweep: thermal ceiling vs serving SLO",
            &["governor", "peak_c", "violations", "residency_pct", "p99_us", "goodput_rps"],
        );
        for g in ["noop", "threshold", "pid"] {
            let report = run_one(window_ns, governor_of(g)?.keep_timeline(keep_timeline))?;
            let d = report.dtm().expect("in-loop run attaches a DtmReport");
            table.row(vec![
                d.governor.to_string(),
                format!("{:.2}", d.peak_c),
                d.ceiling_violations.to_string(),
                format!("{:.1}", d.throttle_residency * 100.0),
                format!("{:.1}", report.stats.overall.hist.quantile(0.99) as f64 / 1e3),
                format!("{:.0}", report.stats.goodput_rps()),
            ]);
        }
        table.print();
        return Ok(());
    }
    // A dtm-* scenario carries its own in-loop spec; --governor replaces
    // it (and equips plain traffic scenarios with one).  --keep-timeline
    // still applies to the preset's governor.
    let report = match (&scenario_thermal, args.get("governor")) {
        (Some(ThermalSpec::InLoop { window_ns: preset_win, governor }), None) => {
            let governor = if args.get("keep-timeline").is_some() {
                governor.clone().keep_timeline(keep_timeline)
            } else {
                governor.clone()
            };
            run_one(*preset_win, governor)?
        }
        (_, explicit) => run_one(
            window_ns,
            governor_of(explicit.unwrap_or("threshold"))?.keep_timeline(keep_timeline),
        )?,
    };
    print!("{}", report.summary());
    if let Some(path) = args.get("csv") {
        let d = report.dtm().expect("in-loop run attaches a DtmReport");
        std::fs::write(path, d.timeline_csv())?;
        if (d.timeline.len() as u64) < d.windows {
            println!(
                "dtm window trace written to {path} (trailing {} of {} windows — pass \
                 --keep-timeline N to keep more)",
                d.timeline.len(),
                d.windows
            );
        } else {
            println!("dtm window trace written to {path}");
        }
    }
    Ok(())
}

/// Fleet-scale serving: N replica boards (each a full co-simulation with
/// its own network, thermal, and DTM state) behind one dispatcher pulling
/// from one global arrival stream.  Routing, autoscaling, and
/// thermal-emergency migration are pluggable; the report aggregates
/// per-replica serving stats into global p50/p99/goodput plus scale and
/// migration events.  `--sweep routing-compare` races all four routing
/// policies on the same seed; `--sweep knee` bisects over the offered
/// rate for the *fleet* saturation knee (same bisection as `chipsim
/// traffic --sweep`, driving a whole fleet per probe).
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use std::sync::Arc;

    use chipsim::fleet::{parse_autoscaler, parse_routing, Fleet, FleetSpec};
    use chipsim::scenario::FleetPreset;
    use chipsim::serving::{ArrivalSpec, LoadSweep, TrafficSpec};
    let inst = RunOptions::from_args(args)?.instrument();
    let reg = Registry::builtin();
    type SimFactory = Arc<dyn Fn() -> anyhow::Result<Simulation>>;
    let (spec, seed, make_sim, preset): (TrafficSpec, u64, SimFactory, Option<FleetPreset>) =
        if let Some(name) = args.get("scenario") {
            let sc = reg.get(name).ok_or_else(|| {
                anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
            })?;
            let seed = args.get_u64("seed", sc.default_seed)?;
            let spec = sc.traffic_spec(seed).ok_or_else(|| {
                anyhow::anyhow!(
                    "scenario '{name}' is not a traffic scenario; a fleet serves an \
                     arrival stream (fleet-* and traffic-* presets qualify)"
                )
            })?;
            let preset = sc.fleet_preset().cloned();
            let sc = sc.clone();
            (spec, seed, Arc::new(move || sc.build()), preset)
        } else {
            let hw = build_hw(args)?;
            let params = build_params(args)?;
            let seed = args.get_u64("seed", params.seed)?;
            let rate = args.get_f64("rate", 6_000.0)?;
            let arrivals = match args.get_or("arrivals", "poisson") {
                "poisson" => ArrivalSpec::poisson(rate),
                "burst" => ArrivalSpec::on_off(2.0 * rate, 0.0, 5e6, 5e6),
                "diurnal" => ArrivalSpec::diurnal(
                    rate,
                    0.6,
                    (args.get_f64("period-ms", 20.0)? * 1e6) as u64,
                ),
                "trace" => ArrivalSpec::trace_file(args.get("trace-file").ok_or_else(|| {
                    anyhow::anyhow!("--arrivals trace requires --trace-file FILE.json")
                })?)?,
                other => {
                    anyhow::bail!("unknown --arrivals '{other}' (poisson|burst|diurnal|trace)")
                }
            }
            .inferences(args.get_u64("inferences", 1)? as u32);
            let spec = TrafficSpec::new(arrivals)
                .horizon_ms(args.get_f64("horizon-ms", 30.0)?)
                .warmup_ms(args.get_f64("warmup-ms", 5.0)?)
                .window_ms(args.get_f64("window-ms", 5.0)?)
                .slo_ms(args.get_f64("slo-ms", 2.0)?);
            (
                spec,
                seed,
                Arc::new(move || {
                    Simulation::builder().hardware(hw.clone()).params(params.clone()).build()
                }),
                None,
            )
        };
    // --rate on a scenario rescales its arrival shape (generic runs
    // already consumed --rate above).  Steady-state early stop never
    // applies to fleets: the full horizon always runs.
    let mut spec = TrafficSpec { steady: None, ..spec };
    if args.get("scenario").is_some() && args.get("rate").is_some() {
        spec.arrivals = spec.arrivals.with_rate(args.get_f64("rate", 0.0)?)?;
    }
    // CLI knobs override the preset, which overrides the defaults.
    let p = preset.as_ref();
    let replicas = args.get_usize("replicas", p.map_or(4, |p| p.replicas))?;
    let max_replicas =
        args.get_usize("max-replicas", p.map_or(replicas, |p| p.max_replicas))?;
    let routing_name =
        args.get_or("routing", p.map_or("least-outstanding", |p| p.routing)).to_string();
    let autoscale_name = args.get_or("autoscale", p.map_or("none", |p| p.autoscale)).to_string();
    let epoch_us = args.get_f64("epoch-us", p.map_or(200.0, |p| p.epoch_ns as f64 / 1e3))?;
    let cold_ms =
        args.get_f64("cold-start-ms", p.map_or(5.0, |p| p.cold_start_ns as f64 / 1e6))?;
    let emergency = match args.get("emergency-c") {
        Some(_) => Some(args.get_f64("emergency-c", 0.0)?),
        None => p.and_then(|p| p.emergency_c),
    };
    // Replica boards advance on the shared worker pool; `--threads`
    // sizes it (0 / absent = all cores).  Per-board NoI sharding stays
    // off here — nested parallelism under the fleet pool would
    // oversubscribe, and the pool suppresses it anyway.
    let threads = inst.options().pool_threads();
    // --faults replaces a scenario's built-in plan; either way the plan
    // reaches both the dispatcher (board: events, retry policy) and —
    // via the spawn seam — every replica's simulation.
    let faults = inst.options().faults.clone().or_else(|| {
        args.get("scenario").and_then(|n| reg.get(n)).and_then(|sc| sc.fault_plan().cloned())
    });
    let fleet_spec = |traffic: TrafficSpec| {
        let mut fs = FleetSpec::new(traffic, replicas)
            .max_replicas(max_replicas)
            .epoch_us(epoch_us)
            .cold_start_ms(cold_ms)
            .threads(threads)
            .faults(faults.clone());
        if let Some(c) = emergency {
            fs = fs.emergency_c(c);
        }
        fs
    };
    let trace_cfg = inst.options().trace.clone();
    let build_fleet = |traffic: TrafficSpec, routing: &str| -> anyhow::Result<Fleet> {
        let f = make_sim.clone();
        Ok(Fleet::new(fleet_spec(traffic), move || f(), parse_routing(routing)?)
            .autoscaler(parse_autoscaler(&autoscale_name)?)
            .trace(trace_cfg.clone()))
    };
    // `--sweep routing-compare` (also: bare `--sweep`, `--sweep=knee`).
    let sweep_kind = if args.flag("sweep") || args.get("sweep").is_some() {
        Some(
            args.get("sweep")
                .map(|s| s.to_string())
                .or_else(|| args.positionals.get(1).cloned())
                .unwrap_or_else(|| "routing-compare".to_string()),
        )
    } else {
        None
    };
    anyhow::ensure!(
        sweep_kind.is_none() || trace_cfg.is_none(),
        "--trace does not combine with --sweep (trace a single run)"
    );
    anyhow::ensure!(
        sweep_kind.is_none() || inst.options().faults_out.is_none(),
        "--faults-out does not combine with --sweep (write a single run's report)"
    );
    // Profile attached to the single-run report; sweeps fall back to a
    // snapshot over the whole subcommand (all probes share one
    // collection).
    let mut attached: Option<chipsim::prof::ProfileReport> = None;
    match sweep_kind.as_deref() {
        Some("routing-compare") => {
            use chipsim::util::benchkit::Table;
            let mut table = Table::new(
                "fleet routing compare (same seed, same arrival stream)",
                &["routing", "completed", "p99_us", "viol_pct", "goodput_rps", "migrations"],
            );
            for name in ["round-robin", "least-outstanding", "affinity", "thermal"] {
                let report = build_fleet(spec.clone(), name)?.run(seed)?;
                table.row(vec![
                    name.to_string(),
                    report.global.completed().to_string(),
                    format!("{:.1}", report.global.overall.hist.quantile(0.99) as f64 / 1e3),
                    format!("{:.2}", report.global.violation_frac() * 100.0),
                    format!("{:.0}", report.goodput_rps()),
                    report.migrations.to_string(),
                ]);
            }
            table.print();
        }
        Some("knee") => {
            let lo = args.get_f64("lo", 1_000.0)?;
            let hi = args.get_f64("hi", 20_000.0)?;
            let sweep = LoadSweep::new(spec.clone(), lo, hi).iters(args.get_usize("iters", 5)?);
            let result = sweep.run_with_probe(|probe_spec| {
                Ok(build_fleet(probe_spec.clone(), &routing_name)?.run(seed)?.global)
            })?;
            println!(
                "fleet load sweep ({replicas} replicas, {routing_name} routing, \
                 {} probes):",
                result.probes.len()
            );
            for pr in &result.probes {
                println!(
                    "  {:>8.0} req/s  p99 {:>9.1} µs  goodput {:>8.0} req/s  viol {:>6.2} %  {}",
                    pr.rate_rps,
                    pr.p99_ns as f64 / 1e3,
                    pr.goodput_rps,
                    pr.violation_frac * 100.0,
                    if pr.meets_slo { "PASS" } else { "fail" },
                );
            }
            println!(
                "fleet saturation knee: ~{:.0} req/s (highest probed rate meeting the SLO)",
                result.knee_rps
            );
        }
        Some(other) => anyhow::bail!("unknown fleet sweep '{other}' (routing-compare|knee)"),
        None => {
            let mut fleet = build_fleet(spec, &routing_name)?;
            let report = fleet.run(seed)?;
            print!("{}", report.summary());
            inst.write_fault_report(report.fault.as_ref())?;
            attached = report.profile.clone();
            // The fleet attaches one recorder per replica itself; adopt
            // them all into the shared merged export.
            inst.adopt_tracers(fleet.tracers());
            inst.export_trace(&format!(
                "trace_{}.json",
                args.get("scenario").unwrap_or("fleet")
            ))?;
        }
    }
    inst.finish_profile(
        attached.as_ref(),
        &format!("profile_{}.json", args.get("scenario").unwrap_or("fleet")),
    )?;
    Ok(())
}

/// Flight-recorder run of one named scenario — traffic, mix, fleet, or
/// batch — with every category on by default: prints the usual summary
/// (including the per-component latency breakdown for serving runs) and
/// writes Chrome trace-event JSON for Perfetto / chrome://tracing.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use chipsim::fleet::{parse_autoscaler, parse_routing, Fleet, FleetSpec};
    use chipsim::serving::TrafficSpec;
    let reg = Registry::builtin();
    let name = args
        .get("scenario")
        .map(str::to_string)
        .or_else(|| args.positionals.get(1).cloned())
        .ok_or_else(|| {
            anyhow::anyhow!("trace needs --scenario NAME (see `chipsim scenarios`)")
        })?;
    let sc = reg.get(&name).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
    })?;
    let seed = args.get_u64("seed", sc.default_seed)?;
    // This subcommand *is* the trace opt-in: an absent --trace flag
    // still records, with every category on by default.
    let inst = {
        let mut opts = RunOptions::from_args(args)?;
        if opts.trace.is_none() {
            opts.trace = Some(chipsim::trace::TraceConfig::default());
        }
        opts.instrument()
    };
    let cfg = inst.options().trace.clone().expect("trace config forced on above");
    let out_name = format!("trace_{name}.json");
    if sc.is_fleet() {
        let p = sc.fleet_preset().expect("fleet scenario carries a preset").clone();
        let spec = TrafficSpec {
            steady: None,
            ..sc.traffic_spec(seed).expect("fleet preset serves a traffic spec")
        };
        let mut fs = FleetSpec::new(spec, p.replicas)
            .max_replicas(p.max_replicas)
            .threads(inst.options().pool_threads());
        fs.epoch_ns = p.epoch_ns;
        fs.cold_start_ns = p.cold_start_ns;
        fs.emergency_c = p.emergency_c;
        fs.faults = sc.fault_plan().cloned();
        let sc = sc.clone();
        let mut fleet = Fleet::new(fs, move || sc.build(), parse_routing(p.routing)?)
            .autoscaler(parse_autoscaler(p.autoscale)?)
            .trace(Some(cfg));
        let report = fleet.run(seed)?;
        print!("{}", report.summary());
        inst.adopt_tracers(fleet.tracers());
    } else if sc.is_mix() {
        let mix = sc.mix_spec(seed).expect("mix scenario carries a mix").interference(false);
        let report = chipsim::serving::mix::run_mix(
            || {
                let mut sim = sc.build()?;
                // attach() records the first board only — exactly the
                // co-located pass this subcommand wants traced.
                inst.attach(&mut sim);
                Ok(sim)
            },
            &mix,
            seed,
        )?;
        print!("{}", report.summary());
    } else if sc.is_traffic() {
        let spec = sc.traffic_spec(seed).expect("traffic scenario carries a spec");
        let mut sim = sc.build()?;
        inst.attach(&mut sim);
        let report = sim.run_traffic_with(&spec, seed)?;
        print!("{}", report.summary());
    } else {
        let mut sim = sc.build()?;
        inst.attach(&mut sim);
        let report = sim.run(sc.workload(seed))?;
        print!("{}", report.summary());
    }
    inst.export_trace(&out_name)
}

/// Self-profiling run of one named scenario — the "where does the
/// simulator's own wall-clock go?" view: runs the preset with the
/// profiler armed, prints the run summary plus the subsystem /
/// counter / worker-utilization tables, and writes the profile JSON
/// with its `.collapsed` flamegraph sibling.
fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    use chipsim::fleet::{parse_autoscaler, parse_routing, Fleet, FleetSpec};
    use chipsim::serving::TrafficSpec;
    let reg = Registry::builtin();
    let name = args
        .get("scenario")
        .map(str::to_string)
        .or_else(|| args.positionals.get(1).cloned())
        .ok_or_else(|| {
            anyhow::anyhow!("profile needs --scenario NAME (see `chipsim scenarios`)")
        })?;
    let sc = reg.get(&name).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario '{name}' — `chipsim scenarios` lists them")
    })?;
    let seed = args.get_u64("seed", sc.default_seed)?;
    // This subcommand *is* the profile opt-in: arm the profiler whether
    // or not --profile was spelled out.
    let inst = {
        let mut opts = RunOptions::from_args(args)?;
        opts.profile = true;
        opts.instrument()
    };
    let attached: Option<chipsim::prof::ProfileReport> = if sc.is_fleet() {
        let p = sc.fleet_preset().expect("fleet scenario carries a preset").clone();
        let spec = TrafficSpec {
            steady: None,
            ..sc.traffic_spec(seed).expect("fleet preset serves a traffic spec")
        };
        let mut fs = FleetSpec::new(spec, p.replicas)
            .max_replicas(p.max_replicas)
            .threads(inst.options().pool_threads());
        fs.epoch_ns = p.epoch_ns;
        fs.cold_start_ns = p.cold_start_ns;
        fs.emergency_c = p.emergency_c;
        fs.faults = sc.fault_plan().cloned();
        let sc = sc.clone();
        let mut fleet = Fleet::new(fs, move || sc.build(), parse_routing(p.routing)?)
            .autoscaler(parse_autoscaler(p.autoscale)?);
        let report = fleet.run(seed)?;
        print!("{}", report.summary());
        report.profile
    } else if sc.is_mix() {
        let report = sc.run_mix(seed)?;
        print!("{}", report.summary());
        report.sim.profile
    } else if sc.is_traffic() {
        let report = sc.run_traffic(seed)?;
        print!("{}", report.summary());
        report.sim.profile
    } else {
        let report = sc.run(seed)?;
        print!("{}", report.summary());
        report.profile
    };
    inst.finish_profile(attached.as_ref(), &format!("profile_{name}.json"))
}

fn cmd_scenarios() {
    let reg = Registry::builtin();
    println!("registered scenarios ({}):", reg.len());
    for sc in reg.iter() {
        let tag = if sc.is_fleet() {
            "[fleet] "
        } else if sc.is_dtm() {
            "[dtm] "
        } else if sc.is_mix() {
            "[mix] "
        } else if sc.is_traffic() {
            "[traffic] "
        } else {
            ""
        };
        let ftag = if sc.fault_plan().is_some() { "[faults] " } else { "" };
        println!("  {:<22} {tag}{ftag}{}", sc.name, sc.about);
    }
    println!(
        "\nrun one:     chipsim run --scenario NAME [--seed S]\
         \nrun traffic: chipsim traffic --scenario NAME [--rate R] [--seed S]\
         \nrun a mix:   chipsim mix --scenario NAME [--sweep interference] [--seed S]\
         \nrun a fleet: chipsim fleet --scenario NAME [--routing P] [--seed S]\
         \nrun a batch: chipsim batch [--scenarios a,b,c|all] [--threads N] [--seed S]\
         \nprofile one: chipsim profile --scenario NAME [--profile-out FILE.json]"
    );
}

/// Fault-injection reference and plan validator.  Without `--plan` it
/// prints the fault model and grammar; with `--plan SPEC` it parses the
/// plan, arms it against a hardware shape (`--rows/--cols/--topo`,
/// default 10x10 mesh), and prints the expanded toggle schedule — the
/// same expansion a run would execute, minus the run.
fn cmd_faults(args: &Args) -> anyhow::Result<()> {
    use chipsim::fault::{FaultDims, FaultPlan};
    use chipsim::noc::topology::Topology;
    let spec = args
        .get("plan")
        .map(str::to_string)
        .or_else(|| args.positionals.get(1).cloned());
    let Some(spec) = spec else {
        println!(
            "fault model (deterministic, seeded — same seed + same plan => \
             byte-identical FaultReport):\n\
             \n  kind     target         effect\
             \n  link     A-B or ?       undirected NoI link down: flows reroute or fail\
             \n  router   node index     every link touching the node goes down\
             \n  chiplet  chiplet index  mapper excludes it; in-flight segments abort\
             \n  sensor   chiplet index  stuck-at/drift readings feed the DTM governor\
             \n  board    replica index  fleet dispatcher crashes the whole board\n\
             \nplan grammar (events separated by ',' or ';'):\
             \n  KIND:TARGET[:MODE]@T[+D][%P[*K]]\
             \n    @T     first failure instant (ns/us/ms suffixes)\
             \n    +D     transient: repaired after D (omit = permanent)\
             \n    %P[*K] intermittent: re-fires every P, K times (default 3)\
             \n    ?      random target drawn from the plan seed, not the run RNG\
             \n  sensor:IDX:stuck=C@T    reads a constant C degC\
             \n  sensor:IDX:drift=R@T    reading error grows R degC per ms\
             \n  seed=N                  plan seed for ? targets\
             \n  retry=M:B:C:D           fleet retry policy: max attempts, backoff,\
             \n                          backoff cap, per-request deadline\n\
             \nexamples:\
             \n  chipsim traffic --scenario fault-chiplet-kill --faults-out fault.json\
             \n  chipsim traffic --rows 6 --cols 6 --faults \"link:14-15@4ms+1ms%4ms*3\"\
             \n  chipsim fleet --replicas 4 --faults \"board:1@8ms, retry=3:200us:2ms:20ms\"\
             \n  chipsim faults --plan \"chiplet:7@3ms+6ms\" --rows 6 --cols 6\n\
             \npresets: fault-link-flap, fault-chiplet-kill, fault-fleet-board-crash \
             (see `chipsim scenarios`)"
        );
        return Ok(());
    };
    let plan = FaultPlan::parse(&spec)?;
    if plan.is_empty() {
        println!("plan parses to zero events (valid, arms to nothing)");
        return Ok(());
    }
    let hw = build_hw(args)?;
    let topo = Topology::build(&hw);
    let dims = FaultDims {
        links: topo.links.len(),
        nodes: topo.num_nodes,
        chiplets: hw.num_chiplets(),
    };
    let toggles = plan.arm(&dims)?;
    let replicas = args.get_usize("replicas", 4)?;
    let boards = plan.arm_boards(replicas)?;
    println!(
        "plan OK: {} event(s) -> {} sim toggle(s) against {} links / {} nodes / {} \
         chiplets, {} board crash(es) against {replicas} replica(s)",
        plan.events.len(),
        toggles.len(),
        dims.links,
        dims.nodes,
        dims.chiplets,
        boards.len(),
    );
    for t in &toggles {
        println!(
            "  {:>12} ns  {:<7} {:?} {}",
            t.at_ns,
            t.kind.name(),
            t.target,
            if t.up { "repaired" } else { "DOWN" },
        );
    }
    for (at, id) in &boards {
        println!("  {at:>12} ns  board   {id} CRASH (permanent)");
    }
    println!(
        "retry policy: {} attempt(s), backoff {} ns (cap {} ns), deadline {} ns",
        plan.retry.max_attempts, plan.retry.backoff_ns, plan.retry.backoff_cap_ns,
        plan.retry.deadline_ns,
    );
    Ok(())
}

fn cmd_batch(args: &Args) -> anyhow::Result<()> {
    let inst = RunOptions::from_args(args)?.instrument();
    let reg = Registry::builtin();
    let names: Vec<String> = match args.get("scenarios") {
        None | Some("all") => reg.names().iter().map(|s| s.to_string()).collect(),
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let runner = SweepRunner::new()
        .threads(inst.options().pool_threads())
        .base_seed(args.get_u64("seed", 0xC0FFEE)?);
    let t0 = std::time::Instant::now();
    let outcomes = runner.run(&reg, &refs)?;
    println!(
        "batch: {} scenarios in {:.2} s wall",
        outcomes.len(),
        t0.elapsed().as_secs_f64()
    );
    for o in &outcomes {
        let (is_streaming, tag, cmd) = match reg.get(&o.scenario) {
            Some(s) if s.is_mix() => (true, "[mix]", "mix"),
            Some(s) if s.is_traffic() => (true, "[traffic]", "traffic"),
            _ => (false, "", ""),
        };
        match &o.result {
            // Traffic and mix scenarios stream in constant memory: the
            // batch view shows span/energy only (per-model outcomes are
            // not retained) — `chipsim traffic|mix --scenario NAME` has
            // the serving stats.
            Ok(r) if is_streaming => println!(
                "  {:<22} seed {:#018x}  {tag} span {:.3} ms, {:.2} mJ \
                 (serving stats: `chipsim {cmd} --scenario {}`)",
                o.scenario,
                o.seed,
                r.span_ns as f64 / 1e6,
                (r.compute_energy_pj + r.comm_energy_pj) / 1e9,
                o.scenario,
            ),
            Ok(r) => println!(
                "  {:<22} seed {:#018x}  {} models, {} dropped, span {:.3} ms, {:.2} mJ",
                o.scenario,
                o.seed,
                r.outcomes.len(),
                r.dropped.len(),
                r.span_ns as f64 / 1e6,
                (r.compute_energy_pj + r.comm_energy_pj) / 1e9,
            ),
            Err(e) => println!("  {:<22} FAILED: {e:#}", o.scenario),
        }
    }
    // One collection across every scenario and worker thread: the
    // worker-utilization table is the batch's parallel-efficiency view.
    inst.finish_profile(None, "profile_batch.json")?;
    Ok(())
}

/// DSE sweep: topology presets x link widths x pipelining, one co-sim per
/// design point, CSV to the results dir.  The loop an architect runs for
/// early exploration (paper §I: "fast and accurate simulation is key to
/// enabling iteration").
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use chipsim::metrics::Csv;
    let rows = args.get_usize("rows", 8)?;
    let cols = args.get_usize("cols", 8)?;
    let n = args.get_usize("models", 12)?;
    let inferences = args.get_u64("inferences", 5)? as u32;
    let widths = args.get_u64_list("widths", &[16, 32, 64])?;
    let seed = args.get_u64("seed", 0xC0FFEE)?;
    let mut csv = Csv::new(&[
        "topology", "link_bytes", "pipelined", "models_done", "makespan_ms",
        "mean_resnet18_lat_us", "energy_mj", "mean_util_pct", "peak_link_util_pct",
    ]);
    let presets: Vec<(&str, HardwareConfig)> = vec![
        ("mesh", scenario::hardware_preset("mesh", rows, cols, rows, 8)?),
        ("hetero", scenario::hardware_preset("hetero", rows, cols, rows, 8)?),
        ("floret", scenario::hardware_preset("floret", rows, cols, rows, 8)?),
    ];
    for (name, base_hw) in &presets {
        for &w in &widths {
            for pipelined in [false, true] {
                let mut hw = base_hw.clone();
                hw.link.width_bytes = w;
                let params = SimParams {
                    pipelined,
                    inferences_per_model: inferences,
                    warmup_ns: 0,
                    cooldown_ns: 0,
                    seed,
                    ..SimParams::default()
                };
                let report = Simulation::builder()
                    .hardware(hw)
                    .params(params)
                    .build()?
                    .run(WorkloadConfig::cnn_stream(n, inferences, seed))?;
                let lat = report
                    .mean_latency_of(chipsim::workload::ModelKind::ResNet18)
                    .map(|x| format!("{:.1}", x / 1e3))
                    .unwrap_or_else(|| "-".into());
                csv.row(vec![
                    name.to_string(),
                    w.to_string(),
                    pipelined.to_string(),
                    report.outcomes.len().to_string(),
                    format!("{:.3}", report.span_ns as f64 / 1e6),
                    lat,
                    format!("{:.2}", (report.compute_energy_pj + report.comm_energy_pj) / 1e9),
                    format!("{:.1}", report.mean_utilization() * 100.0),
                    format!("{:.1}", report.link_util.peak * 100.0),
                ]);
                println!(
                    "sweep: {name:<7} w={w:<4} pipelined={pipelined:<5} done={}",
                    report.outcomes.len()
                );
            }
        }
    }
    let path = csv.save("sweep.csv")?;
    println!("sweep results written to {}", path.display());
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let rt = chipsim::runtime::Runtime::open_default()?;
    println!("artifacts at {}:", chipsim::runtime::Runtime::default_dir().display());
    for name in rt.artifact_names() {
        let e = &rt.manifest.entries[name];
        let shapes: Vec<String> = e.inputs.iter().map(|i| format!("{:?}", i.shape)).collect();
        println!("  {name:<28} inputs {} -> {} outputs", shapes.join(" "), e.num_outputs);
    }
    Ok(())
}

/// Entry point split from [`dispatch`] so every error path — malformed
/// flags included — prints one clean `error:` line on stderr and exits
/// nonzero, instead of unwinding through a Debug-formatted panic or
/// `anyhow` return.
fn main() {
    logging::init();
    let args = Args::from_env(&["pipelined", "quick", "help", "sweep", "trace", "profile"]);
    if args.flag("help") || args.positionals.is_empty() {
        print!("{}", help().render());
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let cmd = args.positionals[0].as_str();
    match cmd {
        "run" => cmd_run(args)?,
        "traffic" => cmd_traffic(args)?,
        "mix" => cmd_mix(args)?,
        "dtm" => cmd_dtm(args)?,
        "fleet" => cmd_fleet(args)?,
        "trace" => cmd_trace(args)?,
        "profile" => cmd_profile(args)?,
        "faults" => cmd_faults(args)?,
        "scenarios" => cmd_scenarios(),
        "batch" => cmd_batch(args)?,
        "sweep" => cmd_sweep(args)?,
        "table4" => experiments::table4(quick).print(),
        "fig6" => experiments::fig6(quick).print(),
        "fig7" => experiments::fig7(quick).print(),
        "table5" => experiments::table5(quick).print(),
        "table6" => experiments::table6(quick).print(),
        "fig8" => experiments::fig8(quick).print(),
        "fig9" => experiments::fig9(quick).print(),
        "fig10" => experiments::fig10(quick).print(),
        "fig11" => experiments::fig11().print(),
        "table7" => experiments::table7().print(),
        "table8" => experiments::table8(quick).print(),
        "all" => {
            experiments::table4(quick).print();
            experiments::fig6(quick).print();
            experiments::fig7(quick).print();
            experiments::table5(quick).print();
            experiments::table6(quick).print();
            experiments::fig8(quick).print();
            experiments::fig9(quick).print();
            experiments::fig10(quick).print();
            experiments::fig11().print();
            experiments::table7().print();
            experiments::table8(quick).print();
        }
        "artifacts" => cmd_artifacts()?,
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{}", help().render());
            std::process::exit(2);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_lists_the_shared_run_option_cluster() {
        let rendered = help().render();
        for flag in ["--threads", "--trace", "--profile", "--faults", "--faults-out"] {
            assert!(rendered.contains(flag), "help is missing {flag}");
        }
    }

    #[test]
    fn run_options_parse_from_cli_args() {
        let args = Args::parse(
            ["--threads", "4", "--faults", "link:0-1@1ms"].iter().map(|s| s.to_string()),
            &[],
        );
        let opts = RunOptions::from_args(&args).unwrap();
        assert!(opts.exec().is_parallel());
        assert!(opts.faults.is_some());
    }
}
