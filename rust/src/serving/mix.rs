//! Multi-tenant co-execution: N DNN serving tenants resident on one
//! chiplet system at the same time.
//!
//! CHIPSIM's core claim is that computation and communication are
//! modeled *concurrently*, so contention between co-running workloads is
//! captured rather than averaged away.  This module is where that claim
//! pays off: a [`WorkloadMix`] puts several [`TenantSpec`]s — each its
//! own model mix, arrival process, and SLO — onto one simulation, whose
//! single shared [`crate::noc::NetworkSim`] makes packet/flit
//! contention, power, and DTM throttling cross-tenant *by construction*.
//!
//! * a [`crate::mapping::PlacementPolicy`] turns tenant memory demands
//!   into per-chiplet masks (disjoint partition, interleaved, greedy
//!   best-fit) before the run; every mapping attempt is confined to the
//!   requesting tenant's mask;
//! * [`MixSource`] merges the tenants' lazy arrival streams into one
//!   monotone request stream, tagging each request with its tenant;
//! * [`MixSink`] splits completions back out into per-tenant
//!   [`ServingStats`] (p50–p99.9, goodput, SLO violations);
//! * [`run_mix`] drives the co-located run and, when
//!   [`WorkloadMix::interference`] is set, re-runs every tenant *solo on
//!   its same placement* to fill the [`InterferenceMatrix`]: co-located
//!   vs solo tail latency, the signature of cross-tenant contention.
//!
//! ```no_run
//! use chipsim::prelude::*;
//! use chipsim::serving::mix::{run_mix, TenantSpec, WorkloadMix};
//!
//! let mix = WorkloadMix::new(vec![
//!     TenantSpec::new("latency", ArrivalSpec::poisson(1_200.0)).slo_ms(2.0),
//!     TenantSpec::new("batch", ArrivalSpec::poisson(400.0)).slo_ms(8.0),
//! ])
//! .placement(PlacementPolicy::DisjointPartition)
//! .horizon_ms(30.0)
//! .interference(true);
//! let report = run_mix(
//!     || {
//!         Simulation::builder()
//!             .hardware(HardwareConfig::homogeneous_mesh(8, 8))
//!             .params(SimParams { pipelined: true, ..SimParams::default() })
//!             .build()
//!     },
//!     &mix,
//!     0xC0FFEE,
//! )
//! .expect("mix run");
//! println!("{}", report.summary());
//! ```

use crate::mapping::placement::{compute_placements, PlacementPolicy, TenantDemand};
use crate::mapping::MemoryLedger;
use crate::noc::TenantComm;
use crate::power::PowerWindow;
use crate::serving::arrivals::{ArrivalProcess, ArrivalSpec};
use crate::serving::engine::{WindowRoller, WindowSummary};
use crate::serving::slo::ServingStats;
use crate::sim::{ModelOutcome, PowerPort, RequestSource, SimReport, Simulation, StreamSink};
use crate::trace::BreakdownStats;
use crate::util::rng::Rng;
use crate::workload::{ModelKind, ModelRequest};
use crate::TimeNs;

// ------------------------------------------------------------------ tenants

/// One tenant of a multi-tenant mix: a named serving workload with its
/// own arrival process and latency SLO.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub arrivals: ArrivalSpec,
    /// End-to-end (arrival → finish) latency SLO for this tenant.
    pub slo_ns: TimeNs,
}

impl TenantSpec {
    pub fn new(name: &str, arrivals: ArrivalSpec) -> TenantSpec {
        TenantSpec { name: name.to_string(), arrivals, slo_ns: 1_000_000 }
    }

    /// Poisson arrivals of a single model kind — the common CLI shape.
    pub fn poisson(name: &str, kind: ModelKind, rate_rps: f64) -> TenantSpec {
        TenantSpec::new(name, ArrivalSpec::poisson(rate_rps).kinds(&[kind]))
    }

    pub fn slo_ms(mut self, ms: f64) -> TenantSpec {
        self.slo_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn slo_us(mut self, us: f64) -> TenantSpec {
        self.slo_ns = (us * 1e3) as TimeNs;
        self
    }

    /// Memory demand used by placement policies to size this tenant's
    /// chiplet region.
    pub fn demand(&self) -> TenantDemand {
        TenantDemand::of_kinds(&self.arrivals.model_kinds())
    }
}

/// A set of tenants co-resident on one chiplet system, plus the shared
/// run shape (horizon, warm-up, stats window) and placement policy.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    pub tenants: Vec<TenantSpec>,
    pub placement: PlacementPolicy,
    /// Arrivals stop at this virtual time; in-flight work then drains.
    pub horizon_ns: TimeNs,
    /// Completions before this virtual time are excluded from stats.
    pub warmup_ns: TimeNs,
    /// Stats / power-drain window width.
    pub window_ns: TimeNs,
    /// Bounded ring of trailing per-window summaries kept for the report.
    pub keep_windows: usize,
    /// Also run every tenant solo (same placement, same seed) to fill
    /// the [`InterferenceMatrix`].  Costs N extra runs.
    pub interference: bool,
}

impl WorkloadMix {
    pub fn new(tenants: Vec<TenantSpec>) -> WorkloadMix {
        WorkloadMix {
            tenants,
            placement: PlacementPolicy::DisjointPartition,
            horizon_ns: 30_000_000, // 30 ms
            warmup_ns: 4_000_000,   // 4 ms
            window_ns: 2_000_000,   // 2 ms
            keep_windows: 32,
            interference: false,
        }
    }

    pub fn placement(mut self, policy: PlacementPolicy) -> WorkloadMix {
        self.placement = policy;
        self
    }

    pub fn horizon_ms(mut self, ms: f64) -> WorkloadMix {
        self.horizon_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn warmup_ms(mut self, ms: f64) -> WorkloadMix {
        self.warmup_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn window_ms(mut self, ms: f64) -> WorkloadMix {
        self.window_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn keep_windows(mut self, n: usize) -> WorkloadMix {
        self.keep_windows = n.max(1);
        self
    }

    pub fn interference(mut self, on: bool) -> WorkloadMix {
        self.interference = on;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tenants.is_empty(), "a mix needs at least one tenant");
        for (i, t) in self.tenants.iter().enumerate() {
            anyhow::ensure!(!t.name.is_empty(), "tenant {i} has an empty name");
            anyhow::ensure!(t.slo_ns > 0, "tenant '{}': slo_ns must be > 0", t.name);
            anyhow::ensure!(
                !self.tenants[..i].iter().any(|o| o.name == t.name),
                "duplicate tenant name '{}'",
                t.name
            );
        }
        anyhow::ensure!(self.window_ns > 0, "mix window_ns must be > 0");
        anyhow::ensure!(
            self.horizon_ns >= self.window_ns,
            "mix horizon ({} ns) shorter than one window ({} ns)",
            self.horizon_ns,
            self.window_ns
        );
        anyhow::ensure!(
            self.warmup_ns < self.horizon_ns,
            "warm-up ({} ns) swallows the whole horizon ({} ns)",
            self.warmup_ns,
            self.horizon_ns
        );
        Ok(())
    }

    /// Per-tenant memory demands in tenant order.
    pub fn demands(&self) -> Vec<TenantDemand> {
        self.tenants.iter().map(|t| t.demand()).collect()
    }
}

/// Per-tenant arrival seed: deterministic in `(mix seed, tenant index)`
/// and — crucially — identical between the co-located run and the
/// tenant's solo baseline, so both replay byte-identical request streams.
fn tenant_seed(seed: u64, idx: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed;
    for b in (idx as u64).to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Rng::new(h).next_u64()
}

// ------------------------------------------------------------------- source

struct Lane {
    tenant: usize,
    generator: Box<dyn ArrivalProcess>,
    horizon_ns: TimeNs,
    peeked: Option<ModelRequest>,
    exhausted: bool,
    emitted: u64,
}

impl Lane {
    fn fill(&mut self) {
        if self.peeked.is_some() || self.exhausted {
            return;
        }
        match self.generator.next_request() {
            Some(r) if r.arrival_ns <= self.horizon_ns => self.peeked = Some(r),
            _ => self.exhausted = true,
        }
    }
}

/// [`RequestSource`] merging N tenant arrival streams into one monotone
/// stream.  Each emitted request carries its tenant index; ids are
/// renumbered globally (ties between lanes resolve by tenant order, so
/// the merge is deterministic).
pub struct MixSource {
    lanes: Vec<Lane>,
    next_id: usize,
}

impl MixSource {
    /// All tenants of the mix (the co-located run).
    pub fn new(mix: &WorkloadMix, seed: u64) -> anyhow::Result<MixSource> {
        MixSource::build(mix, seed, None)
    }

    /// Only tenant `idx`, with the *same* per-tenant seed the co-located
    /// run uses — the solo baseline of the interference matrix.
    pub fn solo(mix: &WorkloadMix, seed: u64, idx: usize) -> anyhow::Result<MixSource> {
        anyhow::ensure!(idx < mix.tenants.len(), "no tenant {idx} in a {}-tenant mix",
            mix.tenants.len());
        MixSource::build(mix, seed, Some(idx))
    }

    fn build(mix: &WorkloadMix, seed: u64, only: Option<usize>) -> anyhow::Result<MixSource> {
        let mut lanes = Vec::new();
        for (idx, tenant) in mix.tenants.iter().enumerate() {
            if only.is_some_and(|o| o != idx) {
                continue;
            }
            lanes.push(Lane {
                tenant: idx,
                generator: tenant.arrivals.build(tenant_seed(seed, idx))?,
                horizon_ns: mix.horizon_ns,
                peeked: None,
                exhausted: false,
                emitted: 0,
            });
        }
        Ok(MixSource { lanes, next_id: 0 })
    }

    /// Lane index holding the earliest pending arrival.
    fn pick(&mut self) -> Option<usize> {
        let mut best: Option<(TimeNs, usize)> = None;
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            lane.fill();
            if let Some(r) = &lane.peeked {
                let key = (r.arrival_ns, i);
                let better = match best {
                    Some(b) => key < b,
                    None => true,
                };
                if better {
                    best = Some(key);
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Requests emitted for tenant `idx` so far.
    pub fn emitted_of(&self, idx: usize) -> u64 {
        self.lanes.iter().find(|l| l.tenant == idx).map_or(0, |l| l.emitted)
    }

    /// Whether every lane ran past the horizon (or dry).
    pub fn exhausted(&self) -> bool {
        self.lanes.iter().all(|l| l.exhausted && l.peeked.is_none())
    }
}

impl RequestSource for MixSource {
    fn peek_arrival_ns(&mut self) -> Option<TimeNs> {
        let i = self.pick()?;
        self.lanes[i].peeked.as_ref().map(|r| r.arrival_ns)
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        let i = self.pick()?;
        let lane = &mut self.lanes[i];
        let mut req = lane.peeked.take()?;
        req.tenant = lane.tenant;
        req.id = self.next_id;
        self.next_id += 1;
        lane.emitted += 1;
        Some(req)
    }
}

// --------------------------------------------------------------------- sink

/// [`StreamSink`] splitting completions into per-tenant [`ServingStats`].
/// Window/power accounting is the same [`WindowRoller`] the single-tenant
/// traffic engine uses (one window behind virtual time; DTM-owned when
/// in-loop); the pooled window trace covers all tenants together.
pub struct MixSink {
    per: Vec<ServingStats>,
    breakdowns: Vec<BreakdownStats>,
    roller: WindowRoller,
}

impl MixSink {
    pub fn new(mix: &WorkloadMix, external_power: bool) -> MixSink {
        MixSink {
            per: mix
                .tenants
                .iter()
                .map(|t| ServingStats::new(t.slo_ns, mix.warmup_ns))
                .collect(),
            breakdowns: mix.tenants.iter().map(|_| BreakdownStats::new()).collect(),
            roller: WindowRoller::new(mix.window_ns, mix.keep_windows, external_power),
        }
    }

    /// Finalize after the event loop returned: fold the partial last
    /// window in and hand back the per-tenant stats and breakdowns.
    pub fn into_parts(
        self,
        sim: &mut SimReport,
    ) -> (Vec<ServingStats>, Vec<BreakdownStats>, Vec<WindowSummary>) {
        let windows = self.roller.finish(sim);
        (self.per, self.breakdowns, windows)
    }
}

impl StreamSink for MixSink {
    fn on_outcome(&mut self, outcome: &ModelOutcome, _now: TimeNs) -> bool {
        let latency = outcome.finished_ns.saturating_sub(outcome.arrival_ns);
        debug_assert!(outcome.tenant < self.per.len(), "outcome from unknown tenant");
        if let Some(stats) = self.per.get_mut(outcome.tenant) {
            if stats.record(outcome.kind, latency, outcome.finished_ns) {
                self.roller.record(latency);
                if let Some(bd) = &outcome.breakdown {
                    self.breakdowns[outcome.tenant].record(bd);
                }
            }
        }
        true
    }

    fn on_advance(&mut self, now: TimeNs, power: &mut PowerPort<'_>) -> bool {
        while self.roller.due(now) {
            self.roller.roll(power);
        }
        true
    }

    fn on_power_window(&mut self, window: &PowerWindow) {
        self.roller.on_power_window(window);
    }

    fn on_dropped(&mut self, _id: usize, _kind: ModelKind, tenant: usize, _now: TimeNs) {
        if let Some(stats) = self.per.get_mut(tenant) {
            stats.dropped += 1;
        }
    }

    fn retain_state(&self) -> bool {
        false
    }
}

// ------------------------------------------------------------------- report

/// One tenant's results inside a mix run.
#[derive(Debug)]
pub struct TenantOutcome {
    pub name: String,
    /// Requests injected before the horizon.
    pub offered: u64,
    /// Chiplets in this tenant's placement mask.
    pub chiplets: usize,
    pub slo_ns: TimeNs,
    /// Cumulative post-warm-up serving statistics.
    pub stats: ServingStats,
    /// Per-component latency breakdown over this tenant's post-warm-up
    /// completions (empty unless the run was traced with breakdowns on;
    /// excluded from [`MixReport::fingerprint`]).
    pub breakdown: BreakdownStats,
    /// The tenant's share of NoI traffic (flow→tenant attribution).
    pub comm: TenantComm,
}

/// Solo-vs-co-located tail latency of one tenant: the interference
/// matrix row.  The solo baseline runs the tenant alone *on the same
/// placement* with the same arrival stream, so any difference is pure
/// cross-tenant contention (shared links, shared chiplet queues, shared
/// thermal budget) — not a placement artifact.
#[derive(Debug, Clone)]
pub struct InterferenceEntry {
    pub tenant: String,
    pub solo_completed: u64,
    pub solo_p50_ns: u64,
    pub solo_p99_ns: u64,
    pub solo_goodput_rps: f64,
    pub co_completed: u64,
    pub co_p50_ns: u64,
    pub co_p99_ns: u64,
    pub co_goodput_rps: f64,
}

impl InterferenceEntry {
    /// Co-located p99 over solo p99 (1.0 = no interference).
    pub fn p99_slowdown(&self) -> f64 {
        if self.solo_p99_ns == 0 {
            return if self.co_p99_ns == 0 { 1.0 } else { f64::INFINITY };
        }
        self.co_p99_ns as f64 / self.solo_p99_ns as f64
    }
}

/// Per-tenant solo-vs-co-located comparison.
#[derive(Debug, Clone, Default)]
pub struct InterferenceMatrix {
    pub entries: Vec<InterferenceEntry>,
}

impl InterferenceMatrix {
    /// The worst p99 slowdown any tenant suffers from co-location.
    pub fn max_p99_slowdown(&self) -> f64 {
        self.entries.iter().map(|e| e.p99_slowdown()).fold(0.0, f64::max)
    }

    pub fn get(&self, tenant: &str) -> Option<&InterferenceEntry> {
        self.entries.iter().find(|e| e.tenant == tenant)
    }
}

/// Result of a multi-tenant mix run.
#[derive(Debug)]
pub struct MixReport {
    pub seed: u64,
    pub placement: PlacementPolicy,
    pub tenants: Vec<TenantOutcome>,
    /// Trailing per-window summaries of the co-located run (all tenants
    /// pooled; bounded by `WorkloadMix::keep_windows`).
    pub windows: Vec<WindowSummary>,
    /// Filled when the mix ran with `interference(true)`.
    pub interference: Option<InterferenceMatrix>,
    /// Tail simulation state of the co-located run.
    pub sim: SimReport,
}

impl MixReport {
    pub fn span_ns(&self) -> TimeNs {
        self.sim.span_ns
    }

    /// Closed-loop DTM results, when the simulation was built with
    /// `ThermalSpec::InLoop`.
    pub fn dtm(&self) -> Option<&crate::dtm::DtmReport> {
        self.sim.dtm.as_ref()
    }

    /// All tenants' latency breakdowns pooled into one aggregate (empty
    /// unless the run was traced with breakdowns enabled).
    pub fn breakdown(&self) -> BreakdownStats {
        let mut pooled = BreakdownStats::new();
        for t in &self.tenants {
            pooled.merge(&t.breakdown);
        }
        pooled
    }

    /// Human-readable roll-up: one block per tenant, then the
    /// interference matrix when present.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "mix: {} tenants ({} placement) over {:.3} ms\n",
            self.tenants.len(),
            self.placement.name(),
            self.sim.span_ns as f64 / 1e6,
        );
        for t in &self.tenants {
            let h = &t.stats.overall.hist;
            let _ = writeln!(
                s,
                "  {:<12} {:>3} chiplets  {:>6} offered  {:>6} done  {:>4} dropped  \
                 p50 {:>8.1} µs  p99 {:>8.1} µs  slo {:.1} µs: {} viol ({:.2} %), \
                 goodput {:.0} req/s",
                t.name,
                t.chiplets,
                t.offered,
                t.stats.completed(),
                t.stats.dropped,
                h.quantile(0.5) as f64 / 1e3,
                h.quantile(0.99) as f64 / 1e3,
                t.slo_ns as f64 / 1e3,
                t.stats.violations(),
                t.stats.violation_frac() * 100.0,
                t.stats.goodput_rps(),
            );
            let _ = writeln!(
                s,
                "  {:<12} noi: {} flows, {:.2} MB, {:.2} M byte-hops",
                "",
                t.comm.flows,
                t.comm.bytes as f64 / 1e6,
                t.comm.byte_hops as f64 / 1e6,
            );
        }
        let pooled = self.breakdown();
        if !pooled.is_empty() {
            s.push_str(&pooled.table().render());
        }
        if let Some(matrix) = &self.interference {
            s.push_str("interference matrix (solo -> co-located):\n");
            for e in &matrix.entries {
                let _ = writeln!(
                    s,
                    "  {:<12} p99 {:>8.1} -> {:>8.1} µs ({:.2}x)   goodput {:>7.0} -> \
                     {:>7.0} req/s",
                    e.tenant,
                    e.solo_p99_ns as f64 / 1e3,
                    e.co_p99_ns as f64 / 1e3,
                    e.p99_slowdown(),
                    e.solo_goodput_rps,
                    e.co_goodput_rps,
                );
            }
        }
        if let Some(d) = self.dtm() {
            s.push_str(&d.summary());
        }
        s
    }

    /// Stable digest for determinism checks.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = format!("seed={};placement={}", self.seed, self.placement.name());
        for t in &self.tenants {
            let _ = write!(
                s,
                ";{}[offered={};chiplets={};{};comm={}b{}h]",
                t.name,
                t.offered,
                t.chiplets,
                t.stats.fingerprint(),
                t.comm.bytes,
                t.comm.byte_hops,
            );
        }
        let _ = write!(s, ";sim:{}", self.sim.fingerprint());
        s
    }
}

// ------------------------------------------------------------------- driver

/// Run a co-located mix (and its solo baselines when requested).
///
/// `make_sim` builds a fresh, identically-configured [`Simulation`] per
/// run — the co-located pass plus one pass per tenant when
/// [`WorkloadMix::interference`] is set.  Placement masks are computed
/// once from the mix and installed on every pass, so solo baselines
/// differ from the co-located run *only* in which tenants are present.
pub fn run_mix<F>(make_sim: F, mix: &WorkloadMix, seed: u64) -> anyhow::Result<MixReport>
where
    F: Fn() -> anyhow::Result<Simulation>,
{
    mix.validate()?;
    let mut sim = make_sim()?;
    let demands = mix.demands();
    let mut ledger = MemoryLedger::new(sim.hardware());
    let masks = compute_placements(
        mix.placement,
        sim.hardware(),
        sim.topology(),
        &demands,
        &mut ledger,
    )?;
    let chiplets_per: Vec<usize> =
        masks.iter().map(|m| m.iter().filter(|&&b| b).count()).collect();

    // ---- co-located pass: all tenants share the one simulation ----
    sim.set_tenant_masks(masks.clone());
    let external = sim.thermal_spec().is_in_loop();
    let mut source = MixSource::new(mix, seed)?;
    let mut sink = MixSink::new(mix, external);
    let mut report = sim.run_with_seeded(&mut source, &mut sink, seed)?;
    let (co_stats, co_breakdowns, windows) = sink.into_parts(&mut report);

    // ---- solo baselines (interference matrix) ----
    let interference = if mix.interference {
        let mut entries = Vec::with_capacity(mix.tenants.len());
        for (idx, tenant) in mix.tenants.iter().enumerate() {
            let mut solo_sim = make_sim()?;
            solo_sim.set_tenant_masks(masks.clone());
            let solo_external = solo_sim.thermal_spec().is_in_loop();
            let mut solo_source = MixSource::solo(mix, seed, idx)?;
            let mut solo_sink = MixSink::new(mix, solo_external);
            let mut solo_report =
                solo_sim.run_with_seeded(&mut solo_source, &mut solo_sink, seed)?;
            let (solo_stats, _, _) = solo_sink.into_parts(&mut solo_report);
            let solo = &solo_stats[idx];
            let co = &co_stats[idx];
            entries.push(InterferenceEntry {
                tenant: tenant.name.clone(),
                solo_completed: solo.completed(),
                solo_p50_ns: solo.overall.hist.quantile(0.5),
                solo_p99_ns: solo.overall.hist.quantile(0.99),
                solo_goodput_rps: solo.goodput_rps(),
                co_completed: co.completed(),
                co_p50_ns: co.overall.hist.quantile(0.5),
                co_p99_ns: co.overall.hist.quantile(0.99),
                co_goodput_rps: co.goodput_rps(),
            });
        }
        Some(InterferenceMatrix { entries })
    } else {
        None
    };

    let tenants = mix
        .tenants
        .iter()
        .zip(co_stats.into_iter().zip(co_breakdowns))
        .enumerate()
        .map(|(idx, (spec, (stats, breakdown)))| TenantOutcome {
            name: spec.name.clone(),
            offered: source.emitted_of(idx),
            chiplets: chiplets_per[idx],
            slo_ns: spec.slo_ns,
            stats,
            breakdown,
            comm: report.tenant_comm.get(idx).copied().unwrap_or_default(),
        })
        .collect();
    Ok(MixReport {
        seed,
        placement: mix.placement,
        tenants,
        windows,
        interference,
        sim: report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_validation_rejects_bad_shapes() {
        assert!(WorkloadMix::new(vec![]).validate().is_err());
        let t = |n: &str| TenantSpec::poisson(n, ModelKind::ResNet18, 500.0);
        let dup = WorkloadMix::new(vec![t("a"), t("a")]);
        assert!(dup.validate().unwrap_err().to_string().contains("duplicate"));
        let ok = WorkloadMix::new(vec![t("a"), t("b")]);
        assert!(ok.validate().is_ok());
        let swallowed = WorkloadMix::new(vec![t("a")]).horizon_ms(1.0).warmup_ms(2.0);
        assert!(swallowed.validate().is_err());
    }

    #[test]
    fn mix_source_merges_monotone_and_tags_tenants() {
        let mix = WorkloadMix::new(vec![
            TenantSpec::poisson("a", ModelKind::ResNet18, 500_000.0),
            TenantSpec::poisson("b", ModelKind::AlexNet, 500_000.0),
        ])
        .horizon_ms(1.0);
        let mut src = MixSource::new(&mix, 7).unwrap();
        let mut last = 0;
        let mut seen = [0u64; 2];
        let mut next_id = 0usize;
        while let Some(r) = src.next_request() {
            assert!(r.arrival_ns >= last, "merge must stay monotone");
            assert_eq!(r.id, next_id, "ids are renumbered globally");
            next_id += 1;
            last = r.arrival_ns;
            assert!(r.tenant < 2);
            seen[r.tenant] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "both lanes must emit: {seen:?}");
        assert_eq!(src.emitted_of(0), seen[0]);
        assert_eq!(src.emitted_of(1), seen[1]);
        assert!(src.exhausted());
    }

    #[test]
    fn solo_source_replays_the_same_lane_stream() {
        let mix = WorkloadMix::new(vec![
            TenantSpec::poisson("a", ModelKind::ResNet18, 300_000.0),
            TenantSpec::poisson("b", ModelKind::AlexNet, 700_000.0),
        ])
        .horizon_ms(1.0);
        let mut both = MixSource::new(&mix, 21).unwrap();
        let mut only_b: Vec<(TimeNs, ModelKind)> = Vec::new();
        while let Some(r) = both.next_request() {
            if r.tenant == 1 {
                only_b.push((r.arrival_ns, r.kind));
            }
        }
        let mut solo = MixSource::solo(&mix, 21, 1).unwrap();
        let mut replay: Vec<(TimeNs, ModelKind)> = Vec::new();
        while let Some(r) = solo.next_request() {
            assert_eq!(r.tenant, 1, "solo source keeps the tenant index");
            replay.push((r.arrival_ns, r.kind));
        }
        assert_eq!(only_b, replay, "solo baseline must see the identical stream");
        assert!(MixSource::solo(&mix, 21, 2).is_err());
    }

    #[test]
    fn tenant_seed_is_stable_and_index_sensitive() {
        assert_eq!(tenant_seed(1, 0), tenant_seed(1, 0));
        assert_ne!(tenant_seed(1, 0), tenant_seed(1, 1));
        assert_ne!(tenant_seed(1, 0), tenant_seed(2, 0));
    }
}
