//! SLO metrics for sustained serving: log-bucketed latency histograms,
//! tail quantiles, per-kind goodput, and violation counting.
//!
//! The histogram is HDR-style: exact below 32 ns, then 32 sub-buckets
//! per octave, which bounds the relative quantile error at ~3 % with a
//! fixed ~2k-slot footprint — independent of how many requests are
//! recorded, which is what lets the streaming engine track p99.9 over
//! hours of virtual time in constant memory.

use std::collections::BTreeMap;

use crate::workload::ModelKind;
use crate::TimeNs;

/// Sub-bucket resolution: 2^5 = 32 buckets per octave (~3 % rel. error).
const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS;
/// Slots: the linear region (values < 32) plus 32 per remaining octave.
const SLOTS: usize = ((64 - SUB_BITS as usize) << SUB_BITS as usize) + SUB as usize;

/// Index of the bucket containing `v`.
fn bucket_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS;
    (((shift as u64 + 1) << SUB_BITS) + ((v >> shift) - SUB)) as usize
}

/// Lower bound and width of bucket `idx` (inverse of [`bucket_of`]).
fn bucket_bounds(idx: usize) -> (u64, u64) {
    let idx = idx as u64;
    if idx < SUB {
        return (idx, 1);
    }
    let block = (idx >> SUB_BITS) - 1;
    let pos = idx & (SUB - 1);
    ((SUB + pos) << block, 1u64 << block)
}

/// Fixed-size log-bucketed latency histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: u64,
    max: u64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { counts: vec![0; SLOTS], total: 0, sum: 0.0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v_ns: u64) {
        self.counts[bucket_of(v_ns)] += 1;
        self.total += 1;
        self.sum += v_ns as f64;
        self.min = self.min.min(v_ns);
        self.max = self.max.max(v_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile estimate (bucket midpoint, clamped to the observed
    /// range).  `q` outside [0, 1] is clamped; empty histograms report 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let (lo, width) = bucket_bounds(idx);
                return (lo + width / 2).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Clear all recorded values (the windowed p99 tracker reuses one
    /// allocation across windows).
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0.0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

// ------------------------------------------------------------------ stats

/// Latency/SLO accounting for one model kind (or the overall stream).
#[derive(Debug, Clone, Default)]
pub struct KindServing {
    pub hist: LatencyHistogram,
    pub completed: u64,
    pub violations: u64,
}

impl KindServing {
    /// Requests that completed within the SLO.
    pub fn met_slo(&self) -> u64 {
        self.completed - self.violations
    }
}

/// Cumulative serving statistics over a sustained-traffic run, with
/// warm-up truncation: requests finishing inside the warm-up window are
/// counted separately and excluded from every latency/goodput figure.
#[derive(Debug, Clone)]
pub struct ServingStats {
    /// End-to-end latency SLO applied to every request.
    pub slo_ns: TimeNs,
    /// Requests finishing before this virtual time are not counted.
    pub warmup_ns: TimeNs,
    /// How many completions the warm-up truncated.
    pub warmup_skipped: u64,
    /// Requests that could never be mapped and were dropped.
    pub dropped: u64,
    pub overall: KindServing,
    per_kind: BTreeMap<&'static str, KindServing>,
    /// Finish-time span of counted requests (goodput denominator).
    first_ns: TimeNs,
    last_ns: TimeNs,
}

impl ServingStats {
    pub fn new(slo_ns: TimeNs, warmup_ns: TimeNs) -> ServingStats {
        ServingStats {
            slo_ns,
            warmup_ns,
            warmup_skipped: 0,
            dropped: 0,
            overall: KindServing::default(),
            per_kind: BTreeMap::new(),
            first_ns: TimeNs::MAX,
            last_ns: 0,
        }
    }

    /// Record a completed request.  Returns `false` when the completion
    /// fell inside the warm-up window and was truncated.
    pub fn record(&mut self, kind: ModelKind, latency_ns: u64, finished_ns: TimeNs) -> bool {
        if finished_ns < self.warmup_ns {
            self.warmup_skipped += 1;
            return false;
        }
        self.first_ns = self.first_ns.min(finished_ns);
        self.last_ns = self.last_ns.max(finished_ns);
        let violated = latency_ns > self.slo_ns;
        for slot in [&mut self.overall, self.per_kind.entry(kind.name()).or_default()] {
            slot.hist.record(latency_ns);
            slot.completed += 1;
            slot.violations += u64::from(violated);
        }
        true
    }

    pub fn per_kind(&self) -> &BTreeMap<&'static str, KindServing> {
        &self.per_kind
    }

    pub fn completed(&self) -> u64 {
        self.overall.completed
    }

    pub fn violations(&self) -> u64 {
        self.overall.violations
    }

    /// Fraction of counted requests that violated the SLO.
    pub fn violation_frac(&self) -> f64 {
        if self.overall.completed == 0 {
            0.0
        } else {
            self.overall.violations as f64 / self.overall.completed as f64
        }
    }

    /// Span of counted completions, ns.
    pub fn span_ns(&self) -> TimeNs {
        self.last_ns.saturating_sub(self.first_ns.min(self.last_ns))
    }

    /// Within-SLO completions per second of counted span (the serving
    /// headline: how much useful work the system actually sustains).
    pub fn goodput_rps(&self) -> f64 {
        let span = self.span_ns();
        if span == 0 {
            return 0.0;
        }
        self.overall.met_slo() as f64 / (span as f64 * 1e-9)
    }

    /// Within-SLO completions per second for one model kind.
    pub fn goodput_of(&self, kind: ModelKind) -> f64 {
        let span = self.span_ns();
        match (span, self.per_kind.get(kind.name())) {
            (0, _) | (_, None) => 0.0,
            (_, Some(k)) => k.met_slo() as f64 / (span as f64 * 1e-9),
        }
    }

    /// Fold another run's statistics into this one (fleet aggregation:
    /// per-replica stats merge into the global view).  Histograms add
    /// bucket-wise, so merged quantiles are exactly what one stream
    /// containing both runs' completions would report.
    pub fn merge(&mut self, other: &ServingStats) {
        self.warmup_skipped += other.warmup_skipped;
        self.dropped += other.dropped;
        self.overall.hist.merge(&other.overall.hist);
        self.overall.completed += other.overall.completed;
        self.overall.violations += other.overall.violations;
        for (name, k) in &other.per_kind {
            let slot = self.per_kind.entry(name).or_default();
            slot.hist.merge(&k.hist);
            slot.completed += k.completed;
            slot.violations += k.violations;
        }
        self.first_ns = self.first_ns.min(other.first_ns);
        self.last_ns = self.last_ns.max(other.last_ns);
    }

    /// Stable digest for determinism checks.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = format!(
            "done={};viol={};skip={};drop={};span={}",
            self.overall.completed,
            self.overall.violations,
            self.warmup_skipped,
            self.dropped,
            self.span_ns(),
        );
        for q in [0.5, 0.9, 0.99, 0.999] {
            let _ = write!(s, ";q{}={}", q, self.overall.hist.quantile(q));
        }
        for (name, k) in &self.per_kind {
            let _ = write!(s, ";{}={}v{}", name, k.completed, k.violations);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_a_partition() {
        // Every value maps into a bucket whose bounds contain it, and
        // bucket indices are monotone in the value.
        let mut prev_idx = 0usize;
        let mut first = true;
        for v in (0..4_096u64).chain((13..40).map(|k| 1u64 << k)) {
            let idx = bucket_of(v);
            let (lo, w) = bucket_bounds(idx);
            assert!(lo <= v && v < lo + w, "v={v} outside bucket [{lo}, {})", lo + w);
            assert!(first || idx >= prev_idx, "index not monotone at {v}");
            prev_idx = idx;
            first = false;
        }
        assert!(bucket_of(u64::MAX) < SLOTS);
    }

    #[test]
    fn quantiles_have_bounded_relative_error() {
        let mut h = LatencyHistogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, exact) in [(0.5, 50_000.0), (0.9, 90_000.0), (0.99, 99_000.0)] {
            let est = h.quantile(q) as f64;
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.04, "q{q}: {est} vs {exact} (rel {rel})");
        }
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 100_000);
        assert_eq!(h.count(), 100_000);
        assert!((h.mean() - 50_000.5).abs() < 1e-6);
    }

    #[test]
    fn merge_and_reset_roundtrip() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for v in [10, 100, 1_000] {
            a.record(v);
        }
        for v in [20, 200, 2_000, 20_000] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 7);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 20_000);
        a.reset();
        assert!(a.is_empty());
        assert_eq!(a.quantile(0.99), 0);
    }

    #[test]
    fn warmup_truncation_and_slo_counting() {
        let mut s = ServingStats::new(1_000, 10_000);
        assert!(!s.record(ModelKind::AlexNet, 500, 5_000)); // warm-up
        assert!(s.record(ModelKind::AlexNet, 500, 10_000));
        assert!(s.record(ModelKind::AlexNet, 2_000, 20_000)); // violation
        assert!(s.record(ModelKind::ResNet18, 900, 30_000));
        assert_eq!(s.warmup_skipped, 1);
        assert_eq!(s.completed(), 3);
        assert_eq!(s.violations(), 1);
        assert!((s.violation_frac() - 1.0 / 3.0).abs() < 1e-12);
        // Goodput: 2 within-SLO over the 20 µs counted span.
        assert_eq!(s.span_ns(), 20_000);
        assert!((s.goodput_rps() - 2.0 / 20e-6).abs() < 1e-6);
        assert!(s.goodput_of(ModelKind::ResNet18) > 0.0);
        assert_eq!(s.per_kind().len(), 2);
    }

    #[test]
    fn fingerprint_is_stable() {
        let mut a = ServingStats::new(1_000, 0);
        let mut b = ServingStats::new(1_000, 0);
        for s in [&mut a, &mut b] {
            s.record(ModelKind::AlexNet, 750, 1_000);
            s.record(ModelKind::ResNet50, 1_500, 2_000);
        }
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.record(ModelKind::ResNet50, 10, 3_000);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
