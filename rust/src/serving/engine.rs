//! The sustained-traffic engine: drives [`Simulation`]'s event loop in a
//! bounded-horizon streaming mode.
//!
//! Three properties distinguish it from a batch `run()`:
//!
//! * **open-loop arrivals** — requests are pulled lazily from an
//!   [`ArrivalSpec`]-built generator as virtual time advances, cut off at
//!   the horizon;
//! * **constant memory** — finished instance state is retired and its
//!   slot recycled, outcomes stream into fixed-size histograms instead of
//!   a `Vec`, and [`PowerTracker`] bins drain one window behind virtual
//!   time, so an hour-long simulated trace costs no more memory than a
//!   millisecond one;
//! * **steady-state detection** — the run can stop early once the
//!   windowed p99 converges, and [`LoadSweep`] bisects over arrival rate
//!   for the saturation knee (the highest rate still meeting the SLO).

use std::collections::VecDeque;

use crate::dtm::DtmReport;
use crate::power::PowerWindow;
use crate::sim::{ModelOutcome, PowerPort, RequestSource, SimReport, Simulation, StreamSink};
use crate::serving::arrivals::{ArrivalProcess, ArrivalSpec};
use crate::serving::slo::{LatencyHistogram, ServingStats};
use crate::trace::BreakdownStats;
use crate::workload::{ModelKind, ModelRequest};
use crate::TimeNs;

// ------------------------------------------------------------------- spec

/// Convergence criterion for early stop: the windowed p99 must stay
/// within `rel_tol` across `windows` consecutive full windows.
#[derive(Debug, Clone, Copy)]
pub struct SteadyState {
    /// Consecutive windows that must agree.
    pub windows: usize,
    /// Max relative spread (max-min)/max of their p99s.
    pub rel_tol: f64,
    /// Windows with fewer completions than this reset the streak (too
    /// sparse for a meaningful p99).
    pub min_per_window: u64,
}

impl Default for SteadyState {
    fn default() -> Self {
        SteadyState { windows: 4, rel_tol: 0.10, min_per_window: 16 }
    }
}

/// Full description of a sustained-traffic experiment.  Attach one via
/// `Simulation::builder().traffic(spec)` and run with
/// [`Simulation::run_traffic`], or pass it explicitly to
/// [`Simulation::run_traffic_with`].
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    pub arrivals: ArrivalSpec,
    /// Arrivals stop at this virtual time; in-flight work then drains.
    pub horizon_ns: TimeNs,
    /// Completions before this virtual time are excluded from stats.
    pub warmup_ns: TimeNs,
    /// Stats / power-drain window width.
    pub window_ns: TimeNs,
    /// End-to-end (arrival -> finish) latency SLO per request.
    pub slo_ns: TimeNs,
    /// Early-stop criterion; `None` always runs the full horizon.
    pub steady: Option<SteadyState>,
    /// Bounded ring of trailing per-window summaries kept for the report.
    pub keep_windows: usize,
}

impl TrafficSpec {
    pub fn new(arrivals: ArrivalSpec) -> TrafficSpec {
        TrafficSpec {
            arrivals,
            horizon_ns: 50_000_000, // 50 ms
            warmup_ns: 4_000_000,   // 4 ms
            window_ns: 2_000_000,   // 2 ms
            slo_ns: 1_000_000,      // 1 ms end-to-end
            steady: Some(SteadyState::default()),
            keep_windows: 32,
        }
    }

    /// Poisson arrivals over the 4-CNN mix at `rate_rps`.
    pub fn poisson(rate_rps: f64) -> TrafficSpec {
        TrafficSpec::new(ArrivalSpec::poisson(rate_rps))
    }

    pub fn arrivals(mut self, arrivals: ArrivalSpec) -> TrafficSpec {
        self.arrivals = arrivals;
        self
    }

    pub fn horizon_ms(mut self, ms: f64) -> TrafficSpec {
        self.horizon_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn warmup_ms(mut self, ms: f64) -> TrafficSpec {
        self.warmup_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn window_ms(mut self, ms: f64) -> TrafficSpec {
        self.window_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn slo_ms(mut self, ms: f64) -> TrafficSpec {
        self.slo_ns = (ms * 1e6) as TimeNs;
        self
    }

    pub fn slo_us(mut self, us: f64) -> TrafficSpec {
        self.slo_ns = (us * 1e3) as TimeNs;
        self
    }

    pub fn steady(mut self, steady: Option<SteadyState>) -> TrafficSpec {
        self.steady = steady;
        self
    }

    pub fn keep_windows(mut self, n: usize) -> TrafficSpec {
        self.keep_windows = n.max(1);
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.window_ns > 0, "traffic window_ns must be > 0");
        anyhow::ensure!(self.slo_ns > 0, "traffic slo_ns must be > 0");
        anyhow::ensure!(
            self.horizon_ns >= self.window_ns,
            "traffic horizon ({} ns) shorter than one window ({} ns)",
            self.horizon_ns,
            self.window_ns
        );
        anyhow::ensure!(
            self.warmup_ns < self.horizon_ns,
            "warm-up ({} ns) swallows the whole horizon ({} ns)",
            self.warmup_ns,
            self.horizon_ns
        );
        Ok(())
    }
}

// ----------------------------------------------------------------- source

/// [`RequestSource`] over a lazy arrival process, cut off at a horizon.
pub struct StreamingSource {
    generator: Box<dyn ArrivalProcess>,
    horizon_ns: TimeNs,
    peeked: Option<ModelRequest>,
    emitted: u64,
    exhausted: bool,
}

impl StreamingSource {
    pub fn new(generator: Box<dyn ArrivalProcess>, horizon_ns: TimeNs) -> StreamingSource {
        StreamingSource { generator, horizon_ns, peeked: None, emitted: 0, exhausted: false }
    }

    fn fill(&mut self) {
        if self.peeked.is_some() || self.exhausted {
            return;
        }
        match self.generator.next_request() {
            Some(r) if r.arrival_ns <= self.horizon_ns => self.peeked = Some(r),
            _ => self.exhausted = true,
        }
    }

    /// Requests handed to the simulation so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Whether the generator ran past the horizon (or ran dry).
    pub fn exhausted(&self) -> bool {
        self.exhausted && self.peeked.is_none()
    }
}

impl RequestSource for StreamingSource {
    fn peek_arrival_ns(&mut self) -> Option<TimeNs> {
        self.fill();
        self.peeked.as_ref().map(|r| r.arrival_ns)
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        self.fill();
        let r = self.peeked.take();
        if r.is_some() {
            self.emitted += 1;
        }
        r
    }
}

// ------------------------------------------------------------------- sink

/// Aggregate of one finalized stats window.  The power figures cover the
/// window drained at the boundary, which lags the latency stats by one
/// window (stragglers may still book energy just behind virtual time).
#[derive(Debug, Clone)]
pub struct WindowSummary {
    /// Virtual time at which the window closed.
    pub end_ns: TimeNs,
    /// Post-warm-up completions inside the window.
    pub completed: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    /// Mean total system power over the drained window, W.
    pub mean_power_w: f64,
    /// Dynamic energy drained with the window, pJ.
    pub dynamic_pj: f64,
}

/// Why the traffic run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Windowed p99 converged per the [`SteadyState`] criterion.
    SteadyState,
    /// The arrival horizon passed and all in-flight work drained.
    Drained,
    /// Something else cut the run short (e.g. `max_sim_time_ns`).
    Truncated,
}

/// Shared window accounting for streaming sinks ([`TrafficSink`] here
/// and the multi-tenant `MixSink`): latencies of the open stats window,
/// the power drained one window behind virtual time, and the bounded
/// ring of [`WindowSummary`]s.
pub(crate) struct WindowRoller {
    window_ns: TimeNs,
    keep_windows: usize,
    /// When the simulation runs closed-loop DTM, its controller owns the
    /// drain clock and forwards every drained window here; the roller
    /// then must not drain on its own (two cursors would split windows).
    external_power: bool,
    window_hist: LatencyHistogram,
    window_completed: u64,
    window_end: TimeNs,
    windows: VecDeque<WindowSummary>,
    fed_dynamic_pj: f64,
    fed_span_ns: TimeNs,
    fed_baseline_mw: f64,
}

impl WindowRoller {
    pub(crate) fn new(
        window_ns: TimeNs,
        keep_windows: usize,
        external_power: bool,
    ) -> WindowRoller {
        WindowRoller {
            window_ns,
            keep_windows: keep_windows.max(1),
            external_power,
            window_hist: LatencyHistogram::new(),
            window_completed: 0,
            window_end: window_ns,
            windows: VecDeque::new(),
            fed_dynamic_pj: 0.0,
            fed_span_ns: 0,
            fed_baseline_mw: 0.0,
        }
    }

    /// Record one counted (post-warm-up) completion into the open window.
    pub(crate) fn record(&mut self, latency_ns: u64) {
        self.window_hist.record(latency_ns);
        self.window_completed += 1;
    }

    /// Whether virtual time has passed the open window's boundary.
    pub(crate) fn due(&self, now: TimeNs) -> bool {
        now >= self.window_end
    }

    /// Summarize the current stats window and append it to the bounded
    /// ring (shared by the periodic roll and the final partial window).
    fn push_summary(&mut self, end_ns: TimeNs, mean_power_w: f64, dynamic_pj: f64) {
        self.windows.push_back(WindowSummary {
            end_ns,
            completed: self.window_completed,
            p50_ns: self.window_hist.quantile(0.5),
            p99_ns: self.window_hist.quantile(0.99),
            mean_power_w,
            dynamic_pj,
        });
        if self.windows.len() > self.keep_windows {
            self.windows.pop_front();
        }
    }

    /// Mean power / energy of the externally fed windows accumulated
    /// since the last roll, then reset.  Lags the DTM drain cadence by
    /// up to one control window (like the self-drained path lags by one
    /// stats window).
    fn take_fed_power(&mut self) -> (f64, f64) {
        let dynamic_pj = self.fed_dynamic_pj;
        let mean_w = if self.fed_span_ns > 0 {
            (dynamic_pj / self.fed_span_ns as f64 + self.fed_baseline_mw) * 1e-3
        } else {
            0.0
        };
        self.fed_dynamic_pj = 0.0;
        self.fed_span_ns = 0;
        (mean_w, dynamic_pj)
    }

    /// Close the open window and start the next one.  Returns the closed
    /// window's `(completions, p99)` for steady-state detection.
    pub(crate) fn roll(&mut self, power: &mut PowerPort<'_>) -> (u64, u64) {
        if self.external_power {
            let (mean_w, dynamic_pj) = self.take_fed_power();
            self.push_summary(self.window_end, mean_w, dynamic_pj);
        } else {
            // Drain one window behind virtual time: in-flight network
            // events can still book energy just before the boundary, and
            // PowerTracker folds such stragglers into already-drained
            // totals anyway.
            let drained = power.drain_window(self.window_end.saturating_sub(self.window_ns));
            self.push_summary(self.window_end, drained.mean_power_w(), drained.dynamic_pj());
        }
        let closed = self.windows.back().expect("just pushed");
        let result = (closed.completed, closed.p99_ns);
        self.window_hist.reset();
        self.window_completed = 0;
        self.window_end += self.window_ns;
        result
    }

    /// A DTM-drained window arrived (external-power mode).
    pub(crate) fn on_power_window(&mut self, window: &PowerWindow) {
        self.fed_dynamic_pj += window.dynamic_pj();
        self.fed_span_ns += window.span_ns();
        self.fed_baseline_mw = window.baseline_mw.iter().sum();
    }

    /// Finalize after the event loop returned: fold the partial last
    /// window in (using whatever power is still live in the report) and
    /// hand the ring back.
    pub(crate) fn finish(mut self, sim: &mut SimReport) -> Vec<WindowSummary> {
        if self.window_completed > 0 {
            if self.external_power {
                let (mean_w, dynamic_pj) = self.take_fed_power();
                self.push_summary(sim.span_ns, mean_w, dynamic_pj);
            } else {
                let end = self.window_end.min(sim.span_ns + self.window_ns);
                let drained = sim.power.drain_window(end.saturating_sub(self.window_ns));
                self.push_summary(sim.span_ns, drained.mean_power_w(), drained.dynamic_pj());
            }
        }
        self.windows.into_iter().collect()
    }
}

struct TrafficSink<'a> {
    spec: &'a TrafficSpec,
    stats: ServingStats,
    roller: WindowRoller,
    recent_p99: VecDeque<u64>,
    converged: bool,
    breakdown: BreakdownStats,
}

impl<'a> TrafficSink<'a> {
    fn new(spec: &'a TrafficSpec, external_power: bool) -> TrafficSink<'a> {
        TrafficSink {
            spec,
            stats: ServingStats::new(spec.slo_ns, spec.warmup_ns),
            roller: WindowRoller::new(spec.window_ns, spec.keep_windows, external_power),
            recent_p99: VecDeque::new(),
            converged: false,
            breakdown: BreakdownStats::new(),
        }
    }

    /// Steady-state detection over the just-closed window.
    fn note_window(&mut self, completed: u64, p99: u64) {
        if let Some(ss) = &self.spec.steady {
            if completed >= ss.min_per_window {
                self.recent_p99.push_back(p99);
                if self.recent_p99.len() > ss.windows {
                    self.recent_p99.pop_front();
                }
                if self.recent_p99.len() == ss.windows {
                    let hi = *self.recent_p99.iter().max().unwrap();
                    let lo = *self.recent_p99.iter().min().unwrap();
                    if hi > 0 && (hi - lo) as f64 / hi as f64 <= ss.rel_tol {
                        self.converged = true;
                    }
                }
            } else {
                // A sparse window breaks the streak.
                self.recent_p99.clear();
            }
        }
    }

    /// Finalize after the event loop returned.
    fn into_report(
        self,
        mut sim: SimReport,
        offered: u64,
        exhausted: bool,
        seed: u64,
    ) -> TrafficReport {
        let windows = self.roller.finish(&mut sim);
        let stop = if self.converged {
            StopReason::SteadyState
        } else if exhausted {
            StopReason::Drained
        } else {
            StopReason::Truncated
        };
        TrafficReport {
            seed,
            offered,
            stats: self.stats,
            windows,
            stop,
            breakdown: self.breakdown,
            sim,
        }
    }
}

impl StreamSink for TrafficSink<'_> {
    fn on_outcome(&mut self, outcome: &ModelOutcome, _now: TimeNs) -> bool {
        let latency = outcome.finished_ns.saturating_sub(outcome.arrival_ns);
        if self.stats.record(outcome.kind, latency, outcome.finished_ns) {
            self.roller.record(latency);
            if let Some(bd) = &outcome.breakdown {
                self.breakdown.record(bd);
            }
        }
        // Early stop is driven entirely by on_advance (convergence is
        // only ever detected at a window boundary).
        true
    }

    fn on_advance(&mut self, now: TimeNs, power: &mut PowerPort<'_>) -> bool {
        while self.roller.due(now) {
            let (completed, p99) = self.roller.roll(power);
            self.note_window(completed, p99);
            if self.converged {
                return false;
            }
        }
        true
    }

    fn on_power_window(&mut self, window: &PowerWindow) {
        self.roller.on_power_window(window);
    }

    fn on_dropped(&mut self, _id: usize, _kind: ModelKind, _tenant: usize, _now: TimeNs) {
        self.stats.dropped += 1;
    }

    fn retain_state(&self) -> bool {
        false
    }
}

// ----------------------------------------------------------------- report

/// Result of a sustained-traffic run.
#[derive(Debug)]
pub struct TrafficReport {
    /// Workload seed the arrival stream was built from.
    pub seed: u64,
    /// Requests injected before the horizon.
    pub offered: u64,
    /// Cumulative post-warm-up serving statistics.
    pub stats: ServingStats,
    /// Trailing per-window summaries (bounded by `spec.keep_windows`).
    pub windows: Vec<WindowSummary>,
    pub stop: StopReason,
    /// Per-component latency breakdown over post-warm-up completions.
    /// Empty unless a flight recorder with breakdowns enabled was
    /// installed; excluded from [`fingerprint`](Self::fingerprint) so
    /// traced and untraced runs digest identically.
    pub breakdown: BreakdownStats,
    /// Tail simulation state: span, residual power bins, energy totals.
    /// Per-model outcomes are *not* retained in streaming mode.
    pub sim: SimReport,
}

impl TrafficReport {
    pub fn span_ns(&self) -> TimeNs {
        self.sim.span_ns
    }

    /// Closed-loop DTM results, when the simulation was built with
    /// `ThermalSpec::InLoop`.
    pub fn dtm(&self) -> Option<&DtmReport> {
        self.sim.dtm.as_ref()
    }

    /// Mean offered arrival rate actually seen, req/s.
    pub fn offered_rps(&self) -> f64 {
        if self.sim.span_ns == 0 {
            return 0.0;
        }
        self.offered as f64 / (self.sim.span_ns as f64 * 1e-9)
    }

    /// Human-readable roll-up.
    pub fn summary(&self) -> String {
        use std::fmt::Write;
        let st = &self.stats;
        let h = &st.overall.hist;
        let stop = match self.stop {
            StopReason::SteadyState => "steady state",
            StopReason::Drained => "horizon drained",
            StopReason::Truncated => "truncated",
        };
        let mut s = format!(
            "traffic: {} offered ({:.0} req/s), {} completed, {} dropped, {} in warm-up \
             over {:.3} ms  [stop: {stop}]\n",
            self.offered,
            self.offered_rps(),
            st.completed(),
            st.dropped,
            st.warmup_skipped,
            self.sim.span_ns as f64 / 1e6,
        );
        let _ = writeln!(
            s,
            "latency (µs): p50 {:.1}  p90 {:.1}  p95 {:.1}  p99 {:.1}  p99.9 {:.1}  max {:.1}",
            h.quantile(0.5) as f64 / 1e3,
            h.quantile(0.9) as f64 / 1e3,
            h.quantile(0.95) as f64 / 1e3,
            h.quantile(0.99) as f64 / 1e3,
            h.quantile(0.999) as f64 / 1e3,
            h.max() as f64 / 1e3,
        );
        let _ = writeln!(
            s,
            "slo {:.1} µs: {} violations ({:.2} %), goodput {:.0} req/s",
            st.slo_ns as f64 / 1e3,
            st.violations(),
            st.violation_frac() * 100.0,
            st.goodput_rps(),
        );
        for (kind, k) in st.per_kind() {
            let _ = writeln!(
                s,
                "  {kind:<10} x{:<6} p99 {:>9.1} µs  {:>5} violations",
                k.completed,
                k.hist.quantile(0.99) as f64 / 1e3,
                k.violations,
            );
        }
        if !self.windows.is_empty() {
            let tail: Vec<String> = self
                .windows
                .iter()
                .rev()
                .take(6)
                .rev()
                .map(|w| {
                    format!(
                        "[{:.1} ms: {} done, p99 {:.0} µs, {:.2} W]",
                        w.end_ns as f64 / 1e6,
                        w.completed,
                        w.p99_ns as f64 / 1e3,
                        w.mean_power_w,
                    )
                })
                .collect();
            let _ = writeln!(s, "windows (µs power trace, trailing): {}", tail.join(" "));
        }
        if !self.breakdown.is_empty() {
            s.push_str(&self.breakdown.table().render());
        }
        if let Some(d) = self.dtm() {
            s.push_str(&d.summary());
        }
        s
    }

    /// Stable digest for determinism checks (includes the tail sim
    /// fingerprint, so power/energy differences are caught too).
    pub fn fingerprint(&self) -> String {
        format!(
            "offered={};stop={:?};{};sim:{}",
            self.offered,
            self.stop,
            self.stats.fingerprint(),
            self.sim.fingerprint(),
        )
    }
}

/// Drive `sim` with the sustained-traffic spec.  Entry point behind
/// [`Simulation::run_traffic`] / [`Simulation::run_traffic_with`].
pub fn run_traffic(
    sim: &mut Simulation,
    spec: &TrafficSpec,
    seed: u64,
) -> anyhow::Result<TrafficReport> {
    spec.validate()?;
    let generator = spec.arrivals.build(seed)?;
    let mut source = StreamingSource::new(generator, spec.horizon_ns);
    let mut sink = TrafficSink::new(spec, sim.thermal_spec().is_in_loop());
    // The traffic seed doubles as the run seed so in-loop DTM sensor
    // noise gets a fresh realization per run (not one shared stream).
    let report = sim.run_with_seeded(&mut source, &mut sink, seed)?;
    let exhausted = source.exhausted();
    let offered = source.emitted();
    Ok(sink.into_report(report, offered, exhausted, seed))
}

// ------------------------------------------------------------- load sweep

/// One probe of a load sweep.
#[derive(Debug, Clone)]
pub struct SweepProbe {
    pub rate_rps: f64,
    pub p99_ns: u64,
    pub goodput_rps: f64,
    pub violation_frac: f64,
    pub meets_slo: bool,
}

/// Result of a saturation-knee search.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Every probe evaluated, in evaluation order.
    pub probes: Vec<SweepProbe>,
    /// Highest probed rate that met the SLO (0 when even `lo_rps` fails).
    pub knee_rps: f64,
}

/// Bisects over arrival rate for the saturation knee: the highest rate
/// whose post-warm-up p99 stays within the SLO (and whose violation
/// fraction stays under `max_violation_frac`).  Each probe is an
/// independent, fully-seeded traffic run, so the search is deterministic.
#[derive(Debug, Clone)]
pub struct LoadSweep {
    /// Template spec; its arrival shape is rescaled per probe.
    pub spec: TrafficSpec,
    pub lo_rps: f64,
    pub hi_rps: f64,
    /// Bisection steps after probing both endpoints.
    pub iters: usize,
    pub max_violation_frac: f64,
}

impl LoadSweep {
    pub fn new(spec: TrafficSpec, lo_rps: f64, hi_rps: f64) -> LoadSweep {
        LoadSweep { spec, lo_rps, hi_rps, iters: 5, max_violation_frac: 0.01 }
    }

    pub fn iters(mut self, n: usize) -> LoadSweep {
        self.iters = n;
        self
    }

    pub fn max_violation_frac(mut self, f: f64) -> LoadSweep {
        self.max_violation_frac = f;
        self
    }

    /// Run the search against single boards.  `make_sim` builds a fresh
    /// simulation per probe (each probe must start from cold state).
    pub fn run<F>(&self, mut make_sim: F, seed: u64) -> anyhow::Result<SweepResult>
    where
        F: FnMut() -> anyhow::Result<Simulation>,
    {
        self.run_with_probe(|spec| {
            let report = make_sim()?.run_traffic_with(spec, seed)?;
            Ok(report.stats)
        })
    }

    /// Run the search with a pluggable probe: `probe` receives the
    /// rate-rescaled spec and returns the post-warm-up serving stats of
    /// whatever system it drove — a single board ([`run`](Self::run)
    /// wires it to `run_traffic_with`) or a whole fleet (`chipsim fleet
    /// --sweep knee` builds a [`crate::fleet::Fleet`] per probe).  The
    /// bisection itself is system-agnostic.
    pub fn run_with_probe<P>(&self, mut probe: P) -> anyhow::Result<SweepResult>
    where
        P: FnMut(&TrafficSpec) -> anyhow::Result<ServingStats>,
    {
        anyhow::ensure!(
            self.lo_rps > 0.0 && self.lo_rps < self.hi_rps,
            "load sweep needs 0 < lo ({}) < hi ({})",
            self.lo_rps,
            self.hi_rps
        );
        let mut probes = Vec::new();
        let mut eval = |rate: f64, probes: &mut Vec<SweepProbe>| -> anyhow::Result<bool> {
            let spec =
                TrafficSpec { arrivals: self.spec.arrivals.with_rate(rate)?, ..self.spec.clone() };
            let stats = probe(&spec)?;
            let p99 = stats.overall.hist.quantile(0.99);
            let vf = stats.violation_frac();
            let meets =
                stats.completed() > 0 && p99 <= spec.slo_ns && vf <= self.max_violation_frac;
            probes.push(SweepProbe {
                rate_rps: rate,
                p99_ns: p99,
                goodput_rps: stats.goodput_rps(),
                violation_frac: vf,
                meets_slo: meets,
            });
            Ok(meets)
        };
        let lo_ok = eval(self.lo_rps, &mut probes)?;
        let hi_ok = eval(self.hi_rps, &mut probes)?;
        if !lo_ok {
            // Nothing in range is sustainable.
            return Ok(SweepResult { probes, knee_rps: 0.0 });
        }
        if hi_ok {
            // The knee lies beyond the sweep range.
            return Ok(SweepResult { probes, knee_rps: self.hi_rps });
        }
        let (mut lo, mut hi) = (self.lo_rps, self.hi_rps);
        for _ in 0..self.iters {
            let mid = 0.5 * (lo + hi);
            if eval(mid, &mut probes)? {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Ok(SweepResult { probes, knee_rps: lo })
    }
}
