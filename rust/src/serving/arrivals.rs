//! Open-loop arrival processes for sustained-traffic simulation.
//!
//! A serving workload is not a fixed batch: requests keep arriving
//! whether or not the system has finished the previous ones (open-loop,
//! the regime where queueing delay and tail latency live).  Every
//! generator here is **lazy** — it emits one [`ModelRequest`] at a time
//! as the engine's virtual clock reaches it, so an hour-long simulated
//! trace never materializes as a `Vec` — and **deterministic per seed**:
//! the same `(spec, seed)` pair reproduces the exact same stream,
//! byte for byte.
//!
//! Four processes cover the usual serving studies:
//!
//! * [`PoissonArrivals`] — memoryless baseline at a constant rate;
//! * [`OnOffArrivals`] — a two-state Markov-modulated Poisson process
//!   (bursts at one rate, lulls at another, exponential state holding
//!   times) for bursty traffic;
//! * [`DiurnalArrivals`] — a sinusoidal rate curve sampled by thinning,
//!   the classic day/night load shape compressed to simulation scale;
//! * [`TraceArrivals`] — replay of a recorded trace (JSON or in-memory).

use std::sync::Arc;

use crate::util::json;
use crate::util::rng::Rng;
use crate::workload::{ModelKind, ModelRequest, ALL_CNNS};
use crate::TimeNs;

/// A lazy, seeded stream of model requests with non-decreasing arrival
/// times.  `None` means the process is exhausted (only trace replay ever
/// ends; the synthetic processes are infinite and are cut off by the
/// engine's horizon).  `Send` so the fleet dispatcher can own the global
/// stream while replicas advance on worker threads.
pub trait ArrivalProcess: Send {
    fn name(&self) -> &'static str;
    fn next_request(&mut self) -> Option<ModelRequest>;
}

/// Draw an exponential sample with the given mean (inverse CDF).
/// `1 - f64()` lies in (0, 1], so the logarithm is always finite.
pub fn sample_exp_ns(rng: &mut Rng, mean_ns: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_ns
}

/// Round a nanosecond gap to the integer clock, never below 1 ns (the
/// stream must make progress).
fn gap_ns(dt: f64) -> TimeNs {
    (dt.round() as TimeNs).max(1)
}

/// Uniform model-kind mix shared by the synthetic generators.
#[derive(Debug, Clone)]
struct KindMix {
    kinds: Vec<ModelKind>,
}

impl KindMix {
    fn choose(&self, rng: &mut Rng) -> ModelKind {
        *rng.choice(&self.kinds)
    }
}

// ---------------------------------------------------------------- poisson

/// Constant-rate memoryless arrivals.
pub struct PoissonArrivals {
    mix: KindMix,
    mean_gap_ns: f64,
    inferences: u32,
    rng: Rng,
    t_ns: TimeNs,
    next_id: usize,
}

impl PoissonArrivals {
    pub fn new(rate_rps: f64, kinds: &[ModelKind], inferences: u32, seed: u64) -> PoissonArrivals {
        PoissonArrivals {
            mix: KindMix { kinds: kinds.to_vec() },
            mean_gap_ns: 1e9 / rate_rps,
            inferences,
            rng: Rng::new(seed),
            t_ns: 0,
            next_id: 0,
        }
    }
}

impl ArrivalProcess for PoissonArrivals {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        self.t_ns += gap_ns(sample_exp_ns(&mut self.rng, self.mean_gap_ns));
        let id = self.next_id;
        self.next_id += 1;
        Some(ModelRequest {
            id,
            kind: self.mix.choose(&mut self.rng),
            arrival_ns: self.t_ns,
            inferences: self.inferences,
            tenant: 0,
        })
    }
}

// ----------------------------------------------------------- on-off MMPP

/// Two-state Markov-modulated Poisson process: arrivals at `rate_on`
/// during bursts and `rate_off` during lulls, with exponential state
/// holding times of the configured means.  `rate_off = 0` gives pure
/// on-off traffic (silence between bursts).
pub struct OnOffArrivals {
    mix: KindMix,
    rate_on_per_ns: f64,
    rate_off_per_ns: f64,
    mean_on_ns: f64,
    mean_off_ns: f64,
    inferences: u32,
    rng: Rng,
    t_ns: TimeNs,
    on: bool,
    state_end_ns: TimeNs,
    next_id: usize,
}

impl OnOffArrivals {
    pub fn new(
        rate_on_rps: f64,
        rate_off_rps: f64,
        mean_on_ns: f64,
        mean_off_ns: f64,
        kinds: &[ModelKind],
        inferences: u32,
        seed: u64,
    ) -> OnOffArrivals {
        let mut rng = Rng::new(seed);
        let first_burst = gap_ns(sample_exp_ns(&mut rng, mean_on_ns));
        OnOffArrivals {
            mix: KindMix { kinds: kinds.to_vec() },
            rate_on_per_ns: rate_on_rps * 1e-9,
            rate_off_per_ns: rate_off_rps * 1e-9,
            mean_on_ns,
            mean_off_ns,
            inferences,
            rng,
            t_ns: 0,
            on: true,
            state_end_ns: first_burst,
            next_id: 0,
        }
    }

    fn toggle(&mut self) {
        self.t_ns = self.state_end_ns;
        self.on = !self.on;
        let mean = if self.on { self.mean_on_ns } else { self.mean_off_ns };
        self.state_end_ns = self.t_ns + gap_ns(sample_exp_ns(&mut self.rng, mean));
    }
}

impl ArrivalProcess for OnOffArrivals {
    fn name(&self) -> &'static str {
        "on-off"
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        loop {
            let rate = if self.on { self.rate_on_per_ns } else { self.rate_off_per_ns };
            if rate <= 0.0 {
                self.toggle();
                continue;
            }
            // Memorylessness makes re-sampling at a state boundary exact:
            // the residual of an exponential is the same exponential.
            let dt = gap_ns(sample_exp_ns(&mut self.rng, 1.0 / rate));
            if self.t_ns + dt > self.state_end_ns {
                self.toggle();
                continue;
            }
            self.t_ns += dt;
            let id = self.next_id;
            self.next_id += 1;
            return Some(ModelRequest {
                id,
                kind: self.mix.choose(&mut self.rng),
                arrival_ns: self.t_ns,
                inferences: self.inferences,
                tenant: 0,
            });
        }
    }
}

// ---------------------------------------------------------------- diurnal

/// Sinusoidal rate curve `base * (1 + amplitude * sin(2πt/period))`,
/// sampled exactly by thinning against the peak rate (candidate gaps are
/// drawn at the peak and accepted with probability `rate(t) / peak`).
pub struct DiurnalArrivals {
    mix: KindMix,
    base_per_ns: f64,
    amplitude: f64,
    period_ns: f64,
    inferences: u32,
    rng: Rng,
    t_ns: TimeNs,
    next_id: usize,
}

impl DiurnalArrivals {
    pub fn new(
        base_rps: f64,
        amplitude: f64,
        period_ns: TimeNs,
        kinds: &[ModelKind],
        inferences: u32,
        seed: u64,
    ) -> DiurnalArrivals {
        DiurnalArrivals {
            mix: KindMix { kinds: kinds.to_vec() },
            base_per_ns: base_rps * 1e-9,
            amplitude,
            period_ns: period_ns as f64,
            inferences,
            rng: Rng::new(seed),
            t_ns: 0,
            next_id: 0,
        }
    }

    fn rate_at(&self, t: TimeNs) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * (t as f64 / self.period_ns);
        self.base_per_ns * (1.0 + self.amplitude * phase.sin())
    }
}

impl ArrivalProcess for DiurnalArrivals {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        let peak = self.base_per_ns * (1.0 + self.amplitude);
        loop {
            self.t_ns += gap_ns(sample_exp_ns(&mut self.rng, 1.0 / peak));
            let accept = self.rate_at(self.t_ns) / peak;
            if self.rng.f64() < accept {
                let id = self.next_id;
                self.next_id += 1;
                return Some(ModelRequest {
                    id,
                    kind: self.mix.choose(&mut self.rng),
                    arrival_ns: self.t_ns,
                    inferences: self.inferences,
                    tenant: 0,
                });
            }
        }
    }
}

// ------------------------------------------------------------ trace replay

/// One entry of a recorded arrival trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub at_ns: TimeNs,
    pub kind: ModelKind,
    pub inferences: u32,
}

/// Replay of a recorded trace, sorted by arrival time at load.
pub struct TraceArrivals {
    events: Arc<Vec<TraceEvent>>,
    idx: usize,
}

impl TraceArrivals {
    /// `events` must be sorted by `at_ns` (both [`ArrivalSpec::trace`]
    /// and [`TraceArrivals::parse`] guarantee it).
    pub fn new(events: Arc<Vec<TraceEvent>>) -> TraceArrivals {
        debug_assert!(events.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        TraceArrivals { events, idx: 0 }
    }

    /// Parse a trace from JSON: either a top-level array or an object
    /// with an `"events"` array; each entry is
    /// `{"t_ns": <u64>, "model": "<name>", "inferences": <u32, opt>}`.
    /// Entries are sorted by time (the engine requires monotone arrivals).
    pub fn parse(v: &json::Value) -> anyhow::Result<Vec<TraceEvent>> {
        let arr = match v.opt("events") {
            Some(e) => e.as_arr()?,
            None => v.as_arr()?,
        };
        let mut events = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let name = e.get("model")?.as_str()?;
            let kind = ModelKind::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("trace entry {i}: unknown model '{name}'"))?;
            let inferences = match e.opt("inferences") {
                Some(n) => n.as_u64()? as u32,
                None => 1,
            };
            events.push(TraceEvent { at_ns: e.get("t_ns")?.as_u64()?, kind, inferences });
        }
        events.sort_by_key(|e| e.at_ns);
        Ok(events)
    }

    pub fn from_json_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<TraceArrivals> {
        let v = json::parse_file(path)?;
        Ok(TraceArrivals::new(Arc::new(TraceArrivals::parse(&v)?)))
    }
}

impl ArrivalProcess for TraceArrivals {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn next_request(&mut self) -> Option<ModelRequest> {
        let e = self.events.get(self.idx)?;
        let id = self.idx;
        self.idx += 1;
        Some(ModelRequest {
            id,
            kind: e.kind,
            arrival_ns: e.at_ns,
            inferences: e.inferences,
            tenant: 0,
        })
    }
}

// ------------------------------------------------------------------- spec

/// Declarative, cloneable description of an arrival process.  A spec plus
/// a seed fully determines the stream ([`ArrivalSpec::build`]), which is
/// what lets traffic scenarios live in the registry and load sweeps
/// re-run the same workload shape at different rates.
#[derive(Debug, Clone)]
pub enum ArrivalSpec {
    Poisson {
        rate_rps: f64,
        kinds: Vec<ModelKind>,
        inferences: u32,
    },
    OnOff {
        rate_on_rps: f64,
        rate_off_rps: f64,
        mean_on_ns: f64,
        mean_off_ns: f64,
        kinds: Vec<ModelKind>,
        inferences: u32,
    },
    Diurnal {
        base_rps: f64,
        amplitude: f64,
        period_ns: TimeNs,
        kinds: Vec<ModelKind>,
        inferences: u32,
    },
    Trace {
        events: Arc<Vec<TraceEvent>>,
    },
}

impl ArrivalSpec {
    /// Memoryless arrivals over the 4-CNN mix, one inference each.
    pub fn poisson(rate_rps: f64) -> ArrivalSpec {
        ArrivalSpec::Poisson { rate_rps, kinds: ALL_CNNS.to_vec(), inferences: 1 }
    }

    /// Bursty on-off MMPP over the 4-CNN mix.
    pub fn on_off(
        rate_on_rps: f64,
        rate_off_rps: f64,
        mean_on_ns: f64,
        mean_off_ns: f64,
    ) -> ArrivalSpec {
        ArrivalSpec::OnOff {
            rate_on_rps,
            rate_off_rps,
            mean_on_ns,
            mean_off_ns,
            kinds: ALL_CNNS.to_vec(),
            inferences: 1,
        }
    }

    /// Sinusoidal day/night curve over the 4-CNN mix.
    pub fn diurnal(base_rps: f64, amplitude: f64, period_ns: TimeNs) -> ArrivalSpec {
        ArrivalSpec::Diurnal {
            base_rps,
            amplitude,
            period_ns,
            kinds: ALL_CNNS.to_vec(),
            inferences: 1,
        }
    }

    pub fn trace(mut events: Vec<TraceEvent>) -> ArrivalSpec {
        // The engine requires monotone arrivals; accept caller traces in
        // any order (the JSON path sorts in parse()).
        events.sort_by_key(|e| e.at_ns);
        ArrivalSpec::Trace { events: Arc::new(events) }
    }

    pub fn trace_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<ArrivalSpec> {
        let v = json::parse_file(path)?;
        Ok(ArrivalSpec::Trace { events: Arc::new(TraceArrivals::parse(&v)?) })
    }

    /// Replace the model mix (no-op for trace replay, which carries its
    /// own kinds).
    pub fn kinds(mut self, mix: &[ModelKind]) -> ArrivalSpec {
        match &mut self {
            ArrivalSpec::Poisson { kinds, .. }
            | ArrivalSpec::OnOff { kinds, .. }
            | ArrivalSpec::Diurnal { kinds, .. } => *kinds = mix.to_vec(),
            ArrivalSpec::Trace { .. } => {}
        }
        self
    }

    /// Back-to-back inferences per request (no-op for trace replay).
    pub fn inferences(mut self, n: u32) -> ArrivalSpec {
        match &mut self {
            ArrivalSpec::Poisson { inferences, .. }
            | ArrivalSpec::OnOff { inferences, .. }
            | ArrivalSpec::Diurnal { inferences, .. } => *inferences = n,
            ArrivalSpec::Trace { .. } => {}
        }
        self
    }

    /// Distinct model kinds this spec can emit (in first-appearance
    /// order).  Placement policies size tenant partitions from the models
    /// behind a spec, so trace replay reports the kinds of its events.
    pub fn model_kinds(&self) -> Vec<ModelKind> {
        let dedup = |kinds: &[ModelKind]| {
            let mut out: Vec<ModelKind> = Vec::new();
            for &k in kinds {
                if !out.contains(&k) {
                    out.push(k);
                }
            }
            out
        };
        match self {
            ArrivalSpec::Poisson { kinds, .. }
            | ArrivalSpec::OnOff { kinds, .. }
            | ArrivalSpec::Diurnal { kinds, .. } => dedup(kinds),
            ArrivalSpec::Trace { events } => {
                let kinds: Vec<ModelKind> = events.iter().map(|e| e.kind).collect();
                dedup(&kinds)
            }
        }
    }

    /// Nominal mean request rate, req/s (duty-cycle weighted for on-off;
    /// `None` for trace replay).
    pub fn rate_rps(&self) -> Option<f64> {
        match self {
            ArrivalSpec::Poisson { rate_rps, .. } => Some(*rate_rps),
            ArrivalSpec::OnOff { rate_on_rps, rate_off_rps, mean_on_ns, mean_off_ns, .. } => {
                let cycle = mean_on_ns + mean_off_ns;
                Some((rate_on_rps * mean_on_ns + rate_off_rps * mean_off_ns) / cycle)
            }
            ArrivalSpec::Diurnal { base_rps, .. } => Some(*base_rps),
            ArrivalSpec::Trace { .. } => None,
        }
    }

    /// The same traffic *shape* rescaled to a new mean rate — the lever
    /// the load sweep bisects on.  Errors for trace replay.
    pub fn with_rate(&self, new_rps: f64) -> anyhow::Result<ArrivalSpec> {
        anyhow::ensure!(
            new_rps.is_finite() && new_rps > 0.0,
            "arrival rate must be positive and finite, got {new_rps}"
        );
        let mut spec = self.clone();
        match &mut spec {
            ArrivalSpec::Poisson { rate_rps, .. } => *rate_rps = new_rps,
            ArrivalSpec::OnOff { rate_on_rps, rate_off_rps, .. } => {
                let old = self.rate_rps().expect("on-off has a rate");
                anyhow::ensure!(old > 0.0, "on-off spec has zero mean rate; cannot rescale");
                let k = new_rps / old;
                *rate_on_rps *= k;
                *rate_off_rps *= k;
            }
            ArrivalSpec::Diurnal { base_rps, .. } => *base_rps = new_rps,
            ArrivalSpec::Trace { .. } => {
                anyhow::bail!("trace replay has a fixed timeline; cannot rescale its rate")
            }
        }
        Ok(spec)
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalSpec::Poisson { .. } => "poisson",
            ArrivalSpec::OnOff { .. } => "on-off",
            ArrivalSpec::Diurnal { .. } => "diurnal",
            ArrivalSpec::Trace { .. } => "trace",
        }
    }

    /// Instantiate the generator for a seed (validates parameters).
    pub fn build(&self, seed: u64) -> anyhow::Result<Box<dyn ArrivalProcess>> {
        let check_rate = |r: f64, what: &str| {
            anyhow::ensure!(r.is_finite() && r >= 0.0, "{what} must be >= 0 and finite, got {r}");
            Ok(())
        };
        let check_mix = |kinds: &[ModelKind]| {
            anyhow::ensure!(!kinds.is_empty(), "arrival spec has an empty model mix");
            Ok(())
        };
        Ok(match self {
            ArrivalSpec::Poisson { rate_rps, kinds, inferences } => {
                check_mix(kinds)?;
                anyhow::ensure!(
                    rate_rps.is_finite() && *rate_rps > 0.0,
                    "poisson rate must be > 0, got {rate_rps}"
                );
                Box::new(PoissonArrivals::new(*rate_rps, kinds, *inferences, seed))
            }
            ArrivalSpec::OnOff {
                rate_on_rps,
                rate_off_rps,
                mean_on_ns,
                mean_off_ns,
                kinds,
                inferences,
            } => {
                check_mix(kinds)?;
                check_rate(*rate_on_rps, "on-state rate")?;
                check_rate(*rate_off_rps, "off-state rate")?;
                anyhow::ensure!(
                    *rate_on_rps > 0.0 || *rate_off_rps > 0.0,
                    "on-off spec never produces arrivals (both rates are 0)"
                );
                anyhow::ensure!(
                    *mean_on_ns > 0.0 && *mean_off_ns > 0.0,
                    "on/off state means must be > 0"
                );
                Box::new(OnOffArrivals::new(
                    *rate_on_rps,
                    *rate_off_rps,
                    *mean_on_ns,
                    *mean_off_ns,
                    kinds,
                    *inferences,
                    seed,
                ))
            }
            ArrivalSpec::Diurnal { base_rps, amplitude, period_ns, kinds, inferences } => {
                check_mix(kinds)?;
                anyhow::ensure!(
                    base_rps.is_finite() && *base_rps > 0.0,
                    "diurnal base rate must be > 0, got {base_rps}"
                );
                anyhow::ensure!(
                    (0.0..=1.0).contains(amplitude),
                    "diurnal amplitude must be in [0, 1], got {amplitude} \
                     (above 1 the rate would go negative)"
                );
                anyhow::ensure!(*period_ns > 0, "diurnal period must be > 0");
                Box::new(DiurnalArrivals::new(
                    *base_rps,
                    *amplitude,
                    *period_ns,
                    kinds,
                    *inferences,
                    seed,
                ))
            }
            ArrivalSpec::Trace { events } => Box::new(TraceArrivals::new(events.clone())),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(spec: &ArrivalSpec, seed: u64, n: usize) -> Vec<ModelRequest> {
        let mut gen = spec.build(seed).unwrap();
        (0..n).map(|_| gen.next_request().unwrap()).collect()
    }

    #[test]
    fn poisson_empirical_rate_converges() {
        let rate = 1_000_000.0; // 1 req/µs keeps the test fast
        let reqs = drain(&ArrivalSpec::poisson(rate), 42, 50_000);
        let span_s = reqs.last().unwrap().arrival_ns as f64 * 1e-9;
        let empirical = reqs.len() as f64 / span_s;
        let rel = (empirical - rate).abs() / rate;
        assert!(rel < 0.05, "empirical rate {empirical} vs {rate} (rel err {rel})");
    }

    #[test]
    fn streams_are_identical_per_seed_and_differ_across_seeds() {
        for spec in [
            ArrivalSpec::poisson(500_000.0),
            ArrivalSpec::on_off(2_000_000.0, 100_000.0, 50_000.0, 50_000.0),
            ArrivalSpec::diurnal(500_000.0, 0.8, 1_000_000),
        ] {
            let a = drain(&spec, 7, 2_000);
            let b = drain(&spec, 7, 2_000);
            assert_eq!(a, b, "{} stream not reproducible", spec.name());
            let c = drain(&spec, 8, 2_000);
            assert_ne!(a, c, "{} stream ignores the seed", spec.name());
        }
    }

    #[test]
    fn arrivals_are_monotone_and_ids_sequential() {
        for spec in [
            ArrivalSpec::poisson(1_000_000.0),
            ArrivalSpec::on_off(2_000_000.0, 0.0, 100_000.0, 100_000.0),
            ArrivalSpec::diurnal(1_000_000.0, 1.0, 500_000),
        ] {
            let reqs = drain(&spec, 3, 5_000);
            for (i, w) in reqs.windows(2).enumerate() {
                assert!(w[0].arrival_ns <= w[1].arrival_ns, "{} not monotone", spec.name());
                assert_eq!(w[0].id + 1, w[1].id, "id gap at {i}");
            }
        }
    }

    #[test]
    fn exponential_sampler_hits_its_mean() {
        let mut rng = Rng::new(11);
        let mean = 12_345.0;
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| sample_exp_ns(&mut rng, mean)).sum();
        let rel = (sum / n as f64 - mean).abs() / mean;
        assert!(rel < 0.02, "exp sample mean off by {rel}");
    }

    #[test]
    fn on_off_burst_and_idle_durations_honor_their_means() {
        // Pure on-off traffic (silence between bursts): gaps far above the
        // in-burst scale mark state transitions, so burst/idle durations
        // are recoverable from the stream alone.
        let mean_on = 1_000_000.0; // 1 ms bursts
        let mean_off = 2_000_000.0; // 2 ms lulls
        let rate_on = 2_000_000.0; // in-burst gap 1/rate = 500 ns
        let spec = ArrivalSpec::on_off(rate_on, 0.0, mean_on, mean_off);
        let reqs = drain(&spec, 19, 120_000); // ~60 bursts of ~2k arrivals
        let idle_threshold = 200_000; // 200 µs >> 500 ns, << 2 ms
        let mut idle_gaps: Vec<f64> = Vec::new();
        let mut burst_spans: Vec<f64> = Vec::new();
        let mut burst_start = reqs[0].arrival_ns;
        let mut prev = reqs[0].arrival_ns;
        for r in &reqs[1..] {
            let gap = r.arrival_ns - prev;
            if gap > idle_threshold {
                idle_gaps.push(gap as f64);
                burst_spans.push((prev - burst_start) as f64);
                burst_start = r.arrival_ns;
            }
            prev = r.arrival_ns;
        }
        assert!(idle_gaps.len() > 20, "need several bursts, saw {}", idle_gaps.len());
        let mean_gap = idle_gaps.iter().sum::<f64>() / idle_gaps.len() as f64;
        let mean_burst = burst_spans.iter().sum::<f64>() / burst_spans.len() as f64;
        let rel_off = (mean_gap - mean_off).abs() / mean_off;
        let rel_on = (mean_burst - mean_on).abs() / mean_on;
        assert!(rel_off < 0.25, "idle mean {mean_gap} vs {mean_off} (rel {rel_off})");
        assert!(rel_on < 0.25, "burst mean {mean_burst} vs {mean_on} (rel {rel_on})");
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let period = 10_000_000; // 10 ms
        let spec = ArrivalSpec::diurnal(1_000_000.0, 0.9, period);
        let mut gen = spec.build(5).unwrap();
        let mut peak = 0usize;
        let mut trough = 0usize;
        // Peak quarter is centred on t = period/4, trough on 3*period/4.
        loop {
            let r = gen.next_request().unwrap();
            if r.arrival_ns > 10 * period {
                break;
            }
            let phase = (r.arrival_ns % period) as f64 / period as f64;
            if (0.125..0.375).contains(&phase) {
                peak += 1;
            } else if (0.625..0.875).contains(&phase) {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > trough as f64 * 3.0,
            "peak {peak} not clearly above trough {trough}"
        );
    }

    #[test]
    fn trace_replay_sorts_and_reports_kinds() {
        // Inline traces are sorted at construction, like JSON ones: an
        // out-of-order event must not truncate replay at the horizon.
        let spec = ArrivalSpec::trace(vec![
            TraceEvent { at_ns: 500, kind: ModelKind::ResNet18, inferences: 2 },
            TraceEvent { at_ns: 100, kind: ModelKind::AlexNet, inferences: 1 },
        ]);
        let mut gen = spec.build(0).unwrap();
        let a = gen.next_request().unwrap();
        assert_eq!(a.arrival_ns, 100);
        assert_eq!(a.kind, ModelKind::AlexNet);
        let b = gen.next_request().unwrap();
        assert_eq!(b.arrival_ns, 500);
        assert_eq!(b.inferences, 2);
        assert!(gen.next_request().is_none());
    }

    #[test]
    fn trace_json_parses_and_sorts() {
        let v = json::parse(
            r#"{"events": [
                {"t_ns": 900, "model": "alexnet"},
                {"t_ns": 100, "model": "resnet50", "inferences": 3}
            ]}"#,
        )
        .unwrap();
        let events = TraceArrivals::parse(&v).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].at_ns, 100);
        assert_eq!(events[0].kind, ModelKind::ResNet50);
        assert_eq!(events[0].inferences, 3);
        assert_eq!(events[1].inferences, 1);
    }

    #[test]
    fn with_rate_rescales_shapes() {
        let p = ArrivalSpec::poisson(1_000.0).with_rate(4_000.0).unwrap();
        assert_eq!(p.rate_rps(), Some(4_000.0));
        let b = ArrivalSpec::on_off(3_000.0, 1_000.0, 1e6, 1e6);
        let mean = b.rate_rps().unwrap();
        assert!((mean - 2_000.0).abs() < 1e-9);
        let b2 = b.with_rate(4_000.0).unwrap();
        assert!((b2.rate_rps().unwrap() - 4_000.0).abs() < 1e-9);
        assert!(ArrivalSpec::trace(vec![]).with_rate(10.0).is_err());
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(ArrivalSpec::poisson(0.0).build(1).is_err());
        assert!(ArrivalSpec::poisson(f64::NAN).build(1).is_err());
        assert!(ArrivalSpec::on_off(0.0, 0.0, 1e6, 1e6).build(1).is_err());
        assert!(ArrivalSpec::on_off(1e3, 0.0, 0.0, 1e6).build(1).is_err());
        assert!(ArrivalSpec::diurnal(1e3, 1.5, 1_000_000).build(1).is_err());
        assert!(ArrivalSpec::poisson(1e3).kinds(&[]).build(1).is_err());
    }
}
