//! Sustained-traffic serving simulation: open-loop arrivals, SLO
//! metrics, and a constant-memory streaming engine.
//!
//! The batch path (`Simulation::run`) answers "how long does this set of
//! models take?".  This subsystem answers the serving questions the
//! ROADMAP's north star actually asks: *what p99 latency and goodput
//! does this chiplet system sustain at 2,000 req/s?  Where is its
//! saturation knee?*
//!
//! Three parts, layered on the existing event loop through the
//! [`crate::sim::RequestSource`] / [`crate::sim::StreamSink`] seams:
//!
//! * [`arrivals`] — pluggable open-loop generators (Poisson, bursty
//!   on-off MMPP, diurnal rate curve, trace replay), each a lazy,
//!   per-seed-deterministic request stream;
//! * [`slo`] — log-bucketed latency histograms (p50/p90/p99/p99.9),
//!   per-kind goodput, SLO-violation counting, warm-up truncation;
//! * [`engine`] — the streaming driver: requests are pulled as virtual
//!   time advances, finished state is retired, and power bins drain in
//!   windows, so hour-long simulated traces run in constant memory; with
//!   steady-state early stop and [`engine::LoadSweep`] bisection for the
//!   saturation knee;
//! * [`mix`] — multi-tenant co-execution: a [`mix::WorkloadMix`] of N
//!   tenants (model mix + arrival process + SLO each) shares one
//!   simulation under a placement policy, with per-tenant stats and a
//!   solo-vs-co-located interference matrix.
//!
//! ```no_run
//! use chipsim::prelude::*;
//!
//! let report = Simulation::builder()
//!     .hardware(HardwareConfig::homogeneous_mesh(8, 8))
//!     .params(SimParams { pipelined: true, ..SimParams::default() })
//!     .traffic(TrafficSpec::poisson(2_000.0).horizon_ms(50.0).slo_ms(1.0))
//!     .build()
//!     .expect("valid configuration")
//!     .run_traffic(0xC0FFEE)
//!     .expect("traffic run");
//! println!("{}", report.summary());
//! ```
//!
//! Or from the CLI: `chipsim traffic --scenario traffic-poisson-mesh
//! --rate 2000 --seed 7`.

pub mod arrivals;
pub mod engine;
pub mod mix;
pub mod slo;

pub use arrivals::{
    ArrivalProcess, ArrivalSpec, DiurnalArrivals, OnOffArrivals, PoissonArrivals, TraceArrivals,
    TraceEvent,
};
pub use engine::{
    LoadSweep, SteadyState, StopReason, StreamingSource, SweepProbe, SweepResult, TrafficReport,
    TrafficSpec, WindowSummary,
};
pub use mix::{
    run_mix, InterferenceEntry, InterferenceMatrix, MixReport, MixSource, TenantOutcome,
    TenantSpec, WorkloadMix,
};
pub use slo::{KindServing, LatencyHistogram, ServingStats};
