//! The CHIPSIM co-simulation core (paper §III).
//!
//! [`GlobalManager`] orchestrates computation and communication simulation
//! under a coherent global timeline:
//!
//! * **model queue + arbitration** — requests stream in, the age-aware
//!   queue picks the next mappable model (out-of-order, anti-starvation);
//! * **mapping** — the nearest-neighbour mapper places each layer, the
//!   memory ledger tracks occupancy for future mapping decisions;
//! * **compute events** — each mapped layer segment is evaluated by the
//!   compute backend (batched per model at map time) and completion events
//!   are scheduled on the global queue;
//! * **communication** — all activation transfers of all active models
//!   share one network engine, advanced in lockstep with the event queue
//!   so contention between models emerges cycle-accurately;
//! * **power** — every operation books energy per chiplet at 1 µs bins.
//!
//! Pipelined mode implements the paper's §V-B2 semantics: a chiplet that
//! finished a layer and sent activations immediately starts the next
//! inference, bounded by a double-buffering credit per pipeline stage.

mod manager;
mod report;

pub use manager::GlobalManager;
pub use report::{KindStats, ModelOutcome, SimReport};
