//! The CHIPSIM co-simulation core (paper §III).
//!
//! [`Simulation`] orchestrates computation and communication simulation
//! under a coherent global timeline:
//!
//! * **model queue + arbitration** — requests stream in, the age-aware
//!   queue picks the next mappable model (out-of-order, anti-starvation);
//! * **mapping** — the injected [`crate::mapping::Mapper`] policy places
//!   each layer, the memory ledger tracks occupancy for future mapping
//!   decisions;
//! * **compute events** — each mapped layer segment is evaluated by the
//!   injected compute backend (batched per model at map time) and
//!   completion events are scheduled on the global queue;
//! * **communication** — all activation transfers of all active models
//!   share one [`crate::noc::NetworkSim`] engine (fidelity injected via
//!   the builder), advanced in lockstep with the event queue so
//!   contention between models emerges cycle-accurately;
//! * **power** — every operation books energy per chiplet at 1 µs bins,
//!   and [`SimObserver`] probes see the same event stream.
//!
//! Pipelined mode implements the paper's §V-B2 semantics: a chiplet that
//! finished a layer and sent activations immediately starts the next
//! inference, bounded by a double-buffering credit per pipeline stage.
//!
//! Assemble a run with [`Simulation::builder`].  (The pre-builder
//! `GlobalManager` shim served out its one-release deprecation window
//! and is gone; `Simulation::builder()` is the only entry point.)

mod report;
mod simulation;

pub use report::{KindStats, ModelOutcome, SimReport, ThermalSummary};
pub use simulation::{
    BatchSource, EventCounter, NetworkFactory, NullSink, ObserverHandle, PowerPort,
    RequestSource, RunSession, RunStatus, SimObserver, Simulation, SimulationBuilder,
    StreamSink, ThermalSpec,
};
